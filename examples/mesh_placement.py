"""The framework integration (DESIGN §2): compile a multi-pod training
step, extract its device traffic graph from the HLO, and compute the
VieM-optimized device order for the production mesh.

Run:  PYTHONPATH=src python examples/mesh_placement.py
(needs no TPUs — 512 host devices are forced, like the dry-run).
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np           # noqa: E402
import jax                   # noqa: E402
import jax.numpy as jnp      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import qap_objective, tpu_v5e_fleet            # noqa: E402
from repro.core.comm_model import device_comm_graph, \
    logical_traffic_summary                                    # noqa: E402
from repro.launch.mesh import make_production_mesh, \
    viem_device_order                                          # noqa: E402

mesh = make_production_mesh(multi_pod=True)
D = 1024


def train_step_like(w, x):
    def body(c, wl):
        return jnp.tanh(c @ wl), ()
    h, _ = jax.lax.scan(body, x, w)
    return jnp.sum(h * h)


compiled = jax.jit(
    train_step_like,
    in_shardings=(NamedSharding(mesh, P(None, "data", "model")),
                  NamedSharding(mesh, P(("pod", "data"), "model"))),
    out_shardings=NamedSharding(mesh, P())).lower(
    jax.ShapeDtypeStruct((4, D, D), jnp.bfloat16),
    jax.ShapeDtypeStruct((256, D), jnp.bfloat16)).compile()

hlo = compiled.as_text()
g = device_comm_graph(hlo, 512)
print(f"traffic graph from HLO: {g.num_edges} device pairs, "
      f"{g.total_edge_weight()/2**30:.2f} GiB per step")

order, res = viem_device_order(hlo, 512, pods=2,
                               preconfiguration="fast",
                               neighborhood_dist=2)
h = tpu_v5e_fleet(pods=2)
print(f"identity placement J = {qap_objective(g, h, np.arange(512)):,.0f}")
print(f"VieM placement     J = {res.final_objective:,.0f} "
      f"({res.improvement:.1%} better than its own start)")
print("traffic by fleet level under VieM:")
for k, v in logical_traffic_summary(g, h, res.perm).items():
    print(f"  {k}: {v/2**20:,.1f} MiB")

# the order feeds straight back into the launcher:
devices = np.array(jax.devices())[order]
optimized_mesh = make_production_mesh(multi_pod=True, devices=devices)
print("optimized mesh ready:", optimized_mesh.shape)
