"""Quickstart: the paper's pipeline end to end in 40 lines.

  1. build a communication graph (a 3D stencil application),
  2. describe the machine hierarchy (the guide's parameter strings),
  3. map processes to PEs with VieM (top-down + N_C^d local search),
  4. evaluate the objective and per-level traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Hierarchy, grid3d, map_processes, qap_objective
from repro.core.comm_model import logical_traffic_summary

# 1. an 8×8×8 stencil — 512 communicating processes
g = grid3d(8, 8, 8)
print(f"communication graph: n={g.n} processes, m={g.num_edges} edges")

# 2. machine: 16 cores/processor, 8 processors/node, 4 nodes
#    (--hierarchy_parameter_string=16:8:4 --distance_parameter_string=1:10:100)
h = Hierarchy.from_strings("16:8:4", "1:10:100")

# 3. map (defaults: hierarchytopdown construction + communication
#    neighborhood with distance 10 — guide §4.1)
res = map_processes(g, h, communication_neighborhood_dist=3,
                    preconfiguration_mapping="fast", seed=0)
print(f"construction J = {res.initial_objective:,.0f} "
      f"({res.construction_seconds:.2f}s)")
print(f"after search  J = {res.final_objective:,.0f} "
      f"({res.search_seconds:.2f}s, {res.search_stats.swaps} swaps)")

# compare against naive placements
for name, perm in [("identity", np.arange(g.n)),
                   ("random", np.random.default_rng(0).permutation(g.n))]:
    print(f"{name:9s} J = {qap_objective(g, h, perm):,.0f}")

# 4. where does the traffic live now?
for lvl, traffic in logical_traffic_summary(g, h, res.perm).items():
    print(f"  {lvl}: {traffic:,.0f}")
