"""Quickstart: the paper's pipeline end to end with the session API.

  1. build a communication graph (a 3D stencil application),
  2. describe the machine hierarchy (the guide's parameter strings),
  3. declare the mapping in a MappingSpec and open a Mapper session,
  4. map one graph — then a whole batch through the same session,
  5. stage it explicitly: lower a MappingPlan once, execute many,
  6. evaluate the objective and per-level traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Hierarchy, Mapper, MappingSpec, grid3d, qap_objective
from repro.core.comm_model import logical_traffic_summary

# 1. an 8×8×8 stencil — 512 communicating processes
g = grid3d(8, 8, 8)
print(f"communication graph: n={g.n} processes, m={g.num_edges} edges")

# 2. machine: 16 cores/processor, 8 processors/node, 4 nodes
#    (--hierarchy_parameter_string=16:8:4 --distance_parameter_string=1:10:100)
h = Hierarchy.from_strings("16:8:4", "1:10:100")

# 3. declare *what* to compute: hierarchytopdown construction + N_C^d local
#    search (guide §4.1 defaults), fast preconfiguration.  The spec is a
#    frozen value — serialize it with spec.to_json() and hand the same file
#    to the CLI via `viem --config spec.json`.
spec = MappingSpec(neighborhood="communication", neighborhood_dist=3,
                   preconfiguration="fast", seed=0)
mapper = Mapper(h, spec)   # session: oracle + kernels built once, reused

# 4. map one graph …
res = mapper.map(g)
print(f"construction J = {res.initial_objective:,.0f} "
      f"({res.construction_seconds:.2f}s)")
print(f"after search  J = {res.final_objective:,.0f} "
      f"({res.search_seconds:.2f}s, {res.search_stats.swaps} swaps)")

# … and a batch of same-shape graphs through the same session (the
# hierarchy oracle and candidate neighborhoods are shared, not rebuilt):
variants = []
for i in range(4):
    gg = grid3d(8, 8, 8)
    gg.adjwgt = gg.adjwgt * (1.0 + 0.25 * i)   # shifting traffic intensity
    variants.append(gg)
batch = mapper.map_many(variants)
print("batch         J =",
      ", ".join(f"{r.final_objective:,.0f}" for r in batch))
info = mapper.cache_info()
print(f"session cache: plans={info['plan_builds']} built / "
      f"{info['plan_hits']} hits, pair sets={info['pair_cache_builds']} "
      f"built / {info['pair_cache_hits']} hits")

# 5. the staging is explicit when you want it: lower once (AOT — this is
#    what the session cached for you above), execute many; the plan
#    serializes and reloads bit-identically in another process.
plan = mapper.lower_for(g)
print(f"plan: bucket {plan.bucket.tag()}, "
      f"{len(plan.machines)} level(s), engine={plan.spec.engine}")
res2 = plan.execute(g)
assert np.array_equal(res2.perm, res.perm)

# compare against naive placements
for name, perm in [("identity", np.arange(g.n)),
                   ("random", np.random.default_rng(0).permutation(g.n))]:
    print(f"{name:9s} J = {qap_objective(g, h, perm):,.0f}")

# 6. where does the traffic live now?
for lvl, traffic in logical_traffic_summary(g, h, res.perm).items():
    print(f"  {lvl}: {traffic:,.0f}")
