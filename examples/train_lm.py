"""End-to-end driver: train a reduced granite-family model for a few
hundred steps on synthetic data, with checkpointing and restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

Loss must drop well below ln(vocab) — the data has causal structure.
"""

import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        out = train(args.arch, steps=args.steps, global_batch=8,
                    seq_len=128, smoke=True, ckpt_dir=ckpt,
                    ckpt_every=50, log_every=20)
        print(f"final loss: {out['final_loss']:.4f}")
        # simulate a failure + restart from the latest checkpoint
        out2 = train(args.arch, steps=args.steps + 20, global_batch=8,
                     seq_len=128, smoke=True, ckpt_dir=ckpt,
                     ckpt_every=50, log_every=20)
        print(f"after restart+20 steps: {out2['final_loss']:.4f}")


if __name__ == "__main__":
    main()
