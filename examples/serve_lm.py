"""Serving example: batched prefill + KV/SSM-cache decode across three
model families (attention, SSM, hybrid-MoE).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import serve

for arch in ("granite-3-2b", "rwkv6-3b", "jamba-v0.1-52b"):
    out = serve(arch, batch=2, prompt_len=24, gen=8, smoke=True)
    print(f"{arch:18s} prefill={out['prefill_s']:.2f}s "
          f"decode={out['decode_tok_per_s']:.1f} tok/s "
          f"sample={out['tokens'][0, :6].tolist()}")
