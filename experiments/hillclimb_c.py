"""Hillclimb C: VieM placement on the jamba decode_32k multi-pod cell.

The cell is collective-bound (i=87ms, d=10ms vs m=38ms baseline).  The
roofline collective term assumes placement-oblivious bandwidth; the
*placement-aware* communication cost is exactly the paper's QAP objective
J = Σ bytes·distance over the fleet hierarchy.  This script:

  1. compiles the cell, extracts the per-device traffic graph from HLO,
  2. evaluates J for identity / random placements (baselines),
  3. runs the paper's constructions × neighborhoods (the §Perf iterations),
  4. converts J into a modeled per-step collective time via per-level
     effective bandwidths, and writes the chosen device order.
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import json
import time
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.core import Mapper, MappingSpec, qap_objective, tpu_v5e_fleet
from repro.core.comm_model import device_comm_graph, \
    logical_traffic_summary
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh

OUT = Path(__file__).parent / "hillclimb_c.json"

# per-level effective bandwidth (B/s per chip-pair link at that level):
# tray-local ICI, superblock ICI, cross-superblock ICI, DCN
LEVEL_BW = {1: 50e9, 2: 25e9, 3: 12.5e9, 4: 6.25e9}


def placed_comm_time(g, h, perm):
    """Σ_edges bytes / bw(level(perm)) — placement-aware collective model."""
    u, v, w = g.edge_list()
    lvl = h.lca_level(perm[u], perm[v])
    t = 0.0
    for l, bw in LEVEL_BW.items():
        t += float(np.sum(w[lvl == l])) / bw
    return t


def main():
    cfg = get_config("jamba-v0.1-52b")
    mesh = make_production_mesh(multi_pod=True)
    print("compiling jamba decode_32k multi ...", flush=True)
    lowered, _ = dr.lower_cell(cfg, "decode_32k", mesh)
    hlo = lowered.compile().as_text()
    g = device_comm_graph(hlo, 512)
    h = tpu_v5e_fleet(pods=2)
    print(f"traffic graph: {g.num_edges} edges, "
          f"{g.total_edge_weight()/2**20:.1f} MiB/step")

    results = {}

    def record(name, perm, seconds):
        j = qap_objective(g, h, perm)
        ct = placed_comm_time(g, h, perm)
        results[name] = {
            "J": j, "comm_time_ms": ct * 1e3, "solve_s": seconds,
            "traffic": logical_traffic_summary(g, h, perm)}
        print(f"{name:30s} J={j:12,.0f}  t_comm={ct*1e3:7.3f}ms "
              f"(solve {seconds:.1f}s)")

    record("identity", np.arange(512), 0.0)
    record("random", np.random.default_rng(0).permutation(512), 0.0)

    # one session for the whole sweep: the oracle and the N_C^10 candidate
    # pairs are built once and shared by every C1-C4 iteration below
    base = MappingSpec(preconfiguration="eco", neighborhood_dist=10, seed=0)
    mapper = Mapper(h, base)

    # C1: paper defaults (hierarchytopdown + N_C^10)
    t0 = time.time()
    res = mapper.map(g)
    record("C1_topdown+NC10", res.perm, time.time() - t0)

    # C2: construction ablation (paper's own comparison)
    for cons in ("growing", "hierarchybottomup"):
        t0 = time.time()
        r = mapper.map(g, spec=base.replace(construction=cons))
        record(f"C2_{cons}+NC10", r.perm, time.time() - t0)

    # C3: neighborhood ablation on the best construction
    for d in (1, 2):
        t0 = time.time()
        r = mapper.map(g, spec=base.replace(neighborhood_dist=d))
        record(f"C3_topdown+NC{d}", r.perm, time.time() - t0)
    t0 = time.time()
    r = mapper.map(g, spec=base.replace(neighborhood=None))
    record("C3_topdown_only", r.perm, time.time() - t0)

    # C4: TPU-adapted batched sweep
    t0 = time.time()
    r = mapper.map(g, spec=base.replace(parallel_sweeps=True))
    record("C4_topdown+parallel_NC10", r.perm, time.time() - t0)
    print(f"session cache after C1-C4: {mapper.cache_info()}")

    # C5: the elastic-restart / fragmented-allocation scenario — the
    # scheduler hands out a scrambled fleet (random baseline); can local
    # search alone (no construction) recover the contiguous-layout cost?
    from repro.core.local_search import communication_pairs, local_search, \
        parallel_sweep_search
    rng = np.random.default_rng(1)
    for name, searcher in [
        ("C5_random+NC2_seq", lambda p: local_search(
            g, h, p, neighborhood="communication",
            communication_neighborhood_dist=2, seed=0)),
        ("C5_random+NC10_parallel", lambda p: parallel_sweep_search(
            g, h, p, communication_pairs(g, 10), seed=0)),
    ]:
        p = rng.permutation(512)
        t0 = time.time()
        searcher(p)
        record(name, p, time.time() - t0)
    best = min((k for k in results if k.startswith(("C1", "C2", "C3",
                                                    "C4"))),
               key=lambda k: results[k]["J"])
    results["best"] = best
    results["improvement_vs_identity"] = (
        1 - results[best]["J"] / results["identity"]["J"])
    print(f"\nbest={best}  J improvement vs identity: "
          f"{results['improvement_vs_identity']:.1%}")
    OUT.write_text(json.dumps(results, indent=1, default=str))


if __name__ == "__main__":
    main()
