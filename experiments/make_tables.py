"""Build EXPERIMENTS.md §Dry-run/§Roofline tables from dry-run JSONs."""

import json
from pathlib import Path

DIR = Path(__file__).parent / "dryrun"


def load():
    rows = []
    for f in sorted(DIR.glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(rows, mesh="single"):
    out = ["| arch | shape | step | mem/dev | fits | compute | memory | "
           "ICI | DCN | bound | roofline frac | model/HLO flops | "
           "MFU bound |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = [r for r in rows if r.get("mesh") == mesh]
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | — | skipped | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['per_device_bytes']/2**30:.1f}G "
            f"| {'✓' if r['fits_16g'] else '✗'} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['ici_s'])} | {fmt_s(r['dcn_s'])} "
            f"| {r['bound']} | {r['roofline_fraction']:.3f} "
            f"| {r['model_flops_ratio']:.2f} | {r['mfu_bound']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load()
    print("## single-pod (16×16 = 256 chips)\n")
    print(roofline_table(rows, "single"))
    print("\n## multi-pod (2×16×16 = 512 chips)\n")
    print(roofline_table(rows, "multi"))
