"""Multilevel mapping subsystem: device contraction-kernel invariants,
the shared `core.graph.contract` helper (quotient/partitioner
unification), V-cycle guarantees (bijection at every level, levels=1
bit-parity with the flat engine, multilevel ≤ flat on fixed seeds),
batched V-cycles, spec/CLI plumbing, preconfiguration wiring, and the
LRU-bounded Mapper caches."""

import argparse

import numpy as np
import pytest

from repro.core import (Hierarchy, Mapper, MappingSpec, MultilevelSpec,
                        from_edges, grid3d, qap_objective, random_geometric)
from repro.core.construction import quotient
from repro.core.graph import CommGraph, contract
from repro.core.partition import _contract, _heavy_edge_matching
from repro.multilevel import coarsen_graph, coarsen_machine, \
    project_perm, pyramid_depth
from repro.topology import TorusTopology, TreeTopology

H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


def _pad_edges(g, extra=0):
    import jax.numpy as jnp
    u, v, w = g.edge_list()
    e = max(128, -(-max(len(u), 1) // 128) * 128) + extra
    pad = e - len(u)
    return (jnp.asarray(np.pad(u, (0, pad)).astype(np.int32)),
            jnp.asarray(np.pad(v, (0, pad)).astype(np.int32)),
            jnp.asarray(np.pad(w, (0, pad)).astype(np.float32)))


# ----------------------------------------------------- shared contract()
def _quotient_reference(g, labels, k):
    """The pre-unification quotient implementation (bit-parity oracle)."""
    u, v, w = g.edge_list()
    cu, cv = labels[u], labels[v]
    keep = cu != cv
    cu, cv, w = cu[keep], cv[keep], w[keep]
    lo, hi = np.minimum(cu, cv), np.maximum(cu, cv)
    vw = np.bincount(labels, weights=g.vwgt, minlength=k)
    if len(lo) == 0:
        return CommGraph(np.zeros(k + 1, np.int64), np.zeros(0, np.int64),
                         np.zeros(0), vw)
    return from_edges(k, lo, hi, w, vwgt=vw)


@pytest.mark.parametrize("seed", [0, 3])
def test_shared_contract_is_bit_identical_to_quotient(seed):
    g = random_geometric(48, 0.3, seed=seed)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 6, size=g.n)
    for got in (contract(g, labels, 6), quotient(g, labels, 6)):
        want = _quotient_reference(g, labels, 6)
        assert np.array_equal(got.xadj, want.xadj)
        assert np.array_equal(got.adjncy, want.adjncy)
        assert np.array_equal(got.adjwgt, want.adjwgt)
        assert np.array_equal(got.vwgt, want.vwgt)


def test_partitioner_contract_uses_shared_helper():
    g = random_geometric(40, 0.3, seed=2)
    match = _heavy_edge_matching(g, np.random.default_rng(0))
    coarse, cmap = _contract(g, match)
    rep = np.minimum(np.arange(g.n), match)
    uniq, labels = np.unique(rep, return_inverse=True)
    want = _quotient_reference(g, labels, len(uniq))
    assert np.array_equal(coarse.xadj, want.xadj)
    assert np.array_equal(coarse.adjncy, want.adjncy)
    assert np.array_equal(coarse.adjwgt, want.adjwgt)
    assert np.array_equal(coarse.vwgt, want.vwgt)
    assert np.array_equal(cmap, labels)


# --------------------------------------------- device contraction kernel
@pytest.mark.parametrize("seed", [1, 5])
def test_device_matching_is_perfect_pairing(seed):
    from repro.kernels import contract as ck
    g = random_geometric(64, 0.25, seed=seed)
    eu, ev, ew = _pad_edges(g)
    match = np.asarray(ck.heavy_edge_matching(eu, ev, ew, g.n))
    assert np.all(match != np.arange(g.n))          # nobody self-matched
    assert np.all(match[match] == np.arange(g.n))   # involution
    labels = np.asarray(ck.labels_of_matching(
        ck.heavy_edge_matching(eu, ev, ew, g.n)))
    assert np.all(np.bincount(labels, minlength=g.n // 2) == 2)


def test_device_contraction_invariants():
    from repro.kernels import contract as ck
    g = random_geometric(64, 0.25, seed=7)
    eu, ev, ew = _pad_edges(g)
    import jax.numpy as jnp
    vw = jnp.asarray(g.vwgt.astype(np.float32))
    labels, ceu, cev, cew, cvw = [
        np.asarray(x) for x in ck.coarsen_arrays(eu, ev, ew, vw)]
    nc = g.n // 2
    live = cew > 0
    # self-loops dropped: no live coarse edge joins a cluster to itself
    assert np.all(ceu[live] != cev[live])
    # total edge weight conserved: inter-cluster + dropped intra = total
    u, v, w = g.edge_list()
    intra = w[labels[u] == labels[v]].sum()
    assert cew.sum() + intra == pytest.approx(w.sum(), rel=1e-6)
    # vertex weights summed per cluster; beyond nc all zero
    want_vw = np.bincount(labels, weights=g.vwgt, minlength=g.n)
    assert cvw == pytest.approx(want_vw)
    assert np.all(cvw[nc:] == 0.0)
    # matches the host-side collapse of the same labeling exactly
    host = contract(g, labels.astype(np.int64), nc)
    hu, hv, hw = host.edge_list()
    got = sorted(zip(ceu[live].tolist(), cev[live].tolist(),
                     cew[live].tolist()))
    want = sorted(zip(hu.tolist(), hv.tolist(), hw.tolist()))
    assert [(a, b) for a, b, _ in got] == [(x, y) for x, y, _ in want]
    assert [c for _, _, c in got] == pytest.approx(
        [z for _, _, z in want], rel=1e-5)


def test_device_contraction_is_padding_inert():
    from repro.kernels import contract as ck
    import jax.numpy as jnp
    g = grid3d(4, 4, 2)
    eu, ev, ew = _pad_edges(g)
    labels = ck.labels_of_matching(ck.heavy_edge_matching(eu, ev, ew, g.n))
    base = [np.asarray(x) for x in ck.contract_edges(eu, ev, ew, labels,
                                                     g.n)]
    eu2, ev2, ew2 = (jnp.pad(eu, (0, 256)), jnp.pad(ev, (0, 256)),
                     jnp.pad(ew, (0, 256)))
    big = [np.asarray(x) for x in ck.contract_edges(eu2, ev2, ew2, labels,
                                                    g.n)]
    e = len(base[0])
    assert np.array_equal(big[0][:e], base[0])
    assert np.array_equal(big[1][:e], base[1])
    assert np.allclose(big[2][:e], base[2])
    assert np.all(big[2][e:] == 0.0)                # extra slots stay inert


def test_coarsen_graph_rejects_odd_and_keeps_weights():
    with pytest.raises(ValueError, match="odd"):
        coarsen_graph(grid3d(3, 3, 3))
    g = random_geometric(32, 0.3, seed=4)
    coarse, fine_u, fine_v = coarsen_graph(g)
    assert coarse.n == 16
    assert np.all(fine_u < fine_v)
    members = np.sort(np.concatenate([fine_u, fine_v]))
    assert np.array_equal(members, np.arange(32))   # a perfect pairing
    assert coarse.vwgt.sum() == pytest.approx(g.vwgt.sum())


# ------------------------------------------------------- machine pyramid
def test_coarsen_machine_pairs_siblings():
    h = TreeTopology(hierarchy=Hierarchy((2, 2), (1.0, 10.0)))
    coarse = coarsen_machine(h)
    assert coarse.n_pe == 2
    # PEs (0,1) and (2,3) are sibling pairs: every cross distance is the
    # top-level 10, so the coarse distance is exactly 10
    assert coarse.distance(0, 1) == pytest.approx(10.0)
    assert coarse.distance(0, 0) == 0.0


def test_coarsen_machine_survives_non_representable_weights():
    # the four cross distances of (a, b) and (b, a) sum in different
    # orders; without explicit symmetrization the ULP mismatch trips
    # MatrixTopology's exact-symmetry validation (regression)
    coarse = coarsen_machine(TorusTopology((8, 8), (1.1, 0.3)))
    assert coarse.n_pe == 32


def test_coarsen_machine_torus_last_axis_neighbors():
    t = TorusTopology((4, 4), (1.0, 1.0))
    coarse = coarsen_machine(t)
    assert coarse.n_pe == 8
    D = coarse.matrix()
    assert np.array_equal(D, D.T)
    assert np.all(np.diag(D) == 0.0)
    assert np.all(D[~np.eye(8, dtype=bool)] > 0)


def test_pyramid_depth_rules():
    assert pyramid_depth(64, levels=4, coarsen_min=8) == 4   # budget binds
    assert pyramid_depth(64, levels=10, coarsen_min=16) == 3  # 64→32→16
    assert pyramid_depth(63, levels=4, coarsen_min=8) == 1   # odd: flat
    assert pyramid_depth(64, levels=1, coarsen_min=2) == 1   # escape hatch


# ------------------------------------------------------------ the V-cycle
def _ml_spec(**kw):
    base = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="eco",
                engine="device", seed=1,
                multilevel=MultilevelSpec(levels=3, coarsen_min=8))
    base.update(kw)
    return MappingSpec(**base)


def test_projection_is_bijection_at_every_level():
    spec = _ml_spec()
    mapper = Mapper(H64, spec)
    g = grid3d(4, 4, 4)
    pyramid = mapper.lower_for(g)._pyramid(g, spec.seed)
    assert len(pyramid) == 3
    rng = np.random.default_rng(0)
    perm = rng.permutation(pyramid[-1].graph.n).astype(np.int64)
    for lvl in range(len(pyramid) - 1, 0, -1):
        level = pyramid[lvl]
        assert sorted(perm.tolist()) == list(range(level.graph.n))
        assert level.graph.n == level.machine.n_pe
        perm = project_perm(perm, level.fine_u, level.fine_v)
    assert sorted(perm.tolist()) == list(range(g.n))


def test_levels_one_reproduces_flat_engine_bit_for_bit():
    flat = _ml_spec(multilevel=None)
    hatch = _ml_spec(multilevel=MultilevelSpec(levels=1))
    g = grid3d(4, 4, 4)
    rf = Mapper(H64, flat).map(g)
    r1 = Mapper(H64, hatch).map(g)
    assert np.array_equal(r1.perm, rf.perm)
    assert r1.final_objective == rf.final_objective
    assert r1.initial_objective == rf.initial_objective


@pytest.mark.parametrize("machine", ["tree", "torus"])
def test_multilevel_beats_or_matches_flat(machine):
    topo = H64 if machine == "tree" else TorusTopology((8, 8))
    g = grid3d(4, 4, 4)
    flat = _ml_spec(multilevel=None)
    rf = Mapper(topo, flat).map(g)
    rm = Mapper(topo, _ml_spec()).map(g)
    tol = 1e-6 * max(1.0, abs(rf.final_objective))
    assert rm.final_objective <= rf.final_objective + tol
    assert sorted(rm.perm.tolist()) == list(range(g.n))
    assert rm.final_objective == pytest.approx(
        qap_objective(g, Mapper(topo, flat).topology, rm.perm), rel=1e-9)


def test_multilevel_map_is_deterministic_and_caches_pyramid():
    spec = _ml_spec()
    mapper = Mapper(H64, spec)
    g = grid3d(4, 4, 4)
    r1 = mapper.map(g)
    r2 = mapper.map(g)
    assert np.array_equal(r1.perm, r2.perm)
    info = mapper.cache_info()
    assert info["pyramid_builds"] == 1
    assert info["pyramid_hits"] == 1
    # one engine per level (tree + 2 coarse matrix machines), all cached
    assert info["engine_builds"] == 3


def test_multilevel_map_many_matches_single_maps():
    spec = _ml_spec()
    graphs = []
    for i in range(3):
        g = grid3d(4, 4, 4)
        g.adjwgt = g.adjwgt * (1.0 + 0.5 * i)
        graphs.append(g)
    batch = Mapper(H64, spec).map_many(graphs)
    singles = [Mapper(H64, spec).map(g) for g in graphs]
    for got, want in zip(batch, singles):
        assert got.final_objective == pytest.approx(want.final_objective,
                                                    rel=1e-5)
        assert sorted(got.perm.tolist()) == list(range(64))


def test_multilevel_neighborhood_none_still_maps():
    spec = _ml_spec(neighborhood=None)
    res = Mapper(H64, spec).map(grid3d(4, 4, 4))
    assert sorted(res.perm.tolist()) == list(range(64))


# ------------------------------------------------------ spec/CLI plumbing
def test_multilevel_spec_round_trip_and_unknown_keys():
    spec = _ml_spec()
    again = MappingSpec.from_json(spec.to_json())
    assert again == spec
    assert again.multilevel == MultilevelSpec(levels=3, coarsen_min=8)
    with pytest.raises(ValueError, match="unknown MultilevelSpec keys"):
        MappingSpec.from_dict({"multilevel": {"depth": 3}})
    with pytest.raises(ValueError, match="levels"):
        MappingSpec(engine="device",
                    multilevel=MultilevelSpec(levels=0)).validate()
    with pytest.raises(ValueError, match="coarsen_min"):
        MappingSpec(engine="device",
                    multilevel=MultilevelSpec(coarsen_min=1)).validate()
    with pytest.raises(ValueError, match="device"):
        MappingSpec(engine="host",
                    multilevel=MultilevelSpec()).validate()


def test_multilevel_flags_imply_device_engine():
    ns = argparse.Namespace(multilevel=True)
    spec = MappingSpec.from_flags(ns)
    assert spec.engine == "device"
    assert spec.multilevel == MultilevelSpec()
    ns = argparse.Namespace(multilevel_levels=2, multilevel_coarsen_min=16)
    spec = MappingSpec.from_flags(ns)
    assert spec.multilevel == MultilevelSpec(levels=2, coarsen_min=16)
    # an explicit --engine=host wins (and validate() then rejects it)
    ns = argparse.Namespace(multilevel=True, engine="host")
    assert MappingSpec.from_flags(ns).engine == "host"
    # --no-multilevel clears a config-file multilevel block
    base = _ml_spec()
    ns = argparse.Namespace(multilevel=False)
    assert MappingSpec.from_flags(ns, base=base).multilevel is None


def test_preconfiguration_resolves_vcycle_and_sweep_knobs():
    assert MultilevelSpec().resolve("fast") == (2, 128)
    assert MultilevelSpec().resolve("eco") == (4, 64)
    assert MultilevelSpec().resolve("strong") == (6, 32)
    assert MultilevelSpec(levels=3).resolve("strong") == (3, 32)
    assert MultilevelSpec(coarsen_min=4).resolve("fast") == (2, 4)
    from repro.core.plan import sweep_budget
    for name, sweeps in (("fast", 32), ("eco", 64), ("strong", 128)):
        assert sweep_budget(MappingSpec(preconfiguration=name)) == sweeps
    assert sweep_budget(MappingSpec(max_sweeps=7)) == 7
    # levels=1 via preconfiguration still counts as flat
    assert MappingSpec(
        engine="device",
        multilevel=MultilevelSpec(levels=1)).resolved_multilevel() is None
    got = MappingSpec(engine="device", preconfiguration="fast",
                      multilevel=MultilevelSpec()).resolved_multilevel()
    assert got == (2, 128)


# ----------------------------------------------------- LRU-bounded caches
def test_plan_cache_is_bounded_with_visible_evictions():
    spec = MappingSpec(construction="random", neighborhood="communication",
                       neighborhood_dist=2, preconfiguration="fast",
                       engine="device", seed=0)
    mapper = Mapper(H64, spec, cache_caps={"plans": 2})
    g = grid3d(4, 4, 4)
    for sweeps in (2, 3, 4):        # three distinct plan keys, cap 2
        mapper.map(g, spec=spec.replace(max_sweeps=sweeps))
    info = mapper.cache_info()
    assert info["plan_builds"] == 3
    assert info["plan_evictions"] == 1
    assert len(mapper._plans) == 2
    # every plan built one engine; the evicted plan's counter is retired,
    # not lost
    assert info["engine_builds"] == 3
    with pytest.raises(ValueError, match="cache_caps"):
        Mapper(H64, spec, cache_caps={"nope": 1})


def test_pair_and_pyramid_caches_evict_at_cap():
    spec = _ml_spec()
    mapper = Mapper(H64, spec, cache_caps={"pairs": 2, "pyramids": 1})
    graphs = []
    for i in range(3):
        g = grid3d(4, 4, 4)
        g.adjwgt = g.adjwgt * (i + 1.0)
        graphs.append(g)
    for g in graphs:
        mapper.map(g)
    info = mapper.cache_info()
    # pyramids key on weights: three builds through a (per-plan) cap-1
    # cache — same structure means one plan serves all three graphs
    assert info["plan_builds"] == 1
    assert info["pyramid_builds"] == 3
    assert info["pyramid_evictions"] == 2
    plan = mapper.lower_for(graphs[0])
    assert len(plan._pyramids) == 1
    # candidate pairs of the V-cycle live inside the pyramid entries (one
    # set per level), so the plan's separate pair cache stays in its cap
    assert len(plan._pairs_lru) <= 2