"""The invariant lint engine (`viem lint`) and the jaxpr audit.

Per-rule fixtures run the analyzer over small source snippets — one
triggering, one clean, one suppressed — so a rule regression fails here
before it floods a real module with findings.  The audit tests lower the
registered construction x topology grid (the same sweep the CI
staticcheck job runs) and prove the engine jaxprs carry no host
callbacks or dtype drift.  A threaded stress test locks in the VIEM004
fix in ``obs.metrics.Histogram``.
"""

import threading

import pytest

from repro.staticcheck import analyze_source, lint_paths, LintConfig
from repro.staticcheck.engine import lint_source
from repro.staticcheck.jaxpr_audit import check_jaxpr, run_audit
from repro.staticcheck.rules import RULE_IDS

DEV = "src/repro/engine/snippet.py"      # device-package relpath
HOST = "src/repro/cli/snippet.py"        # non-device relpath
LOCKED = "src/repro/obs/metrics.py"      # lock-discipline module


def _rules(source, relpath=DEV, rules=RULE_IDS):
    return [f.rule for f in analyze_source(source, relpath, rules)]


# ------------------------------------------------------------- VIEM001
SYNC_TRIGGER = """\
import numpy as np

def readback(x):
    import jax.numpy as jnp
    y = jnp.abs(x)
    return np.asarray(y)
"""

SYNC_CLEAN = """\
import numpy as np
from repro.runtime.boundary import host_boundary

def readback(x):
    import jax.numpy as jnp
    y = jnp.abs(x)
    with host_boundary("engine.readback"):
        return np.asarray(y)
"""

SYNC_ITEM = """\
def readback(x):
    import jax.numpy as jnp
    return jnp.abs(x).item()
"""

SYNC_TIMING = """\
import time

def profile(x):
    import jax.numpy as jnp
    def body(v):
        t0 = time.perf_counter()
        return jnp.abs(v)
    return jax.jit(body)(x)
"""


def test_viem001_flags_np_readback():
    assert "VIEM001" in _rules(SYNC_TRIGGER)


def test_viem001_exempts_host_boundary():
    assert "VIEM001" not in _rules(SYNC_CLEAN)


def test_viem001_flags_item():
    assert "VIEM001" in _rules(SYNC_ITEM)


def test_viem001_flags_timing_in_traced_scope():
    assert "VIEM001" in _rules(SYNC_TIMING)


def test_viem001_only_in_device_packages():
    assert "VIEM001" not in _rules(SYNC_TRIGGER, relpath=HOST)


def test_viem001_static_attrs_do_not_taint():
    src = ("def f(x):\n"
           "    import jax.numpy as jnp\n"
           "    n = jnp.abs(x).shape[0]\n"
           "    return float(n)\n")
    assert "VIEM001" not in _rules(src)


# ------------------------------------------------------------- VIEM002
RETRACE_TRIGGER = """\
import jax

def serve(params, tokens, cfg):
    step = jax.jit(lambda p, t: p[0] * t * cfg.scale)
    return step(params, tokens)
"""

RETRACE_CLEAN = """\
import functools
import jax

@functools.lru_cache(maxsize=8)
def _compiled_step(cfg):
    return jax.jit(functools.partial(_step, cfg=cfg))

def serve(params, tokens, cfg):
    return _compiled_step(cfg)(params, tokens)
"""


def test_viem002_flags_jit_closure_in_function():
    assert "VIEM002" in _rules(RETRACE_TRIGGER, relpath=HOST)


def test_viem002_accepts_cached_builder():
    assert "VIEM002" not in _rules(RETRACE_CLEAN, relpath=HOST)


# ------------------------------------------------------------- VIEM003
CONTROL_TRIGGER = """\
def refine(x):
    import jax.numpy as jnp
    g = jnp.sum(x)
    if g > 0:
        return g
    return -g
"""

CONTROL_CLEAN = """\
def refine(x):
    import jax.numpy as jnp
    g = jnp.sum(x)
    return jnp.where(g > 0, g, -g)
"""


def test_viem003_flags_python_branch_on_traced():
    assert "VIEM003" in _rules(CONTROL_TRIGGER)


def test_viem003_accepts_where():
    assert "VIEM003" not in _rules(CONTROL_CLEAN)


def test_viem003_allows_string_dispatch():
    src = ("def f(kind, x):\n"
           "    import jax.numpy as jnp\n"
           "    y = jnp.abs(x)\n"
           "    if kind == 'matrix':\n"
           "        return y\n"
           "    return -y\n")
    assert "VIEM003" not in _rules(src)


# ------------------------------------------------------------- VIEM004
LOCK_TRIGGER = """\
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0

    def inc(self):
        with self._lock:
            self.total += 1

    def read(self):
        return self.total
"""

LOCK_CLEAN = """\
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0

    def inc(self):
        with self._lock:
            self.total += 1

    def read(self):
        with self._lock:
            return self.total
"""


def test_viem004_flags_unguarded_read():
    assert "VIEM004" in _rules(LOCK_TRIGGER, relpath=LOCKED)


def test_viem004_accepts_guarded_read():
    assert "VIEM004" not in _rules(LOCK_CLEAN, relpath=LOCKED)


def test_viem004_scoped_to_lock_modules():
    assert "VIEM004" not in _rules(LOCK_TRIGGER, relpath=HOST)


# ------------------------------------------------------- suppressions
def test_noqa_suppresses_with_justification():
    src = SYNC_TRIGGER.replace(
        "return np.asarray(y)",
        "return np.asarray(y)  "
        "# viem: noqa[VIEM001] tested allclose sweep, host on purpose")
    findings = lint_source(src, DEV)
    assert all(f.suppressed for f in findings if f.rule == "VIEM001")
    sup = [f for f in findings if f.suppressed]
    assert sup and all(f.justification for f in sup)


def test_noqa_other_rule_does_not_suppress():
    src = SYNC_TRIGGER.replace(
        "return np.asarray(y)",
        "return np.asarray(y)  # viem: noqa[VIEM003] wrong rule")
    findings = lint_source(src, DEV)
    assert any(f.rule == "VIEM001" and not f.suppressed for f in findings)


def test_baseline_fingerprint_suppresses():
    clean = lint_source(SYNC_TRIGGER, DEV)
    fps = {f.fingerprint() for f in clean}
    based = lint_source(SYNC_TRIGGER, DEV, baseline=fps)
    assert based and all(f.suppressed for f in based)


def test_repo_is_lint_clean():
    """The shipping tree has zero unsuppressed findings (the CI
    staticcheck job's blocking condition)."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    result = lint_paths(LintConfig(paths=("src",)), root=root)
    assert result.active == [], [f.fingerprint() for f in result.active]
    assert result.unjustified == []


# ------------------------------------------------------------ jaxpr audit
def test_check_jaxpr_flags_callbacks_and_dtype():
    import jax
    import jax.numpy as jnp
    import numpy as np

    def cb(x):
        result_shape = jax.ShapeDtypeStruct(x.shape, x.dtype)
        return jax.pure_callback(lambda v: np.asarray(v), result_shape, x)

    bad = jax.make_jaxpr(cb)(jnp.zeros((4,), jnp.float32))
    assert any("pure_callback" in p for p in check_jaxpr(bad))

    good = jax.make_jaxpr(lambda x: jnp.sum(x * 2))(
        jnp.zeros((4,), jnp.float32))
    assert check_jaxpr(good) == []
    assert check_jaxpr(good, acc_dtype="float64")   # f32 ops vs f64 plan


@pytest.mark.parametrize("topology", ["tree", "torus", "fattree",
                                      "dragonfly", "matrix"])
def test_jaxpr_audit_topology_lane(topology):
    report = run_audit(topologies=[topology])
    assert report["ok"], report["entries"]
    ok = [e for e in report["entries"] if e["status"] == "ok"]
    assert ok, report["entries"]    # at least one construction lowered


# ------------------------------------------------- VIEM004 regression
def test_histogram_snapshot_thread_safe():
    """obs.metrics.Histogram: snapshot() sorts the recent-window deque;
    pre-fix that ran unlocked against observe() appends and raised
    'deque mutated during iteration' under contention."""
    from repro.obs.metrics import Histogram

    h = Histogram(threading.RLock(), window=4096)
    stop = threading.Event()
    errors: list[Exception] = []

    def writer():
        i = 0
        while not stop.is_set():
            h.observe(i * 0.001)
            i += 1

    def reader():
        while not stop.is_set():
            try:
                h.snapshot()
            except Exception as exc:            # pragma: no cover
                errors.append(exc)
                stop.set()

    threads = [threading.Thread(target=writer) for _ in range(2)] + \
              [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    stop.wait(1.0)
    stop.set()
    for t in threads:
        t.join()
    assert errors == []
    snap = h.snapshot()
    assert snap["count"] > 0
