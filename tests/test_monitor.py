"""Closed-loop remapping (`repro.monitor`): profiler EMA windows, drift
hysteresis, dirty-region masking (inert pairs, zero retraces), the
what-if replay gate, the end-to-end loop, and the fault-tolerance
wiring."""

from pathlib import Path

import numpy as np
import pytest

from repro.core import Mapper, MappingSpec
from repro.core.graph import from_edges, grid3d
from repro.monitor import (DriftDetector, MonitorConfig, RemapMonitor,
                           TrafficProfiler, WhatIfReplay, dirty_pair_mask,
                           dirty_vertices, edge_weight_l1, expand_dirty)
from repro.runtime.fault_tolerance import Action, StragglerMonitor
from repro.topology import make_topology

FIXTURE = Path(__file__).parent / "fixtures" / "collectives.hlo"
N = 64


def _graph():
    return grid3d(4, 4, 4)


def _plan(schedule="pow2", **spec_kw):
    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication", neighborhood_dist=10,
                       engine="device", seed=0, **spec_kw)
    topo = make_topology("torus", dims=[8, 8])
    return Mapper(topo, spec).lower_for(_graph(), schedule=schedule)


def _scaled(g, vertices, factor):
    """Scale every edge incident to ``vertices`` by ``factor``."""
    u, v, w = g.edge_list()
    m = np.zeros(g.n, bool)
    m[vertices] = True
    return from_edges(g.n, u, v, np.where(m[u] | m[v], w * factor, w))


# ---------------------------------------------------------------- profiler
def test_profiler_ema_and_pruning():
    p = TrafficProfiler(4, alpha=0.5, min_weight=1.0)
    p.ingest_edges([0, 1], [1, 2], [8.0, 4.0])
    p.end_window()
    assert p.live_edges() == {(0, 1): 4.0, (1, 2): 2.0}
    p.end_window()    # empty window decays everything by (1 - alpha)
    assert p.live_edges() == {(0, 1): 2.0, (1, 2): 1.0}
    p.end_window()    # (1, 2) decays to 0.5 < min_weight: pruned
    assert p.live_edges() == {(0, 1): 1.0}


def test_profiler_prime_is_exact():
    g = _graph()
    p = TrafficProfiler(g.n, alpha=0.5)
    p.prime(g)
    assert edge_weight_l1(g, p.live()) == 0.0


def test_profiler_folds_directions_and_rejects_bad_edges():
    p = TrafficProfiler(4)
    p.ingest_edges([0, 1], [1, 0], [3.0, 5.0])
    p.end_window()
    assert p.live_edges() == {(0, 1): pytest.approx(0.5 * 8.0)}
    with pytest.raises(ValueError, match="outside device range"):
        p.ingest_edges([0], [9], [1.0])


def test_profiler_ingests_hlo_fixture():
    p = TrafficProfiler(8, alpha=1.0, min_weight=0.0)
    p.ingest_hlo(FIXTURE.read_text())
    live = p.end_window()
    # the ring-priced all-reduce dominates: 8 links x 6144 B
    assert live.num_edges == 16
    assert p.live_edges()[(0, 1)] == pytest.approx(4 * 2 * (3 / 4) * 1024)


def test_profiler_publishes_window_metrics():
    p = TrafficProfiler(4, alpha=1.0)
    p.ingest_edges([0], [1], [100.0])
    p.end_window()
    snap = p.registry.snapshot()
    assert snap["monitor.windows"] == 1
    assert snap["monitor.traffic.bytes"] == 100.0
    assert snap["monitor.traffic.edges"] == 1.0


# ------------------------------------------------------------------- drift
def test_edge_weight_l1_hand_values():
    a = from_edges(3, [0, 1], [1, 2], [10.0, 10.0])
    assert edge_weight_l1(a, a) == 0.0
    b = from_edges(3, [0, 1], [1, 2], [15.0, 10.0])
    assert edge_weight_l1(a, b) == pytest.approx(0.25)
    c = from_edges(3, [0], [1], [10.0])      # (1,2) vanished
    assert edge_weight_l1(a, c) == pytest.approx(0.5)


def test_drift_hysteresis_patience_and_rearm():
    g = _graph()
    perm = np.arange(g.n)
    obj = lambda gg, p: float(gg.edge_list()[2].sum())  # noqa: E731
    det = DriftDetector(g, perm, obj, high=0.10, low=0.05, patience=2)
    hot = _scaled(g, range(16), 4.0)
    # patience: first hot window scores high but does not trigger
    assert not det.update(hot).triggered
    s = det.update(hot)
    assert s.triggered
    # disarmed: staying hot cannot re-trigger
    assert not det.update(hot).triggered
    assert not det.update(hot).triggered
    # one quiet window is below `low`: re-arms
    assert not det.update(g).triggered
    r = [det.update(hot) for _ in range(2)]
    assert sum(x.triggered for x in r) == 1


def test_drift_jitter_never_accumulates():
    g = _graph()
    u, v, w = g.edge_list()
    rng = np.random.default_rng(0)
    obj = lambda gg, p: float(gg.edge_list()[2].sum())  # noqa: E731
    det = DriftDetector(g, np.arange(g.n), obj, high=0.10, low=0.05,
                        patience=2)
    for _ in range(50):
        jit = from_edges(g.n, u, v,
                         w * rng.uniform(0.98, 1.02, size=len(w)))
        assert not det.update(jit).triggered


def test_drift_rebaseline_resets():
    g = _graph()
    obj = lambda gg, p: float(gg.edge_list()[2].sum())  # noqa: E731
    det = DriftDetector(g, np.arange(g.n), obj, high=0.1, low=0.05,
                        patience=1)
    hot = _scaled(g, range(16), 4.0)
    assert det.update(hot).triggered
    det.rebaseline(hot, np.arange(g.n))
    s = det.update(hot)
    assert s.score == pytest.approx(0.0) and not s.triggered


# ------------------------------------------------------------ dirty region
def test_dirty_vertices_and_mask():
    base = from_edges(6, [0, 2, 4], [1, 3, 5], [10.0, 10.0, 10.0])
    live = from_edges(6, [0, 2, 4], [1, 3, 5], [10.2, 20.0, 10.0])
    d = dirty_vertices(base, live, rel_tol=0.05)
    assert list(np.nonzero(d)[0]) == [2, 3]
    pairs = np.array([[0, 1], [2, 5], [4, 5]])
    assert list(dirty_pair_mask(pairs, d)) == [False, True, False]
    # appear/disappear always dirty
    gone = from_edges(6, [0, 2], [1, 3], [10.0, 10.0])
    d2 = dirty_vertices(base, gone, rel_tol=0.5)
    assert list(np.nonzero(d2)[0]) == [4, 5]


def test_expand_dirty_halo():
    g = from_edges(5, [0, 1, 2, 3], [1, 2, 3, 4], np.ones(4))
    d = np.zeros(5, bool)
    d[0] = True
    assert list(np.nonzero(expand_dirty(g, d, hops=1))[0]) == [0, 1]
    assert list(np.nonzero(expand_dirty(g, d, hops=2))[0]) == [0, 1, 2]
    assert expand_dirty(g, d, hops=0).sum() == 1


# ------------------------------------------------------- warm execution
def test_execute_warm_full_mask_matches_unmasked():
    plan = _plan()
    g = _graph()
    res0 = plan.execute(g)
    live = _scaled(g, range(16), 8.0)
    pairs = plan.candidate_pairs(g)
    r_none = plan.execute_warm(live, res0.perm, pairs=pairs)
    r_all = plan.execute_warm(live, res0.perm, pairs=pairs,
                              active=np.ones(len(pairs), bool))
    assert np.array_equal(r_none.perm, r_all.perm)
    assert r_none.final_objective == r_all.final_objective
    assert r_none.final_objective <= r_none.initial_objective


def test_execute_warm_does_not_mutate_incumbent():
    plan = _plan()
    g = _graph()
    res0 = plan.execute(g)
    incumbent = res0.perm.copy()
    plan.execute_warm(_scaled(g, range(16), 8.0), res0.perm)
    assert np.array_equal(res0.perm, incumbent)


def test_execute_warm_mask_freezes_untouched_vertices():
    plan = _plan()
    g = _graph()
    res0 = plan.execute(g)
    live = _scaled(g, range(8), 8.0)
    pairs = plan.candidate_pairs(g)
    dirty = expand_dirty(live, dirty_vertices(g, live), hops=1)
    mask = dirty_pair_mask(pairs, dirty)
    res = plan.execute_warm(live, res0.perm, pairs=pairs, active=mask)
    # vertices in no active pair can never be exchanged
    movable = np.zeros(g.n, bool)
    movable[pairs[mask].ravel()] = True
    frozen = ~movable
    assert np.array_equal(res.perm[frozen], res0.perm[frozen])


def test_execute_warm_rejects_bad_mask_shape():
    plan = _plan()
    g = _graph()
    res0 = plan.execute(g)
    with pytest.raises(ValueError, match="active mask"):
        plan.execute_warm(g, res0.perm, active=np.ones(3, bool))


def test_execute_warm_masking_adds_zero_traces():
    plan = _plan()
    g = _graph()
    res0 = plan.execute(g)     # compiles the (K, E, P) executable
    pairs = plan.candidate_pairs(g)
    eng = plan.engines[0]
    before = eng.trace_count()
    rng = np.random.default_rng(0)
    for factor in (2.0, 8.0, 0.5):
        live = _scaled(g, rng.permutation(g.n)[:16], factor)
        mask = dirty_pair_mask(pairs, dirty_vertices(g, live))
        plan.execute_warm(live, res0.perm, pairs=pairs, active=mask)
        plan.execute_warm(live, res0.perm, pairs=pairs)   # full refine
    assert eng.trace_count() == before


def test_execute_warm_host_engine_parity():
    # host-engine fallback refines only the active pairs
    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication", neighborhood_dist=10,
                       engine="host", parallel_sweeps=True, seed=0)
    topo = make_topology("torus", dims=[8, 8])
    plan = Mapper(topo, spec).lower_for(_graph())
    g = _graph()
    res0 = plan.execute(g)
    live = _scaled(g, range(16), 8.0)
    res = plan.execute_warm(live, res0.perm)
    assert res.final_objective <= res.initial_objective


# ------------------------------------------------------------------ replay
def test_replay_gate_accepts_only_above_margin():
    topo = make_topology("torus", dims=[8, 8])
    g = _graph()
    rep = WhatIfReplay(topo, margin=0.02)
    perm = np.arange(N)
    worse = np.roll(perm, 7)
    ji = rep._objective(g, perm)
    jw = rep._objective(g, worse)
    assert jw > ji
    # candidate better than incumbent by a lot: accepted
    v = rep.evaluate(g, worse, perm)
    assert v.accepted and v.predicted_improvement >= 0.02
    # candidate == incumbent: rejected (no strict objective win)
    v2 = rep.evaluate(g, perm, perm.copy())
    assert not v2.accepted and v2.predicted_improvement == 0.0
    # tiny win below the margin: rejected
    rep_wide = WhatIfReplay(topo, margin=0.99)
    assert not rep_wide.evaluate(g, worse, perm).accepted


def test_replay_compute_bound_program_gates_off():
    # a compute-dominated HloCost: comm improvements cannot move the
    # max-of-terms step time, so the gate must reject
    from repro.analysis.hlo import HloCost
    topo = make_topology("torus", dims=[8, 8])
    g = _graph()
    cost = HloCost(flops=1e18, hbm_bytes=0.0)
    rep = WhatIfReplay(topo, margin=0.02, cost=cost)
    perm, worse = np.arange(N), np.roll(np.arange(N), 7)
    v = rep.evaluate(g, worse, perm)
    assert not v.accepted and v.predicted_improvement == 0.0


def test_replay_counters_and_prediction_consistency():
    topo = make_topology("torus", dims=[8, 8])
    g = _graph()
    rep = WhatIfReplay(topo, margin=0.0)
    perm, worse = np.arange(N), np.roll(np.arange(N), 7)
    rep.evaluate(g, worse, perm)
    rep.evaluate(g, perm, worse)
    snap = rep.registry.snapshot()
    assert snap["monitor.replay.evaluated"] == 2
    assert snap["monitor.replay.accepted"] == 1
    assert snap["monitor.replay.rejected"] == 1
    t = rep.predict_step_time(g, perm)
    assert t == pytest.approx(rep.comm_seconds(g, perm))


# -------------------------------------------------------------- the loop
@pytest.fixture(scope="module")
def loop_setup():
    plan = _plan()
    g = _graph()
    return plan, g


def _monitor(plan, g, **cfg_kw):
    kw = dict(drift_patience=2, min_weight=0.01)
    kw.update(cfg_kw)
    return RemapMonitor(plan, g, config=MonitorConfig(**kw), seed=0)


def test_loop_jitter_triggers_zero_remaps(loop_setup):
    plan, g = loop_setup
    mon = _monitor(plan, g)
    u, v, w = g.edge_list()
    rng = np.random.default_rng(1)
    for _ in range(6):
        mon.observe_graph(from_edges(
            g.n, u, v, w * rng.uniform(0.99, 1.01, size=len(w))))
        r = mon.tick()
        assert not r.triggered and not r.remapped
    assert mon.remaps == 0
    assert mon.registry.snapshot().get("monitor.remaps.committed", 0) == 0


def test_loop_shift_detects_gates_and_remaps(loop_setup):
    plan, g = loop_setup
    mon = _monitor(plan, g)
    incumbent0 = mon.incumbent.copy()
    shifted = _scaled(g, range(16), 8.0)
    reports = []
    for _ in range(4):
        mon.observe_graph(shifted)
        reports.append(mon.tick())
    remapped = [r for r in reports if r.remapped]
    assert len(remapped) >= 1
    r = remapped[0]
    assert r.verdict.accepted
    assert r.verdict.objective_candidate < r.verdict.objective_incumbent
    assert r.retraces == 0
    assert 0 < r.dirty <= g.n
    assert not np.array_equal(mon.incumbent, incumbent0)
    # the committed incumbent prices better on the live graph
    live = mon.baseline
    assert plan.objective(live, mon.incumbent) \
        < plan.objective(live, incumbent0)


def test_loop_warm_remaps_add_zero_engine_traces(loop_setup):
    plan, g = loop_setup
    mon = _monitor(plan, g)
    before = sum(e.trace_count() for e in plan.engines)
    shifted = _scaled(g, range(24), 6.0)
    for _ in range(4):
        mon.observe_graph(shifted)
        mon.tick()
    assert mon.remaps >= 1
    assert sum(e.trace_count() for e in plan.engines) == before


def test_loop_rebalance_action_forces_gated_attempt(loop_setup):
    plan, g = loop_setup
    mon = _monitor(plan, g)
    mon.handle_action(Action.REBALANCE, [3], pes_per_host=16)
    u, v, w = g.edge_list()
    mon.observe_graph(from_edges(g.n, u, v, w.copy()))
    r = mon.tick()
    # forced: triggered without drift, evaluated through the gate
    assert r.triggered and r.forced_by == "rebalance"
    assert r.verdict is not None
    # traffic did not change, so the gate must hold the incumbent
    assert not r.remapped
    snap = mon.registry.snapshot()
    assert snap["monitor.action.rebalance"] == 1
    assert snap["monitor.remaps.rolled_back"] == 1


def test_loop_attach_straggler_monitor(loop_setup):
    plan, g = loop_setup
    mon = _monitor(plan, g)
    sm = StragglerMonitor(n_hosts=4, patience=2)
    mon.attach(sm)
    for _ in range(3):
        sm.record_step({h: (3.0 if h == 1 else 1.0) for h in range(4)})
    assert mon._forced and mon._forced[0][0] == "rebalance"


def test_loop_evict_restart_marks_all_dirty(loop_setup):
    plan, g = loop_setup
    mon = _monitor(plan, g)
    mon.handle_action(Action.EVICT_RESTART, [0])
    assert mon._forced[0][1].all()


def test_loop_bucket_exceeded_skips_instead_of_retracing():
    plan = _plan(schedule="tight")
    g = _graph()
    mon = _monitor(plan, g, drift_patience=1)
    # densify: a clique over the first 16 vertices blows the tight bucket
    u, v, w = g.edge_list()
    uu, vv = np.triu_indices(16, k=1)
    live = from_edges(g.n, np.concatenate([u, uu]),
                      np.concatenate([v, vv]),
                      np.concatenate([w, np.full(len(uu), 50.0)]))
    assert not plan.bucket.admits(live)
    mon.observe_graph(live)
    r = mon.tick()
    assert r.triggered and r.skipped == "bucket_exceeded"
    assert not r.remapped
    assert mon.registry.snapshot()["monitor.bucket_exceeded"] == 1


def test_fleet_monitor_wires_hlo_to_loop():
    from repro.launch.mesh import fleet_monitor
    topo = make_topology("torus", dims=[4, 2])
    mon, order = fleet_monitor(FIXTURE.read_text(), 8,
                               machine_model=topo)
    assert sorted(order) == list(range(8))
    committed = []
    mon.on_remap = lambda p, v: committed.append(p.copy())
    # shift the fixture's traffic hard and tick until the gate decides
    live = _scaled(mon.baseline, [0, 1, 2, 3], 16.0)
    for _ in range(4):
        mon.observe_graph(live)
        mon.tick()
    assert mon.ticks == 4
    for p in committed:
        assert sorted(p) == list(range(8))
