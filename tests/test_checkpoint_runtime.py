"""Checkpoint roundtrip/atomicity/async + fault-tolerance policies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (Action, RestartPolicy,
                                           StragglerMonitor,
                                           run_with_restarts)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    st = _state()
    mgr.save(7, st, mesh_shape=(16, 16))
    assert mgr.all_steps() == [7]
    target = jax.tree.map(lambda x: jnp.zeros_like(x), st)
    back = mgr.restore(7, target)
    assert np.allclose(np.asarray(back["params"]["w"]),
                       np.asarray(st["params"]["w"]))
    assert int(back["step"]) == 7


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _state(s))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_restore_rejects_structure_change(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state())
    bad_target = {"params": {"w": jnp.zeros((4, 4))}, "step": jnp.int32(0)}
    with pytest.raises(ValueError):
        mgr.restore(1, bad_target)


def test_bf16_roundtrip(tmp_path):
    """npz can't hold ml_dtypes natively — the uint16-view path must
    restore bf16 bit-exactly (regression: train_lm restore crashed)."""
    mgr = CheckpointManager(tmp_path)
    st = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 16)
                                 ).astype(jnp.bfloat16),
          "v": jnp.ones((4,), jnp.float32)}
    mgr.save(3, st)
    back = mgr.restore(3, jax.tree.map(jnp.zeros_like, st))
    assert back["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(back["w"], np.float32),
                          np.asarray(st["w"], np.float32))


def test_atomic_tmpdir_never_latest(tmp_path):
    mgr = CheckpointManager(tmp_path)
    # a stale .tmp dir (crashed writer) must be invisible to all_steps
    (tmp_path / "step_00000099.tmp").mkdir()
    mgr.save(1, _state())
    assert mgr.all_steps() == [1]


# ------------------------------------------------------- fault tolerance
def test_straggler_detection_flags_slow_host():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=2)
    act = Action.CONTINUE
    for _ in range(4):
        act, slow = mon.record_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0})
    assert act in (Action.REBALANCE, Action.EVICT_RESTART)
    assert slow == [3]


def test_straggler_eviction_escalation():
    mon = StragglerMonitor(n_hosts=2, threshold=1.5, patience=2,
                           evict_after=4)
    act = Action.CONTINUE
    for _ in range(10):
        act, slow = mon.record_step({0: 1.0, 1: 10.0})
        if act is Action.EVICT_RESTART:
            break
    assert act is Action.EVICT_RESTART


def test_dead_host_heartbeats():
    mon = StragglerMonitor(n_hosts=2, max_missed=3)
    acts = [mon.heartbeat_missed(1) for _ in range(3)]
    assert acts[-1] is Action.EVICT_RESTART


def test_restart_policy_backoff_bounds():
    pol = RestartPolicy(max_restarts=3, backoff_s=1.0, backoff_mult=2.0)
    delays = [pol.next_delay() for _ in range(4)]
    assert delays[:3] == [1.0, 2.0, 4.0]
    assert delays[3] is None


def test_run_with_restarts_recovers():
    calls = {"n": 0}

    def train_fn(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated pod failure")
        return state + calls["n"]

    out = run_with_restarts(train_fn, restore_fn=lambda: 100,
                            policy=RestartPolicy(backoff_s=0.0),
                            sleep=lambda *_: None)
    assert out == 103
    assert calls["n"] == 3
