"""QAP objective + delta gains: sparse vs dense oracle, gain matrix."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (Hierarchy, qap_objective, qap_objective_dense,
                        random_geometric, swap_gain)
from repro.core.objective import (apply_swap, batched_swap_gains,
                                  dense_gain_matrix)

H = Hierarchy((4, 2, 2), (1.0, 10.0, 100.0))


def _graph(seed):
    return random_geometric(16, 0.45, seed=seed)


@given(st.integers(0, 50), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_sparse_equals_dense(gseed, pseed):
    g = _graph(gseed)
    perm = np.random.default_rng(pseed).permutation(16)
    j1 = qap_objective(g, H, perm)
    j2 = qap_objective_dense(g.to_dense(), H.distance_matrix(), perm)
    assert np.isclose(j1, j2)


@given(st.integers(0, 50), st.integers(0, 1000),
       st.integers(0, 15), st.integers(0, 15))
@settings(max_examples=60, deadline=None)
def test_gain_equals_recompute(gseed, pseed, u, v):
    """The paper's O(deg) delta gain must equal J(before) − J(after)."""
    if u == v:
        return
    g = _graph(gseed)
    perm = np.random.default_rng(pseed).permutation(16)
    j0 = qap_objective(g, H, perm)
    gain = swap_gain(g, H, perm, u, v)
    p2 = perm.copy()
    apply_swap(p2, u, v)
    assert np.isclose(gain, j0 - qap_objective(g, H, p2), atol=1e-9)


def test_batched_gains_match_single(rng):
    g = _graph(7)
    perm = rng.permutation(16)
    pairs = np.array([(u, v) for u in range(16) for v in range(u + 1, 16)])
    bg = batched_swap_gains(g, H, perm, pairs)
    for (u, v), e in zip(pairs, bg):
        assert np.isclose(e, swap_gain(g, H, perm, u, v))


def test_dense_gain_matrix_matches(rng):
    g = _graph(11)
    C = g.to_dense()
    D = H.distance_matrix()
    perm = rng.permutation(16)
    G = dense_gain_matrix(C, D, perm)
    assert np.allclose(np.diag(G), 0)
    for u in range(0, 16, 3):
        for v in range(u + 1, 16, 2):
            assert np.isclose(G[u, v], swap_gain(g, H, perm, u, v))
    # symmetry: gain(u,v) == gain(v,u)
    assert np.allclose(G, G.T)
