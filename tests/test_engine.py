"""Device-resident refinement engine: DeviceGraph/ELL invariants, sparse
pair-gain kernel parity (jnp and Pallas-interpret), sweep-loop
monotonicity + local-optimum parity with `parallel_sweep_search` on every
distance form, vmapped map_many batching, spec/CLI plumbing, and the
frontier-BFS / seed satellites."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (Hierarchy, Mapper, MappingSpec, grid3d,
                        qap_objective, random_geometric, swap_gain)
from repro.core.construction import construct
from repro.core.graph import DeviceGraph, device_pairs
from repro.core.local_search import (NEIGHBORHOODS, _bfs_pairs,
                                     communication_pairs,
                                     parallel_sweep_search)
from repro.core.objective import batched_swap_gains
from repro.engine import RefinementEngine, refine
from repro.topology import MatrixTopology, TorusTopology, TreeTopology

H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


def _machines():
    torus = TorusTopology((8, 8))
    return {
        "tree": TreeTopology(hierarchy=H64),
        "torus": torus,
        "matrix": MatrixTopology(matrix=torus.distance_matrix()),
    }


MACHINES = _machines()


def _gains_host(g, topo, perm, pairs):
    return batched_swap_gains(g, topo, perm, pairs)


# ------------------------------------------------------------- DeviceGraph
def test_device_graph_is_faithful_ell_view():
    g = random_geometric(48, 0.3, seed=1)
    dg = DeviceGraph.from_comm(g)
    nbr, wgt = np.asarray(dg.nbr), np.asarray(dg.wgt)
    assert dg.n == g.n and dg.num_edges == g.num_edges
    for u in range(g.n):
        live = wgt[u] != 0.0
        got = sorted(zip(nbr[u][live].tolist(), wgt[u][live].tolist()))
        want = sorted(zip(g.neighbors(u).tolist(), g.weights(u).tolist()))
        assert got == pytest.approx(want)
        # padding slots carry the row id and zero weight (inert for any D)
        assert np.all(nbr[u][~live] == u)
    u, v, w = g.edge_list()
    e = len(u)
    assert np.array_equal(np.asarray(dg.eu)[:e], u)
    assert np.array_equal(np.asarray(dg.ev)[:e], v)
    assert np.all(np.asarray(dg.ew)[e:] == 0.0)


def test_device_graph_pad_to_is_inert():
    g = grid3d(4, 4, 2)
    perm = np.arange(g.n, dtype=np.int64)
    pairs = communication_pairs(g, 2)
    from repro.kernels.pair_gain import edge_objective, pair_gains
    import jax.numpy as jnp
    kind, dims, weights = ("torus", (4, 8), (1.0, 1.0))
    D = jnp.zeros((1, 1), jnp.float32)
    p = jnp.asarray(perm, jnp.int32)
    us, vs = device_pairs(pairs)
    dg = DeviceGraph.from_comm(g)
    big = dg.pad_to(dg.max_deg + 16, dg.eu.shape[0] + 256)
    g1 = pair_gains(kind, (dims, weights), dg.nbr, dg.wgt, p, us, vs, D)
    g2 = pair_gains(kind, (dims, weights), big.nbr, big.wgt, p, us, vs, D)
    assert np.allclose(np.asarray(g1), np.asarray(g2))
    j1 = edge_objective(kind, (dims, weights), dg.eu, dg.ev, dg.ew, p, D)
    j2 = edge_objective(kind, (dims, weights), big.eu, big.ev, big.ew, p, D)
    assert float(j1) == pytest.approx(float(j2))


# ------------------------------------------------------------- gain kernels
@pytest.mark.parametrize("name", sorted(MACHINES))
def test_pair_gains_match_host_sparse_gains(name):
    import jax.numpy as jnp
    from repro.kernels.pair_gain import pair_gains
    topo = MACHINES[name]
    g = random_geometric(64, 0.25, seed=3)
    perm = construct("random", g, topo, seed=2)
    pairs = communication_pairs(g, 2)
    want = _gains_host(g, topo, perm, pairs)
    kp = topo.kernel_params()
    kind, params = kp[0], kp[1:] if kp[0] != "matrix" else ()
    D = jnp.asarray(topo.matrix(), jnp.float32) if kind == "matrix" else \
        jnp.zeros((1, 1), jnp.float32)
    dg = DeviceGraph.from_comm(g)
    us, vs = device_pairs(pairs)
    got = np.asarray(pair_gains(kind, params, dg.nbr, dg.wgt,
                                jnp.asarray(perm, jnp.int32), us, vs, D))
    assert got[:len(pairs)] == pytest.approx(want, rel=1e-5, abs=1e-4)
    assert np.all(got[len(pairs):] == 0.0)      # u == v padding is inert


@pytest.mark.parametrize("name", sorted(MACHINES))
def test_pallas_pair_gains_match_jnp(name):
    import jax.numpy as jnp
    from repro.kernels.pair_gain import pair_gains, pair_gains_pallas
    topo = MACHINES[name]
    g = grid3d(4, 4, 4)
    perm = construct("random", g, topo, seed=5)
    pairs = communication_pairs(g, 2)
    kp = topo.kernel_params()
    kind, params = kp[0], kp[1:] if kp[0] != "matrix" else ()
    D = jnp.asarray(topo.matrix(), jnp.float32) if kind == "matrix" else \
        jnp.zeros((1, 1), jnp.float32)
    dg = DeviceGraph.from_comm(g)
    us, vs = device_pairs(pairs)
    p = jnp.asarray(perm, jnp.int32)
    ref = np.asarray(pair_gains(kind, params, dg.nbr, dg.wgt, p, us, vs, D))
    got = np.asarray(pair_gains_pallas(kind, params, dg.nbr, dg.wgt, p,
                                       us, vs, D, interpret=True))
    assert got == pytest.approx(ref, rel=1e-5, abs=1e-4)


def test_edge_objective_matches_host():
    import jax.numpy as jnp
    from repro.kernels.pair_gain import edge_objective
    for name, topo in MACHINES.items():
        g = random_geometric(64, 0.25, seed=7)
        perm = construct("random", g, topo, seed=1)
        kp = topo.kernel_params()
        kind, params = kp[0], kp[1:] if kp[0] != "matrix" else ()
        D = jnp.asarray(topo.matrix(), jnp.float32) if kind == "matrix" \
            else jnp.zeros((1, 1), jnp.float32)
        dg = DeviceGraph.from_comm(g)
        got = float(edge_objective(kind, params, dg.eu, dg.ev, dg.ew,
                                   jnp.asarray(perm, jnp.int32), D))
        assert got == pytest.approx(qap_objective(g, topo, perm), rel=1e-5)


# -------------------------------------------------------------- sweep loop
def _tol(j0):
    return 1e-5 * max(1.0, abs(j0))


@pytest.mark.parametrize("name", sorted(MACHINES))
def test_engine_monotone_and_reaches_local_optimum(name):
    topo = MACHINES[name]
    g = random_geometric(64, 0.25, seed=11)
    perm = construct("random", g, topo, seed=4)
    j0 = qap_objective(g, topo, perm)
    pairs = communication_pairs(g, 2)
    res = refine(topo, g, perm, pairs, max_sweeps=64)
    st = res.stats
    tr = np.asarray(st.objective_trace)
    assert np.all(np.diff(tr) <= _tol(j0))          # device trace monotone
    assert st.final_objective <= j0 + _tol(j0)      # host f64 endpoints too
    assert st.final_objective == pytest.approx(
        qap_objective(g, topo, perm), rel=1e-9)     # reported = recomputed
    assert sorted(perm.tolist()) == list(range(g.n))
    # converged before the budget → no candidate pair has positive gain
    # beyond the engine's acceptance threshold (= a local optimum of the
    # exact same neighborhood the host drivers search)
    assert res.sweeps < 64
    eps = 2e-4 * max(1.0, abs(j0))
    gains = np.array([swap_gain(g, topo, perm, int(u), int(v))
                      for u, v in pairs])
    assert gains.max(initial=0.0) <= eps


@pytest.mark.parametrize("name", sorted(MACHINES))
def test_engine_parity_with_host_parallel_sweep(name):
    """The host batched sweep is the semantic reference: both drivers are
    monotone and both terminate in a local optimum of the same candidate
    set; the engine never ends above the host-greedy starting point."""
    topo = MACHINES[name]
    g = grid3d(4, 4, 4)
    pairs = communication_pairs(g, 2)
    p_dev = construct("random", g, topo, seed=9)
    p_host = p_dev.copy()
    j0 = qap_objective(g, topo, p_dev)
    dev = refine(topo, g, p_dev, pairs, max_sweeps=64).stats
    host = parallel_sweep_search(g, topo, p_host, pairs)
    assert dev.final_objective <= j0 + _tol(j0)
    assert host.final_objective <= j0 + 1e-9
    for stats, perm in ((dev, p_dev), (host, p_host)):
        tr = np.asarray(stats.objective_trace)
        assert np.all(np.diff(tr) <= _tol(j0))
        eps = 2e-4 * max(1.0, abs(j0))
        gains = np.array([swap_gain(g, topo, perm, int(u), int(v))
                          for u, v in pairs])
        assert gains.max(initial=0.0) <= eps


def test_engine_empty_pairs_is_noop():
    g = grid3d(4, 4, 4)
    perm = construct("identity", g, H64, seed=0)
    res = refine(H64, g, perm, np.zeros((0, 2), dtype=np.int64))
    assert res.stats.swaps == 0
    assert res.stats.final_objective == res.stats.initial_objective


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       machine=st.sampled_from(sorted(MACHINES)))
def test_engine_never_increases_objective_property(seed, machine):
    topo = MACHINES[machine]
    g = random_geometric(64, 0.22, seed=seed % 17)
    perm = construct("random", g, topo, seed=seed)
    j0 = qap_objective(g, topo, perm)
    res = refine(topo, g, perm, communication_pairs(g, 2), max_sweeps=16)
    tr = np.asarray(res.stats.objective_trace)
    assert np.all(np.diff(tr) <= _tol(j0))
    assert res.stats.final_objective <= j0 + _tol(j0)
    assert sorted(perm.tolist()) == list(range(g.n))


# --------------------------------------------------------------- sessions
def test_mapper_device_engine_improves_and_caches():
    spec = MappingSpec(construction="random", neighborhood="communication",
                       neighborhood_dist=2, preconfiguration="fast",
                       engine="device", seed=1)
    mapper = Mapper(H64, spec)
    g = grid3d(4, 4, 4)
    r1 = mapper.map(g)
    r2 = mapper.map(g)
    assert r1.final_objective <= r1.initial_objective
    assert np.array_equal(r1.perm, r2.perm)          # deterministic
    info = mapper.cache_info()
    assert info["engine_builds"] == 1                # one engine, reused
    assert info["pair_cache_hits"] == 1


def test_map_many_vmapped_batch_matches_single_maps():
    spec = MappingSpec(construction="random", neighborhood="communication",
                       neighborhood_dist=2, preconfiguration="fast",
                       engine="device", seed=3)
    graphs = []
    for i in range(4):
        g = grid3d(4, 4, 4)
        g.adjwgt = g.adjwgt * (1.0 + 0.5 * i)
        graphs.append(g)
    mapper = Mapper(H64, spec)
    batch = mapper.map_many(graphs)
    singles = [Mapper(H64, spec).map(g) for g in graphs]
    for got, want in zip(batch, singles):
        assert got.final_objective == pytest.approx(want.final_objective,
                                                    rel=1e-5)
        assert got.final_objective <= got.initial_objective
        assert sorted(got.perm.tolist()) == list(range(64))


def test_map_many_device_batches_structurally_different_graphs():
    spec = MappingSpec(construction="random", neighborhood="communication",
                       neighborhood_dist=2, preconfiguration="fast",
                       engine="device", seed=0)
    graphs = [grid3d(4, 4, 4), random_geometric(64, 0.25, seed=2)]
    mapper = Mapper(H64, spec)
    for got, g in zip(mapper.map_many(graphs), graphs):
        want = Mapper(H64, spec).map(g)
        assert got.final_objective == pytest.approx(want.final_objective,
                                                    rel=1e-5)


def test_engine_spec_round_trip_and_flags():
    import argparse
    spec = MappingSpec(engine="device")
    assert MappingSpec.from_dict(spec.to_dict()).engine == "device"
    ns = argparse.Namespace(engine="device")
    assert MappingSpec.from_flags(ns).engine == "device"
    with pytest.raises(ValueError, match="engine"):
        MappingSpec(engine="gpu").validate()


def test_pallas_engine_path_matches_jnp_engine():
    topo = MACHINES["torus"]
    g = grid3d(4, 4, 4)
    pairs = communication_pairs(g, 2)
    p_ref = construct("random", g, topo, seed=6)
    p_pl = p_ref.copy()
    ref = RefinementEngine(topo, max_sweeps=8).refine(g, p_ref, pairs)
    pl_ = RefinementEngine(topo, max_sweeps=8, use_pallas=True,
                           interpret=True).refine(g, p_pl, pairs)
    assert np.array_equal(p_ref, p_pl)
    assert pl_.final_objective == pytest.approx(ref.final_objective)


# -------------------------------------------------- satellites: BFS + seed
def _bfs_reference(g, depth):
    """The original per-vertex Python BFS (pair-set oracle)."""
    out = set()
    for s in range(g.n):
        seen = {s}
        frontier = [s]
        for _ in range(depth):
            nxt = []
            for u in frontier:
                for v in g.neighbors(u):
                    v = int(v)
                    if v not in seen:
                        seen.add(v)
                        nxt.append(v)
            out.update((s, x) for x in nxt if x > s)
            frontier = nxt
            if not frontier:
                break
    return out


@pytest.mark.parametrize("seed,depth", [(0, 2), (1, 3), (2, 4), (3, 6)])
def test_frontier_bfs_pair_set_matches_reference(seed, depth):
    g = random_geometric(40, 0.25, seed=seed)
    got = _bfs_pairs(g, depth, max_pairs=2_000_000)
    want = _bfs_reference(g, depth)
    assert {tuple(p) for p in got} == want
    # deterministic lexicographic order
    assert np.array_equal(got, got[np.lexsort((got[:, 1], got[:, 0]))])


def test_frontier_bfs_chunked_expansion_is_equivalent(monkeypatch):
    import repro.core.local_search as ls
    g = random_geometric(40, 0.3, seed=5)
    want = ls._bfs_pairs(g, 3, 2_000_000)
    monkeypatch.setattr(ls, "_BFS_CHUNK", 7)    # force many tiny slices
    got = ls._bfs_pairs(g, 3, 2_000_000)
    assert np.array_equal(got, want)
    assert ls._bfs_pairs(g, 3, len(want) - 1) is None   # cap still fires


def test_frontier_bfs_respects_cap_like_reference():
    g = grid3d(4, 4, 4)
    full = communication_pairs(g, 4, max_pairs=2_000_000)
    capped = communication_pairs(g, 4, max_pairs=len(full) - 1)
    shallower = communication_pairs(g, 3, max_pairs=2_000_000)
    assert {tuple(p) for p in capped} == {tuple(p) for p in shallower}


def test_communication_generator_is_unseeded_and_cache_shared():
    nb = NEIGHBORHOODS["communication"]
    assert not nb.seeded
    g = grid3d(4, 4, 4)
    a = nb.generate(g, dist=3, seed=0, max_pairs=2_000_000)
    b = nb.generate(g, dist=3, seed=999, max_pairs=2_000_000)
    assert np.array_equal(a, b)
    # Mapper: same graph, different seeds → one cached pair set
    mapper = Mapper(H64, MappingSpec(neighborhood="communication",
                                     neighborhood_dist=2,
                                     preconfiguration="fast", seed=0))
    mapper.map(g)
    mapper.map(g, spec=mapper.spec.replace(seed=42))
    assert mapper.cache_info()["pair_cache_hits"] == 1


def test_seeded_generator_still_receives_seed():
    from repro.core.local_search import register_neighborhood
    calls = []

    # no explicit seeded=: the `seed` parameter in the signature is
    # auto-detected, so advertising a seed and not receiving it is
    # impossible by construction
    @register_neighborhood("_test_seeded")
    def _seeded(g, *, dist=1, seed=0, max_pairs=0):
        calls.append(seed)
        rng = np.random.default_rng(seed)
        u = rng.integers(0, g.n - 1, size=4)
        return np.stack([u, u + 1], axis=1).astype(np.int64)

    try:
        assert NEIGHBORHOODS["_test_seeded"].seeded
        g = grid3d(4, 4, 4)
        mapper = Mapper(H64, MappingSpec(neighborhood="_test_seeded",
                                         preconfiguration="fast", seed=7))
        mapper.map(g)
        mapper.map(g, spec=mapper.spec.replace(seed=8))
        assert calls == [7, 8]                      # seed forwarded, no
        assert mapper.cache_info()["pair_cache_hits"] == 0   # stale cache
    finally:
        del NEIGHBORHOODS["_test_seeded"]
