"""End-to-end paper integration: compiled program → comm graph → VieM
mapping → objective improvement; CLIs; device-order plumbing."""

import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Mapper, MappingSpec, grid3d, qap_objective,
                        tpu_v5e_fleet, write_metis)
from repro.core.comm_model import (device_comm_graph, generate_model,
                                   logical_traffic_summary)

REPO = Path(__file__).resolve().parents[1]


def test_generate_model_matches_guide_semantics():
    g = grid3d(4, 4, 4)
    model, labels = generate_model(g, 8, preconfiguration="fast")
    assert model.n == 8
    # model edge weights equal summed cut edges between the blocks
    u, v, w = g.edge_list()
    expected = {}
    for a, b, ww in zip(labels[u], labels[v], w):
        if a != b:
            key = (min(a, b), max(a, b))
            expected[key] = expected.get(key, 0) + ww
    mu, mv, mw = model.edge_list()
    got = {(min(a, b), max(a, b)): ww for a, b, ww in zip(mu, mv, mw)}
    assert got == pytest.approx(expected)


def test_device_comm_graph_from_hlo():
    hlo = """
HloModule m
ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%x), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%s
}
%s (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""
    g = device_comm_graph(hlo, 8)
    assert g.n == 8
    # ring over {0,1,2,3}: edges (0,1),(1,2),(2,3),(3,0)
    u, v, w = g.edge_list()
    assert set(zip(u.tolist(), v.tolist())) == {(0, 1), (1, 2), (2, 3),
                                                (0, 3)}
    assert np.allclose(w, 2 * 3 / 4 * 256)


def test_mapping_improves_mesh_traffic():
    """The paper's core claim on the framework's own workload: VieM
    placement beats identity and random on a synthetic multi-ring comm
    graph shaped like SPMD collectives."""
    from repro.core import from_edges
    n = 256
    h = tpu_v5e_fleet(pods=1)
    us, vs, ws = [], [], []
    # 16 TP rings of size 16 with heavy traffic, strided layout (worst
    # case for identity), plus a DP ring with light traffic
    for r in range(16):
        members = [r + 16 * i for i in range(16)]
        for i in range(16):
            us.append(members[i])
            vs.append(members[(i + 1) % 16])
            ws.append(1000.0)
    for i in range(n):
        us.append(i)
        vs.append((i + 1) % n)
        ws.append(1.0)
    g = from_edges(n, np.array(us), np.array(vs), np.array(ws))
    j_ident = qap_objective(g, h, np.arange(n))
    res = Mapper(h, MappingSpec(preconfiguration="fast",
                                neighborhood_dist=2, seed=0)).map(g)
    assert res.final_objective < 0.6 * j_ident
    tr = logical_traffic_summary(g, h, res.perm)
    tr_id = logical_traffic_summary(g, h, np.arange(n))
    # mapping moves traffic down the hierarchy (more level-1, less level-3)
    assert tr["level_3_bytes"] < tr_id["level_3_bytes"]


def _run_cli(mod, *args):
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        cwd=REPO, env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
                       "JAX_PLATFORMS": "cpu"})


def test_cli_roundtrip(tmp_path):
    g = grid3d(4, 4, 2)
    gpath = tmp_path / "g.metis"
    write_metis(g, str(gpath))

    r = _run_cli("repro.cli.graphchecker", str(gpath))
    assert r.returncode == 0 and "seems correct" in r.stdout

    perm_path = tmp_path / "perm.txt"
    r = _run_cli("repro.cli.viem", str(gpath),
                 "--hierarchy_parameter_string=4:4:2",
                 "--distance_parameter_string=1:10:100",
                 "--preconfiguration_mapping=fast",
                 f"--output_filename={perm_path}")
    assert r.returncode == 0, r.stderr
    assert "final objective" in r.stdout
    perm = np.loadtxt(perm_path, dtype=int)
    assert sorted(perm.tolist()) == list(range(32))

    r = _run_cli("repro.cli.evaluator", str(gpath),
                 f"--input_mapping={perm_path}",
                 "--hierarchy_parameter_string=4:4:2",
                 "--distance_parameter_string=1:10:100")
    assert r.returncode == 0 and "objective" in r.stdout

    model_path = tmp_path / "model.graph"
    r = _run_cli("repro.cli.generate_model", str(gpath), "--k=4",
                 "--preconfiguration=fast",
                 f"--output_filename={model_path}")
    assert r.returncode == 0, r.stderr
    r = _run_cli("repro.cli.graphchecker", str(model_path))
    assert r.returncode == 0


def test_cli_graphchecker_rejects_bad(tmp_path):
    bad = tmp_path / "bad.metis"
    bad.write_text("2 1\n2\n\n")   # missing backward edge line content
    r = _run_cli("repro.cli.graphchecker", str(bad))
    assert r.returncode == 1
