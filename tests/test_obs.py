"""Observability layer: tracer spans, engine telemetry invariants
(bit-identity, no-retrace toggle, padding inertness, lane parity,
exchange/objective consistency), metrics registry atomicity, Chrome
trace export, the MappingService stats compat view, viem --profile, and
the benchmark provenance stamp."""

import json
import threading

import numpy as np
import pytest

from repro.core import (Hierarchy, Mapper, MappingSpec, MultilevelSpec,
                        ShapeBucket, grid3d, random_geometric)
from repro.core.spec import PortfolioSpec
from repro.engine import RefinementEngine
from repro.obs import (EngineTelemetry, MetricsRegistry, Span, Tracer,
                       chrome_trace_events, get_tracer, span_breakdown,
                       write_chrome_trace, write_jsonl)
from repro.topology import TreeTopology

H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))
TOPO = TreeTopology(hierarchy=H64)


def _dev_spec(**kw):
    base = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="fast",
                engine="device", seed=1)
    base.update(kw)
    return MappingSpec(**base)


def _workload(seed=3):
    return random_geometric(64, 0.3, seed=seed)


def _refine_inputs(seed=3, n_pairs=None):
    from repro.core.local_search import communication_pairs
    from repro.core.objective import qap_objective
    g = _workload(seed)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n).astype(np.int64)
    pairs = communication_pairs(g, dist=2)
    j0 = qap_objective(g, H64, perm)
    return g, perm, pairs, j0


# ------------------------------------------------------------------ tracer
def test_tracer_records_nested_spans_with_depth():
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="t") as outer:
        with tr.span("inner") as inner:
            pass
    assert [sp.name for sp in tr.spans()] == ["inner", "outer"]
    assert outer.depth == 0 and inner.depth == 1
    assert outer.dur >= inner.dur >= 0.0
    assert outer.t0 <= inner.t0


def test_tracer_disabled_measures_but_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("quiet") as sp:
        pass
    assert sp.dur >= 0.0            # callers still read dur for timing
    assert len(tr) == 0


def test_tracer_ring_buffer_bounds_and_dropped():
    tr = Tracer(enabled=True, capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [sp.name for sp in tr.spans()] == ["s6", "s7", "s8", "s9"]


def test_tracer_drain_and_wrap():
    tr = Tracer(enabled=True)

    @tr.wrap("work", cat="fn")
    def work(x):
        return x + 1

    assert work(1) == 2
    spans = tr.drain()
    assert [sp.name for sp in spans] == ["work"]
    assert len(tr) == 0


def test_get_tracer_is_a_stable_singleton():
    assert get_tracer() is get_tracer()


# --------------------------------------------------------------- telemetry
def test_engine_telemetry_from_device_trims_to_passes():
    tel = EngineTelemetry.from_device(
        {"passes": np.int32(2), "sweeps": np.int32(2),
         "exchanges": np.array([3, 1, 0, 0], np.int32),
         "tabu_masked": np.zeros(4, np.int32),
         "aspirations": np.zeros(4, np.int32),
         "match_rounds": np.array([2, 1, 0, 0], np.int32),
         "downhill_escapes": np.int32(0)},
        objective_trace=np.array([9.0, 5.0, 5.0]))
    assert tel.passes == 2 and tel.sweeps == 2
    assert tel.exchanges.tolist() == [3, 1]
    assert tel.total_exchanges == 4
    assert len(tel.objective_trace) == 3
    s = tel.summary()
    assert s["exchanges"] == 4 and s["merged_from"] == 1


def test_engine_telemetry_merge_sums_and_envelopes():
    a = EngineTelemetry(passes=2, sweeps=2,
                        exchanges=np.array([3, 1]),
                        tabu_masked=np.array([0, 0]),
                        aspirations=np.array([1, 0]),
                        match_rounds=np.array([2, 1]),
                        downhill_escapes=1,
                        objective_trace=np.array([9.0, 5.0, 4.0]))
    b = EngineTelemetry(passes=1, sweeps=1,
                        exchanges=np.array([2]),
                        tabu_masked=np.array([4]),
                        aspirations=np.array([0]),
                        match_rounds=np.array([1]),
                        downhill_escapes=0,
                        objective_trace=np.array([8.0, 3.0]))
    m = EngineTelemetry.merge([a, b])
    assert m.merged_from == 2
    assert m.passes == 2 and m.sweeps == 2
    assert m.exchanges.tolist() == [5, 1]        # zero-padded sum
    assert m.tabu_masked.tolist() == [4, 0]
    assert m.total_exchanges == 6
    assert m.downhill_escapes == 1
    # objective envelope: elementwise min over the extended traces
    assert m.objective_trace.tolist() == [8.0, 3.0, 3.0]


# --------------------------------------------- engine telemetry invariants
def test_telemetry_off_and_on_are_bit_identical_and_no_retrace():
    g, perm, pairs, j0 = _refine_inputs()
    eng = RefinementEngine(TOPO, max_sweeps=32)
    p_off, p_on = perm.copy(), perm.copy()
    st_off = eng.refine(g, p_off, pairs, j0=j0)
    st_on = eng.refine(g, p_on, pairs, j0=j0, telemetry=True)
    assert np.array_equal(p_off, p_on)       # refined in place
    assert st_off.final_objective == st_on.final_objective
    assert st_off.telemetry is None
    assert st_on.telemetry is not None
    assert eng.trace_count() == 1      # the toggle never retraces
    # tabu toggles still share the executable too
    eng.refine(g, perm.copy(), pairs, j0=j0, tabu_tenure=4, dlb=True,
               telemetry=True)
    assert eng.trace_count() == 1


def test_telemetry_exchanges_sum_matches_swaps_and_trace():
    g, perm, pairs, j0 = _refine_inputs()
    eng = RefinementEngine(TOPO, max_sweeps=32)
    st = eng.refine(g, perm.copy(), pairs, j0=j0, telemetry=True)
    tel = st.telemetry
    assert st.swaps > 0
    assert int(tel.exchanges.sum()) == st.swaps
    assert tel.sweeps == len(st.objective_trace) - 1
    # without tabu the sweep is monotone: every pass with exchanges
    # must not increase the objective
    trace = np.asarray(st.objective_trace, dtype=float)
    assert np.all(np.diff(trace) <= 1e-6)
    assert tel.tabu_masked_total == 0 and tel.aspiration_fires == 0


def test_telemetry_tabu_counters_populate():
    g, perm, pairs, j0 = _refine_inputs()
    eng = RefinementEngine(TOPO, max_sweeps=48)
    st = eng.refine(g, perm.copy(), pairs, j0=j0, tabu_tenure=6,
                    dlb=True, telemetry=True)
    tel = st.telemetry
    assert tel.tabu_masked_total > 0
    assert tel.passes == len(tel.exchanges)
    assert 0.0 <= tel.aspiration_rate


def test_telemetry_is_padding_inert():
    g, perm, pairs, j0 = _refine_inputs()
    eng = RefinementEngine(TOPO, max_sweeps=32)
    tight = ShapeBucket.of(g)
    big = ShapeBucket(max_deg=tight.max_deg + 7,
                      num_edges=tight.num_edges + 33,
                      num_pairs=(tight.num_pairs or len(pairs)) + 11)
    p_t, p_b = perm.copy(), perm.copy()
    st_t = eng.refine(g, p_t, pairs, j0=j0, bucket=tight,
                      telemetry=True)
    st_b = eng.refine(g, p_b, pairs, j0=j0, bucket=big,
                      telemetry=True)
    assert np.array_equal(p_t, p_b)
    for f in ("exchanges", "tabu_masked", "aspirations", "match_rounds"):
        assert np.array_equal(getattr(st_t.telemetry, f),
                              getattr(st_b.telemetry, f)), f
    assert st_t.telemetry.downhill_escapes == \
        st_b.telemetry.downhill_escapes


def test_lane_telemetry_equals_single_refines():
    g, _, pairs, _ = _refine_inputs()
    from repro.core.objective import qap_objective
    rng = np.random.default_rng(0)
    perms = [rng.permutation(g.n).astype(np.int64) for _ in range(3)]
    j0s = [qap_objective(g, H64, p) for p in perms]
    eng = RefinementEngine(TOPO, max_sweeps=32)
    lane_perms = [p.copy() for p in perms]
    lane_stats = eng.refine_lanes(g, lane_perms, pairs, j0s=j0s,
                                  tabu_tenure=4, dlb=True,
                                  telemetry=True)
    for p, lp, j0, ls in zip(perms, lane_perms, j0s, lane_stats):
        sp = p.copy()
        single = eng.refine(g, sp, pairs, j0=j0, tabu_tenure=4,
                            dlb=True, telemetry=True)
        assert np.array_equal(lp, sp)
        for f in ("exchanges", "tabu_masked", "aspirations"):
            assert np.array_equal(getattr(ls.telemetry, f),
                                  getattr(single.telemetry, f)), f


@pytest.mark.parametrize("spec", [
    _dev_spec(),
    _dev_spec(multilevel=MultilevelSpec(levels=3, coarsen_min=8)),
    _dev_spec(portfolio=PortfolioSpec(lanes=2, rounds=2,
                                      tabu_tenure=4)),
], ids=["flat", "multilevel", "portfolio"])
def test_mapper_telemetry_toggle_is_bit_identical(spec):
    mapper = Mapper(H64, spec)
    g = _workload()
    r_off = mapper.map(g)
    r_on = mapper.map(g, telemetry=True)
    assert np.array_equal(r_off.perm, r_on.perm)
    assert r_off.final_objective == r_on.final_objective
    assert r_on.search_stats.telemetry is not None
    assert r_off.search_stats.telemetry is None
    # MappingResult timing fields survive the tracer refactor
    assert r_on.construction_seconds >= 0.0
    assert r_on.search_seconds >= 0.0


def test_map_many_telemetry_matches_singles():
    mapper = Mapper(H64, _dev_spec())
    gs = [_workload(3), _workload(5)]
    batch = mapper.map_many(gs, telemetry=True)
    for g, r in zip(gs, batch):
        tel = r.search_stats.telemetry
        assert tel is not None
        assert int(tel.exchanges.sum()) == r.search_stats.swaps


# ----------------------------------------------------------------- metrics
def test_metrics_registry_snapshot_is_deep_and_reset_keeps_names():
    m = MetricsRegistry()
    m.counter("a").inc(3)
    m.gauge("g").set_max(7)
    m.histogram("h").observe(0.5)
    snap = m.snapshot()
    assert snap["a"] == 3 and snap["g"] == 7
    assert snap["h"]["count"] == 1
    snap["h"]["count"] = 999               # mutating a snapshot is inert
    assert m.snapshot()["h"]["count"] == 1
    m.reset()
    snap2 = m.snapshot()
    assert set(snap2) == {"a", "g", "h"}   # registrations survive
    assert snap2["a"] == 0 and snap2["h"]["count"] == 0


def test_metrics_registry_rejects_kind_mismatch():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        m.gauge("x")


def test_metrics_histogram_percentiles_use_recent_window():
    m = MetricsRegistry()
    h = m.histogram("lat", window=4)
    for v in (10.0, 1.0, 2.0, 3.0, 4.0):   # 10.0 falls out of the window
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["max"] == 10.0
    assert snap["p99"] == 4.0


def test_prometheus_exposition_round_trips():
    from repro.obs import parse_prometheus
    m = MetricsRegistry()
    m.counter("monitor.remaps.committed").inc(3)
    m.gauge("monitor.drift.score").set(0.125)
    h = m.histogram("monitor.remap_seconds")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    text = m.to_prometheus()
    assert "# TYPE viem_monitor_remaps_committed counter" in text
    assert "# TYPE viem_monitor_drift_score gauge" in text
    assert "# TYPE viem_monitor_remap_seconds summary" in text
    back = parse_prometheus(text)
    assert back["viem_monitor_remaps_committed"]["type"] == "counter"
    assert back["viem_monitor_remaps_committed"]["samples"][""] == 3
    assert back["viem_monitor_drift_score"]["samples"][""] == 0.125
    summ = back["viem_monitor_remap_seconds"]
    assert summ["type"] == "summary"
    assert summ["samples"]["count"] == 4
    assert summ["samples"]["sum"] == pytest.approx(1.0)
    assert summ["samples"]['quantile="0.5"'] == pytest.approx(
        m.histogram("monitor.remap_seconds").percentile(0.5))


def test_prometheus_empty_registry_and_snapshot_parity():
    from repro.obs import parse_prometheus
    m = MetricsRegistry()
    assert m.to_prometheus() == ""
    m.counter("a.b-c").inc()
    back = parse_prometheus(m.to_prometheus())
    assert back == {"viem_a_b_c": {"type": "counter", "samples": {"": 1.0}}}


def test_service_prometheus_exposes_served_counters():
    from repro.launch.serve import MappingService
    from repro.obs import parse_prometheus
    kw = {"max_wait_s": 0.002}
    with MappingService(Mapper(H64, _dev_spec()), **kw) as svc:
        svc.map(_workload(), timeout=300)
        text = svc.prometheus()
    back = parse_prometheus(text)
    assert back["viem_served"]["samples"][""] >= 1.0
    assert back["viem_served"]["type"] == "counter"
    assert back["viem_latency_s"]["type"] == "summary"


# ------------------------------------------------------------------ export
def test_chrome_trace_events_structure_and_counters(tmp_path):
    tr = Tracer(enabled=True)
    tel = EngineTelemetry(passes=2, sweeps=2,
                          exchanges=np.array([3, 1]),
                          tabu_masked=np.array([2, 0]),
                          aspirations=np.array([1, 0]),
                          match_rounds=np.array([2, 1]),
                          downhill_escapes=0,
                          objective_trace=np.array([9.0, 5.0, 4.0]))
    with tr.span("plan.execute"):
        with tr.span("plan.refine", telemetry=tel, retraces=0):
            pass
    payload = chrome_trace_events(tr.spans())
    events = payload["traceEvents"]
    complete = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in complete} == {"plan.execute",
                                             "plan.refine"}
    for e in complete:
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        json.dumps(e["args"])              # args must be JSON-safe
    counters = [e for e in events if e["ph"] == "C"]
    by_track = {}
    for e in counters:
        by_track.setdefault(e["name"], []).append(e["args"]["value"])
    assert by_track["engine/exchanges"] == [3, 1]
    assert by_track["engine/objective"] == [9.0, 5.0, 4.0]
    meta = [e for e in events if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)
    # file round-trip
    path = tmp_path / "t.trace.json"
    n = write_chrome_trace(tr.spans(), path)
    assert n == len(json.loads(path.read_text())["traceEvents"])


def test_write_jsonl_and_breakdown(tmp_path):
    tr = Tracer(enabled=True)
    for _ in range(3):
        with tr.span("a"):
            pass
    with tr.span("b", k=np.int32(7)):
        pass
    path = tmp_path / "spans.jsonl"
    assert write_jsonl(tr.spans(), path) == 4
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines[-1]["attrs"]["k"] == 7
    agg = span_breakdown(tr.spans())
    assert agg["a"]["count"] == 3
    assert agg["a"]["total_s"] >= agg["a"]["max_s"]
    assert agg["b"]["mean_s"] == agg["b"]["total_s"]


def test_plan_spans_and_describe_timings():
    tr = get_tracer()
    tr.enable()
    try:
        tr.clear()
        mapper = Mapper(H64, _dev_spec(
            multilevel=MultilevelSpec(levels=3, coarsen_min=8)))
        mapper.map(_workload())
        names = {sp.name for sp in tr.spans()}
        assert {"plan.lower", "plan.execute", "plan.vcycle",
                "vcycle.construct", "vcycle.refine"} <= names
        refines = [sp for sp in tr.spans()
                   if sp.name == "vcycle.refine"]
        assert {sp.attrs["level"] for sp in refines} == {0, 1, 2}
        assert all(sp.attrs["retraces"] >= 0 for sp in refines)
        plan = next(iter(mapper._plans.values()))
        t = plan.describe()["timings"]
        assert t["executes"] == 1
        assert t["lower_seconds"] > 0.0
        assert t["execute_seconds_total"] > 0.0
        assert all(c >= 1 for c in t["engine_traces"])
    finally:
        tr.disable()
        tr.clear()


# ----------------------------------------------------------------- service
def _service(mapper, **kw):
    from repro.launch.serve import MappingService
    kw.setdefault("max_wait_s", 0.002)
    return MappingService(mapper, **kw)


def test_service_stats_compat_keys_and_engine_aggregates():
    legacy = {"served", "batches", "batched_requests", "max_batch_seen",
              "result_cache_hits", "in_tick_deduped",
              "result_cache_size", "errors", "quality_served",
              "queue_depth", "peak_queue_depth", "latency_p50_s",
              "latency_p99_s"}
    with _service(Mapper(H64, _dev_spec()),
                  collect_telemetry=True) as svc:
        for s in (3, 5, 3):
            svc.map(_workload(s), timeout=300)
        stats = svc.stats()
    assert legacy <= set(stats)
    assert stats["served"] == 3
    assert stats["latency_count"] == 3
    assert stats["telemetry_requests"] >= 1
    assert stats["engine_sweeps_total"] > 0
    assert stats["engine_mean_sweeps_per_request"] > 0
    assert stats["quality_served"] == {"default": 3}


def test_service_reset_stats_zeroes_registry():
    with _service(Mapper(H64, _dev_spec())) as svc:
        svc.map(_workload(), timeout=300)
        assert svc.stats()["served"] == 1
        svc.reset_stats()
        stats = svc.stats()
    assert stats["served"] == 0
    assert stats["latency_count"] == 0
    assert stats["latency_p99_s"] == 0.0
    assert stats["quality_served"] == {"default": 0}


def test_service_stats_never_tear_under_burst():
    """A monitoring thread polling during a burst must always observe
    served == latency_count (they update under one registry lock)."""
    mapper = Mapper(H64, _dev_spec())
    torn = []
    stop = threading.Event()

    with _service(mapper) as svc:
        svc.map(_workload(), timeout=300)      # warm the plan first

        def monitor():
            while not stop.is_set():
                s = svc.stats()
                if s["served"] != s["latency_count"]:
                    torn.append((s["served"], s["latency_count"]))

        t = threading.Thread(target=monitor)
        t.start()
        try:
            tickets = [svc.submit(_workload(i % 4)) for i in range(24)]
            for _ in tickets:
                _, res = svc.results.get(timeout=300)
                assert not isinstance(res, Exception)
        finally:
            stop.set()
            t.join()
    assert torn == []


def test_service_without_telemetry_keeps_counters_quiet():
    with _service(Mapper(H64, _dev_spec())) as svc:
        svc.map(_workload(), timeout=300)
        stats = svc.stats()
    assert stats["telemetry_requests"] == 0
    assert stats["engine_exchanges_total"] == 0
    assert stats["engine_sweeps_total"] > 0   # from the objective trace


# --------------------------------------------------------------------- cli
def test_viem_profile_writes_loadable_trace(tmp_path, capsys):
    from repro.cli.viem import main as viem_main
    from repro.core import write_metis
    g = grid3d(4, 4, 4)
    gpath = tmp_path / "g.metis"
    write_metis(g, gpath)
    trace = tmp_path / "run.trace.json"
    tr = get_tracer()
    try:
        viem_main([str(gpath),
                   "--hierarchy_parameter_string=4:4:4",
                   "--distance_parameter_string=1:10:100",
                   "--engine=device",
                   f"--output_filename={tmp_path / 'perm'}",
                   f"--profile={trace}"])
    finally:
        tr.disable()
        tr.clear()
    out = capsys.readouterr().out
    assert "engine sweeps" in out
    payload = json.loads(trace.read_text())
    names = {e["name"] for e in payload["traceEvents"]
             if e.get("ph") == "X"}
    assert {"plan.lower", "plan.execute", "plan.refine"} <= names
    assert (tmp_path / "perm").exists()


# -------------------------------------------------------------- benchmarks
def test_bench_metadata_stamp(tmp_path):
    import sys
    sys.path.insert(0, "benchmarks")
    try:
        from _common import BENCH_SCHEMA_VERSION, write_bench
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_x.json"
    write_bench({"cells": [1, 2]}, str(out))
    payload = json.loads(out.read_text())
    assert payload["cells"] == [1, 2]
    meta = payload["meta"]
    assert meta["schema_version"] == BENCH_SCHEMA_VERSION
    assert meta["backend"] in ("cpu", "gpu", "tpu")
    assert meta["jax_version"]
    assert "git_sha" in meta and "timestamp" in meta
