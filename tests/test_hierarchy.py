"""Hierarchy + online distance oracle vs materialized matrix."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Hierarchy, supermuc_like, tpu_v5e_fleet


def test_parse_strings():
    h = Hierarchy.from_strings("4:2:2", "1:10:100")
    assert h.factors == (4, 2, 2) and h.n_pe == 16
    assert h.distances == (1.0, 10.0, 100.0)


def test_distance_basics():
    h = Hierarchy((4, 2, 2), (1.0, 10.0, 100.0))
    assert h.distance(0, 0) == 0
    assert h.distance(0, 3) == 1       # same processor
    assert h.distance(0, 4) == 10      # same node, diff processor
    assert h.distance(0, 8) == 100     # diff node
    assert h.distance(5, 4) == 1


@given(st.lists(st.integers(2, 4), min_size=1, max_size=4),
       st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_online_oracle_matches_matrix(factors, seed):
    dists = tuple(float(10 ** i) for i in range(len(factors)))
    h = Hierarchy(tuple(factors), dists)
    if h.n_pe > 256:
        return
    D = h.distance_matrix()
    assert np.allclose(D, D.T)
    assert np.all(np.diag(D) == 0)
    rng = np.random.default_rng(seed)
    p = rng.integers(0, h.n_pe, 32)
    q = rng.integers(0, h.n_pe, 32)
    assert np.allclose(h.distance(p, q), D[p, q])


def test_lca_levels():
    h = Hierarchy((4, 2, 2), (1.0, 10.0, 100.0))
    assert h.lca_level(0, 1) == 1
    assert h.lca_level(0, 4) == 2
    assert h.lca_level(0, 8) == 3
    assert h.lca_level(3, 3) == 0


def test_presets():
    assert tpu_v5e_fleet(2).n_pe == 512
    assert tpu_v5e_fleet(1).n_pe == 256
    assert supermuc_like().n_pe == 16 * 32 * 18


def test_monotone_distances_required():
    with pytest.raises(ValueError):
        Hierarchy((2, 2), (10.0, 1.0))
