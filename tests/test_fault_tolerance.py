"""StragglerMonitor decision logic: threshold/patience/hysteresis edges,
escalation, and the consumer interface (callback + queue) the closed
remapping loop subscribes to."""

import pytest

from repro.runtime.fault_tolerance import (Action, RestartPolicy,
                                           StragglerMonitor,
                                           run_with_restarts)


def steps(n_hosts, slow=(), factor=3.0, base=1.0):
    return {h: base * (factor if h in slow else 1.0)
            for h in range(n_hosts)}


def test_healthy_fleet_continues():
    mon = StragglerMonitor(n_hosts=4)
    for _ in range(20):
        action, hosts = mon.record_step(steps(4))
        assert action == Action.CONTINUE and hosts == []
    assert mon.drain_actions() == []


def test_straggler_needs_patience_consecutive_steps():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=3)
    # two slow steps: flagged but below patience
    for _ in range(2):
        action, hosts = mon.record_step(steps(4, slow={2}))
        assert action == Action.CONTINUE
    action, hosts = mon.record_step(steps(4, slow={2}))
    assert action == Action.REBALANCE and hosts == [2]


def test_threshold_edge_is_exclusive():
    # exactly threshold x median must NOT flag (strict >)
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=1)
    for _ in range(5):
        action, _ = mon.record_step(steps(4, slow={1}, factor=1.5))
        assert action == Action.CONTINUE
    mon2 = StragglerMonitor(n_hosts=4, threshold=1.5, patience=1)
    action, hosts = mon2.record_step(steps(4, slow={1}, factor=1.51))
    assert action == Action.REBALANCE and hosts == [1]


def test_flag_decay_hysteresis():
    """Alternating slow/fast steps never accumulate to patience."""
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=3)
    for i in range(30):
        # one slow step, then enough fast ones to drag the median back
        slow = {3} if i % 4 == 0 else set()
        action, _ = mon.record_step(steps(4, slow=slow, factor=10.0))
        assert action == Action.CONTINUE
    assert mon._flags[3] < mon.patience


def test_escalates_to_eviction_after_evict_after():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=2,
                           evict_after=5)
    seen = []
    for _ in range(10):
        action, hosts = mon.record_step(steps(4, slow={0}))
        seen.append(action)
    assert Action.REBALANCE in seen
    assert seen[-1] == Action.EVICT_RESTART


def test_heartbeat_eviction_threshold():
    mon = StragglerMonitor(n_hosts=2, max_missed=3)
    assert mon.heartbeat_missed(1) == Action.CONTINUE
    assert mon.heartbeat_missed(1) == Action.CONTINUE
    assert mon.heartbeat_missed(1) == Action.EVICT_RESTART
    # a successful step resets the missed count
    mon.record_step(steps(2))
    assert mon.hosts[1].missed_heartbeats == 0


def test_on_action_callback_and_queue():
    events = []
    mon = StragglerMonitor(n_hosts=4, patience=2,
                           on_action=lambda a, h: events.append((a, h)))
    for _ in range(4):
        mon.record_step(steps(4, slow={2}))
    assert events and all(a == Action.REBALANCE and h == [2]
                          for a, h in events)
    # the queue saw the same decisions, and drains exactly once
    drained = mon.drain_actions()
    assert drained == events
    assert mon.drain_actions() == []


def test_callback_not_fired_on_continue():
    events = []
    mon = StragglerMonitor(n_hosts=4,
                           on_action=lambda a, h: events.append(a))
    for _ in range(10):
        mon.record_step(steps(4))
    assert events == []


def test_queue_is_bounded():
    mon = StragglerMonitor(n_hosts=4, patience=1, evict_after=10**9,
                           queue_len=8)
    for _ in range(50):
        mon.record_step(steps(4, slow={1}))
    assert len(mon.actions) <= 8


def test_restart_policy_backoff_and_exhaustion():
    pol = RestartPolicy(max_restarts=3, backoff_s=1.0, backoff_mult=2.0,
                        max_backoff_s=3.0)
    assert [pol.next_delay() for _ in range(3)] == [1.0, 2.0, 3.0]
    assert pol.next_delay() is None


def test_run_with_restarts_recovers():
    calls = {"n": 0}

    def train(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("flake")
        return state + calls["n"]

    out = run_with_restarts(train, lambda: 100,
                            RestartPolicy(max_restarts=5, backoff_s=0.0),
                            sleep=lambda _: None)
    assert out == 103


def test_run_with_restarts_exhausts():
    def train(state):
        raise RuntimeError("persistent")

    with pytest.raises(RuntimeError):
        run_with_restarts(train, lambda: 0,
                          RestartPolicy(max_restarts=2, backoff_s=0.0),
                          sleep=lambda _: None)
