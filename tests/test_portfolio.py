"""Device-side portfolio search: PortfolioSpec plumbing, the lanes=1
degeneracy (bit-for-bit the flat pipeline), vmapped lane parity, tabu
escape + no-retrace masking regression, kick bijectivity, engine cache
caps, service quality classes, and the evaluator --seeds satellite."""

import argparse
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Hierarchy, Mapper, MappingSpec, grid3d,
                        qap_objective, random_geometric, write_metis)
from repro.core.construction import construct
from repro.core.local_search import communication_pairs
from repro.core.spec import PortfolioSpec
from repro.engine import RefinementEngine
from repro.topology import TorusTopology, TreeTopology

REPO = Path(__file__).resolve().parents[1]
H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


def _dev_spec(**kw):
    base = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="fast",
                engine="device", seed=1)
    base.update(kw)
    return MappingSpec(**base)


# ------------------------------------------------------------------- spec
def test_portfolio_spec_round_trip_and_validation():
    p = PortfolioSpec(lanes=4, rounds=2, tabu_tenure=5,
                      constructions=["random", "growing"])
    assert p.constructions == ("random", "growing")   # list → tuple
    assert PortfolioSpec.from_dict(p.to_dict()) == p
    json.dumps(p.to_dict())                           # JSON-safe
    with pytest.raises(ValueError, match="unknown PortfolioSpec keys"):
        PortfolioSpec.from_dict({"lanes": 2, "tempo": 1})
    for bad in (dict(lanes=0), dict(rounds=0), dict(tabu_tenure=-1),
                dict(kick_strength=1.5), dict(stagnation=0),
                dict(constructions=())):
        with pytest.raises(ValueError, match="portfolio"):
            PortfolioSpec(**bad).validate()
    with pytest.raises(ValueError, match="construction"):
        PortfolioSpec(constructions=("nope",)).validate()


def test_mapping_spec_carries_portfolio_and_requires_device():
    spec = _dev_spec(portfolio=PortfolioSpec(lanes=2))
    # dict round trip rebuilds the nested spec
    spec2 = MappingSpec.from_dict(json.loads(spec.to_json()))
    assert spec2.portfolio == spec.portfolio
    assert isinstance(spec2.portfolio, PortfolioSpec)
    with pytest.raises(ValueError, match="device"):
        spec.replace(engine="host").validate()


def test_from_flags_builds_and_strips_portfolio():
    ns = lambda **kw: argparse.Namespace(**kw)  # noqa: E731
    # --portfolio alone: defaults + auto device engine
    spec = MappingSpec.from_flags(ns(portfolio=True))
    assert spec.portfolio == PortfolioSpec()
    assert spec.engine == "device"
    # sub-flags imply --portfolio and override fields
    spec = MappingSpec.from_flags(ns(portfolio_lanes=3,
                                     portfolio_kick=0.5))
    assert spec.portfolio.lanes == 3
    assert spec.portfolio.kick_strength == 0.5
    assert spec.portfolio.rounds == PortfolioSpec().rounds
    # explicit --engine still wins over the auto-upgrade
    spec = MappingSpec.from_flags(ns(portfolio=True, engine="host"))
    with pytest.raises(ValueError, match="device"):
        spec.validate()
    # --no-portfolio strips a config-file portfolio
    base = _dev_spec(portfolio=PortfolioSpec())
    assert MappingSpec.from_flags(ns(portfolio=False),
                                  base=base).portfolio is None


# -------------------------------------------------------------- degeneracy
@pytest.mark.parametrize("extra", [
    {},
    {"multilevel": {"levels": 2, "coarsen_min": 8}},
])
def test_lanes1_tabu_off_reproduces_flat_execute_bit_for_bit(extra):
    """PortfolioSpec(1, 1, 0) is the escape hatch: same perm, same
    objectives as the non-portfolio pipeline — flat and multilevel."""
    g = grid3d(4, 4, 4)
    flat_spec = _dev_spec(**extra)
    pf_spec = flat_spec.replace(portfolio=PortfolioSpec(
        lanes=1, rounds=1, tabu_tenure=0, dont_look=False))
    want = Mapper(H64, flat_spec).map(g)
    got = Mapper(H64, pf_spec).map(g)
    assert np.array_equal(want.perm, got.perm)
    assert want.final_objective == got.final_objective
    assert want.initial_objective == got.initial_objective


def test_vmapped_lanes_equal_independent_single_runs():
    """engine.refine_lanes over L stacked perms == L sequential
    engine.refine calls, lane by lane (shared graph/pair arrays are
    inert)."""
    topo = TreeTopology(hierarchy=H64)
    g = random_geometric(64, 0.25, seed=3)
    pairs = communication_pairs(g, 2)
    perms0 = [construct("random", g, topo, seed=s) for s in range(4)]
    eng = RefinementEngine(topo, max_sweeps=32)
    lanes = [p.copy() for p in perms0]
    lane_stats = eng.refine_lanes(g, lanes, pairs,
                                  tabu_tenure=6, dlb=True)
    for p0, lane, st in zip(perms0, lanes, lane_stats):
        single = p0.copy()
        sst = eng.refine(g, single, pairs, tabu_tenure=6, dlb=True)
        assert np.array_equal(lane, single)
        assert st.final_objective == sst.final_objective


# ---------------------------------------------------------------- tabu/dlb
def test_tabu_escapes_local_optimum_strictly():
    """Tenure on, same single trajectory: the sweep walks downhill out
    of the monotone local optimum and returns a strictly better best-seen
    permutation (the paper's tabu escape, measured on a fixed cell)."""
    topo = TorusTopology((8, 8))
    g = grid3d(4, 4, 4)
    pairs = communication_pairs(g, 2)
    eng = RefinementEngine(topo, max_sweeps=64)
    mono = construct("random", g, topo, seed=0)
    tabu = mono.copy()
    eng.refine(g, mono, pairs)
    eng.refine(g, tabu, pairs, tabu_tenure=8, dlb=True)
    j_mono = qap_objective(g, topo, mono)
    j_tabu = qap_objective(g, topo, tabu)
    assert j_tabu < j_mono     # escaped: strictly better, not just equal
    assert sorted(tabu.tolist()) == list(range(g.n))


def test_tabu_off_is_bit_identical_to_plain_sweep():
    """tenure=0/dlb=False masking is the identity — not merely close."""
    topo = TreeTopology(hierarchy=H64)
    g = random_geometric(64, 0.2, seed=7)
    pairs = communication_pairs(g, 2)
    eng = RefinementEngine(topo, max_sweeps=32)
    a = construct("random", g, topo, seed=1)
    b = a.copy()
    sa = eng.refine(g, a, pairs)
    sb = eng.refine(g, b, pairs, tabu_tenure=0, dlb=False)
    assert np.array_equal(a, b)
    assert sa.final_objective == sb.final_objective


def test_tabu_toggle_is_masking_not_retracing():
    """Regression: tenure/dlb are runtime scalars — toggling them across
    calls must reuse the ONE compiled executable (trace count flat)."""
    topo = TreeTopology(hierarchy=H64)
    g = grid3d(4, 4, 4)
    pairs = communication_pairs(g, 2)
    eng = RefinementEngine(topo, max_sweeps=16)
    for tenure, dlb in ((0, False), (8, True), (3, False), (17, True)):
        perm = construct("random", g, topo, seed=tenure)
        eng.refine(g, perm, pairs, tabu_tenure=tenure, dlb=dlb)
    assert eng.trace_count() == 1


# -------------------------------------------------------------------- kicks
def test_kick_is_a_permutation_and_seed_steered():
    import jax
    from repro.portfolio import make_kick
    n = 37
    kick = make_kick(n, 0.2)
    assert 2 <= kick.klen <= n
    perm = np.random.default_rng(0).permutation(n).astype(np.int32)
    import jax.numpy as jnp
    out1 = np.asarray(kick(jnp.asarray(perm), jax.random.PRNGKey(1)))
    out2 = np.asarray(kick(jnp.asarray(perm), jax.random.PRNGKey(2)))
    same = np.asarray(kick(jnp.asarray(perm), jax.random.PRNGKey(1)))
    for out in (out1, out2):
        assert sorted(out.tolist()) == list(range(n))   # still a perm
        assert not np.array_equal(out, perm)            # actually kicked
    assert np.array_equal(out1, same)                   # deterministic
    assert not np.array_equal(out1, out2)               # key-steered


# ---------------------------------------------------------------- portfolio
def test_portfolio_never_loses_to_its_own_lane0():
    """Lane 0 shares the single pipeline's construction seed, and the
    tournament incumbent only improves — so the portfolio result can
    never be worse than the flat single-trajectory result."""
    g = random_geometric(64, 0.25, seed=3)
    single = _dev_spec(seed=0)
    pf = single.replace(portfolio=PortfolioSpec(
        lanes=4, rounds=3, tabu_tenure=0, dont_look=False,
        kick_strength=0.2, stagnation=2))
    js = Mapper(H64, single).map(g).final_objective
    res = Mapper(H64, pf).map(g)
    assert res.final_objective <= js + 1e-9 * abs(js)
    assert sorted(res.perm.tolist()) == list(range(64))
    assert res.final_objective == pytest.approx(
        qap_objective(g, TreeTopology(hierarchy=H64), res.perm))


def test_portfolio_plan_describe_reports_lane_geometry():
    spec = _dev_spec(portfolio=PortfolioSpec(
        lanes=3, rounds=2, constructions=("random", "growing")))
    plan = Mapper(H64, spec).lower_for(grid3d(4, 4, 4))
    d = plan.describe()["portfolio"]
    assert d["lanes"] == 3 and d["rounds"] == 2
    assert d["lane_constructions"] == ["random", "growing", "random"]
    json.dumps(plan.describe())


def test_portfolio_multilevel_vcycle_executes():
    spec = _dev_spec(multilevel={"levels": 2, "coarsen_min": 8},
                     portfolio=PortfolioSpec(lanes=2, rounds=2,
                                             stagnation=1))
    res = Mapper(H64, spec).map(grid3d(4, 4, 4))
    assert sorted(res.perm.tolist()) == list(range(64))
    assert res.final_objective <= res.initial_objective


# ------------------------------------------------------------- cache caps
def test_engine_cache_caps_bound_uploads_and_report_evictions():
    topo = TreeTopology(hierarchy=H64)
    eng = RefinementEngine(topo, max_sweeps=8,
                           cache_caps={"graphs": 2, "pairs": 2})
    graphs = [random_geometric(64, 0.2, seed=s) for s in range(3)]
    for g in graphs:
        eng.refine(g, construct("random", g, topo, seed=0),
                   communication_pairs(g, 2))
    info = eng.cache_info()
    assert info["graph_entries"] <= 2
    assert info["graph_evictions"] >= 1
    with pytest.raises(ValueError, match="cache_caps"):
        RefinementEngine(topo, cache_caps={"grphs": 4})


def test_mapper_cache_caps_reach_the_shared_engine():
    mapper = Mapper(H64, _dev_spec(),
                    cache_caps={"engine_graphs": 2, "engine_pairs": 2})
    for s in range(3):
        mapper.map(random_geometric(64, 0.2, seed=s))
    info = mapper.cache_info()
    assert info["engine_graph_evictions"] >= 1


# -------------------------------------------------------- quality classes
def test_service_quality_classes_share_one_plan_cache():
    from repro.launch.serve import MappingService
    g = grid3d(4, 4, 4)
    spec = _dev_spec()
    mapper = Mapper(H64, spec)
    strong = PortfolioSpec(lanes=2, rounds=2, stagnation=1)
    with MappingService(mapper, max_wait_s=0.05,
                        quality_classes={"fast": None,
                                         "strong": strong}) as svc:
        rf = svc.map(g, quality="fast", timeout=300)
        rs = svc.map(g, quality="strong", timeout=300)
        rd = svc.map(g, timeout=300)            # spec as-is = fast path
        stats = svc.stats()
        with pytest.raises(ValueError, match="quality"):
            svc.submit(g, quality="turbo")
    assert stats["quality_served"] == {"fast": 1, "strong": 1,
                                       "default": 1}
    # the default request is answered by the fast class's plan/cache
    assert np.array_equal(rd.perm, rf.perm)
    assert rs.final_objective <= rf.final_objective + 1e-9
    # fast + default share one plan; strong adds exactly one more
    assert mapper.cache_info()["plan_builds"] == 2


# --------------------------------------------------------- evaluator seeds
def test_evaluator_seeds_reports_best_median_spread(tmp_path):
    g = grid3d(4, 4, 4)
    gpath = tmp_path / "g.metis"
    write_metis(g, str(gpath))
    mpath = tmp_path / "perm.txt"
    np.savetxt(mpath, np.arange(64, dtype=np.int64), fmt="%d")
    spath = tmp_path / "spec.json"
    spath.write_text(_dev_spec(seed=0).to_json())
    r = subprocess.run(
        [sys.executable, "-m", "repro.cli.evaluator", str(gpath),
         f"--input_mapping={mpath}",
         "--hierarchy_parameter_string=4:4:4",
         "--distance_parameter_string=1:10:100",
         f"--compare_spec={spath}", "--seeds=3"],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr
    assert "viem seeds          = 3 (seed 0..2)" in r.stdout
    assert "viem best/median" in r.stdout
    assert "viem spread" in r.stdout
    best = float(r.stdout.split("viem best/median    = ")[1].split(" /")[0])
    worst = float(r.stdout.split("(worst ")[1].split(")")[0])
    assert best <= worst
