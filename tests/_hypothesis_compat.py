"""Guarded `hypothesis` import shared by the property-test modules.

When hypothesis is installed the real ``given``/``settings``/``st`` are
re-exported.  When it is not (the bare runtime image), the substitutes
below turn each ``@given`` test into a cleanly skipped zero-arg test —
property tests skip, every other test in the module still runs.
"""

import pytest

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Accepts any strategy expression at decoration time; the values
        are never used because the test body is skipped."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
