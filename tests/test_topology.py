"""Shared distance-oracle contract suite, run against *every* registered
topology backend: zero diagonal, symmetry, online oracle ≡ materialized
matrix, kernel path ≡ numpy path, split() decomposition invariants, spec
round-trips — plus `tree` ≡ legacy `Hierarchy` bit-for-bit through Mapper.
"""

import numpy as np
import pytest

from repro.core import (Hierarchy, Mapper, MappingSpec, TopologySpec,
                        grid3d, qap_objective, write_metis)
from repro.topology import (DragonflyTopology, FatTreeTopology,
                            MatrixTopology, TorusTopology, TreeTopology,
                            as_topology, list_topologies,
                            load_distance_matrix, make_topology,
                            tpu_v5e_torus, tpu_v5p_torus)


def _matrix_instance(n=64, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.random((n, n)) * 9.0
    D = A + A.T
    np.fill_diagonal(D, 0.0)
    return MatrixTopology(matrix=D)


# one instance per registered backend, all with n_pe == 64 so the same
# graphs/mappings exercise every machine model
INSTANCES = {
    "tree": TreeTopology((4, 4, 4), (1.0, 10.0, 100.0)),
    "torus": TorusTopology((4, 4, 4), (1.0, 2.0, 7.0)),
    "fattree": FatTreeTopology((4, 4, 4), (1.0, 3.0, 9.0)),
    "dragonfly": DragonflyTopology(pes_per_router=4, routers_per_group=4,
                                   n_groups=4),
    "matrix": _matrix_instance(),
}


def _params(request):
    return INSTANCES[request.param]


@pytest.fixture(params=sorted(INSTANCES))
def topo(request):
    return INSTANCES[request.param]


def test_every_registered_backend_is_covered():
    """The contract suite must grow with the registry."""
    assert set(INSTANCES) == set(list_topologies())
    for name, t in INSTANCES.items():
        assert t.kind == name
        assert t.n_pe == 64


# ----------------------------------------------------------- the contract
def test_zero_diagonal_and_symmetry(topo):
    D = topo.distance_matrix()
    assert D.shape == (64, 64)
    assert np.all(np.diag(D) == 0.0)
    assert np.array_equal(D, D.T)
    assert np.all(D >= 0.0)


def test_online_oracle_matches_matrix(topo, rng):
    D = topo.distance_matrix()
    p = rng.integers(0, topo.n_pe, 200)
    q = rng.integers(0, topo.n_pe, 200)
    assert np.array_equal(topo.distance(p, q), D[p, q])
    # scalar form
    assert topo.distance(3, 7) == D[3, 7]
    # broadcasting form
    idx = np.arange(topo.n_pe)
    assert np.array_equal(topo.distance(idx[:, None], idx[None, :]), D)


def test_matrix_is_cached(topo):
    assert topo.matrix() is topo.matrix()
    assert not topo.matrix().flags.writeable


def test_kernel_path_matches_numpy_path(topo):
    """The Pallas edge-list objective (tree/torus closed form, matrix
    gather) agrees with the host oracle for every backend."""
    g = grid3d(4, 4, 4)
    spec = MappingSpec(construction="random", neighborhood=None, seed=3)
    mapper = Mapper(topo, spec)
    perm = np.random.default_rng(5).permutation(64)
    want = mapper.objective(g, perm, spec)
    got = mapper.objective(g, perm, spec.replace(backend="pallas"))
    assert want == pytest.approx(got, rel=2e-6)
    assert mapper.cache_info()["kernel_compiles"] == 1


def test_split_is_a_balanced_partition(topo):
    """split() recursively decomposes the full PE set into equal-size(±1)
    parts that exactly partition it, and terminates."""
    def rec(ids, depth):
        assert depth < 32, "split() recursion did not terminate"
        parts = topo.split(ids)
        if parts is None:
            return [ids]
        assert len(parts) >= 2
        sizes = [len(p) for p in parts]
        assert max(sizes) - min(sizes) <= 1
        leaves = []
        for p in parts:
            leaves += rec(p, depth + 1)
        return leaves

    leaves = rec(np.arange(topo.n_pe, dtype=np.int64), 0)
    union = np.sort(np.concatenate(leaves))
    assert np.array_equal(union, np.arange(topo.n_pe))


def test_spec_round_trip(topo):
    rebuilt = make_topology(topo.kind, **topo.spec_params())
    assert np.array_equal(rebuilt.distance_matrix(),
                          topo.distance_matrix())
    # through TopologySpec / MappingSpec JSON
    spec = MappingSpec(topology=TopologySpec.of(topo),
                       preconfiguration="fast").validate()
    spec2 = MappingSpec.from_json(spec.to_json())
    assert spec2.topology == spec.topology
    mapper = Mapper.from_spec(spec2)
    assert mapper.topology.n_pe == topo.n_pe
    assert np.array_equal(mapper.topology.distance_matrix(),
                          topo.distance_matrix())


def test_mapper_end_to_end(topo):
    """Every backend maps the mesh graph: valid permutation, local search
    does not worsen the objective, objective is consistent."""
    g = grid3d(4, 4, 4)
    spec = MappingSpec(preconfiguration="fast", neighborhood_dist=2,
                       max_sweeps=2, seed=0)
    res = Mapper(topo, spec).map(g)
    assert sorted(res.perm) == list(range(64))
    assert res.final_objective <= res.initial_objective + 1e-9
    assert res.final_objective == pytest.approx(
        qap_objective(g, topo, res.perm))


# ------------------------------------------------- tree ≡ Hierarchy (exact)
def test_tree_is_bit_identical_to_hierarchy():
    h = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))
    t = TreeTopology(hierarchy=h)
    assert np.array_equal(t.distance_matrix(), h.distance_matrix())
    g = grid3d(4, 4, 4)
    for nb in ("communication", None):
        spec = MappingSpec(preconfiguration="fast", neighborhood=nb,
                           seed=2)
        r_h = Mapper(h, spec).map(g)
        r_t = Mapper(t, spec).map(g)
        assert np.array_equal(r_h.perm, r_t.perm)
        assert r_h.initial_objective == r_t.initial_objective
        assert r_h.final_objective == r_t.final_objective


def test_hierarchy_coerces_to_tree_topology():
    h = Hierarchy((4, 4), (1.0, 10.0))
    t = as_topology(h)
    assert isinstance(t, TreeTopology) and t.hierarchy is h
    assert as_topology(t) is t
    with pytest.raises(TypeError):
        as_topology(object())


def test_tree_oracle_shared_across_sessions():
    h = Hierarchy((4, 4), (1.0, 10.0))
    m1 = Mapper(h)
    m2 = Mapper(h)
    assert m1.cache_info()["oracle_builds"] == 1
    assert m2.cache_info()["oracle_builds"] == 0      # cached on h
    topo = TorusTopology((4, 4))
    m3, m4 = Mapper(topo), Mapper(topo)
    assert m3.cache_info()["oracle_builds"] == 1
    assert m4.cache_info()["oracle_builds"] == 0      # claimed on topo


# ----------------------------------------------------------- torus details
def test_torus_ring_distance():
    t = TorusTopology((5, 3), (1.0, 4.0))
    assert t.distance(0, 4) == 1.0         # wraparound: min(4, 1)
    assert t.distance(0, 2) == 2.0
    assert t.distance(0, 5) == 4.0         # one hop on axis 1
    assert t.distance(0, 10) == 4.0        # wraparound on axis 1 (ring of 3)
    assert t.n_pe == 15


def test_torus_presets():
    assert tpu_v5e_torus(1).n_pe == 256
    assert tpu_v5e_torus(2).n_pe == 512
    assert tpu_v5p_torus().n_pe == 1024
    # DCN axis dominates ICI
    t = tpu_v5e_torus(2)
    assert t.distance(0, 256) == 60.0


def test_fattree_doubles_cumulative_link_costs():
    ft = FatTreeTopology((2, 2), (1.0, 5.0))
    # same edge switch: up+down one link each = 2; via root: 2·(1+5) = 12
    assert ft.distance(0, 1) == 2.0
    assert ft.distance(0, 2) == 12.0


def test_dragonfly_distance_classes():
    df = DragonflyTopology(pes_per_router=2, routers_per_group=2,
                           n_groups=2, d_router=1.0, d_local=2.0,
                           d_global=10.0)
    assert df.distance(0, 1) == 1.0        # same router
    assert df.distance(0, 2) == 2.0        # same group
    assert df.distance(0, 4) == 14.0       # l-g-l across groups


# ------------------------------------------------------- matrix file I/O
def test_matrix_from_metis_file(tmp_path):
    topo = INSTANCES["torus"]
    # encode the torus distance matrix as a metis graph (weight=distance)
    from repro.core import from_dense
    gD = from_dense(topo.distance_matrix())
    path = tmp_path / "D.metis"
    with open(path, "w") as fh:
        write_metis(gD, fh)
    m = MatrixTopology(file=str(path))
    assert np.array_equal(m.distance_matrix(), topo.distance_matrix())


def test_matrix_from_dense_text_and_npy(tmp_path):
    D = INSTANCES["matrix"].D
    txt = tmp_path / "D.txt"
    np.savetxt(txt, D)
    got = load_distance_matrix(txt)
    assert np.allclose(got, D)
    npy = tmp_path / "D.npy"
    np.save(npy, D)
    assert np.array_equal(load_distance_matrix(str(npy)), D)


def test_matrix_validation():
    with pytest.raises(ValueError, match="square"):
        MatrixTopology(matrix=np.zeros((3, 4)))
    bad = np.ones((3, 3))
    with pytest.raises(ValueError, match="diagonal"):
        MatrixTopology(matrix=bad)
    asym = np.zeros((3, 3))
    asym[0, 1] = 1.0
    with pytest.raises(ValueError, match="symmetric"):
        MatrixTopology(matrix=asym)
    neg = np.zeros((3, 3))
    neg[0, 1] = neg[1, 0] = -1.0
    with pytest.raises(ValueError, match="non-negative"):
        MatrixTopology(matrix=neg)


# -------------------------------------------------------------- registry
def test_registry_rejects_duplicates_and_unknowns():
    from repro.topology import register_topology, resolve_topology
    with pytest.raises(ValueError, match="already registered"):
        register_topology("torus")(TorusTopology)
    with pytest.raises(ValueError, match="unknown topology"):
        resolve_topology("hypercube-of-dreams")
    with pytest.raises(ValueError, match="unknown topology"):
        TopologySpec(kind="nope").validate()


def test_bottomup_requires_tree_family():
    g = grid3d(4, 4, 4)
    spec = MappingSpec(construction="hierarchybottomup",
                       preconfiguration="fast")
    with pytest.raises(ValueError, match="tree-family"):
        Mapper(INSTANCES["torus"], spec).map(g)
    # tree family (incl. fattree/dragonfly) works
    res = Mapper(INSTANCES["fattree"], spec).map(g)
    assert sorted(res.perm) == list(range(64))
