"""Per-arch smoke tests: reduced same-family config, one forward + one
train step + decode steps on CPU, asserting shapes and finiteness —
deliverable (f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.transformer import (decode_step, forward, init_caches,
                                      init_params)
from repro.train import OptConfig, init_train_state, train_step

KEY = jax.random.PRNGKey(0)
OPT = OptConfig(total_steps=10, warmup_steps=2)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_train_decode(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    b, t = 2, 32
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    fe = (jax.random.normal(KEY, (b, cfg.frontend_tokens, cfg.d_model),
                            cfg.jnp_dtype) if cfg.frontend_tokens else None)
    logits, aux = jax.jit(
        lambda p, tk, f: forward(p, tk, cfg, frontend=f))(params, tokens, fe)
    t_out = t + cfg.frontend_tokens
    assert logits.shape == (b, t_out, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert np.isfinite(float(aux))

    state = init_train_state(KEY, cfg)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)}
    if fe is not None:
        batch["frontend"] = fe
    state2, metrics = jax.jit(
        lambda s, bt: train_step(s, bt, cfg, OPT))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params must actually change somewhere (bf16 ULP can mask tiny
    # first-step updates on leaves near 1.0 — check the whole tree)
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert delta > 0
    assert int(state2["step"]) == 1

    caches = init_caches(b, cfg, max_len=48)
    tok = tokens[:, :1]
    dec = jax.jit(lambda p, tk, c, s: decode_step(p, tk, c, s, cfg))
    for step in range(2):
        lg, caches = dec(params, tok, caches, jnp.int32(step))
        assert lg.shape == (b, 1, cfg.padded_vocab)
        assert not bool(jnp.any(jnp.isnan(lg.astype(jnp.float32))))
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_fidelity(arch):
    """The published numbers are wired through exactly (deliverable (f))."""
    cfg = get_config(arch)
    expected = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    assert cfg.n_layers % cfg.period == 0
    assert cfg.padded_vocab % 256 == 0


def test_param_counts_plausible():
    """Sanity-check total parameters against published sizes (±25%)."""
    approx = {
        "jamba-v0.1-52b": 52e9, "mixtral-8x22b": 141e9,
        "mixtral-8x7b": 47e9, "granite-3-8b": 8e9, "granite-3-2b": 2.5e9,
        "stablelm-1.6b": 1.6e9, "starcoder2-7b": 7e9, "rwkv6-3b": 3e9,
        "llava-next-34b": 34e9, "musicgen-medium": 1.5e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.7 * target < n < 1.45 * target, (arch, n, target)


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-v0.1-52b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    attn = [i for i, (m, _) in enumerate(kinds) if m == "attn"]
    assert len(attn) == 4                       # 1:7 ratio over 32 layers
    moe = [i for i, (_, f) in enumerate(kinds) if f == "moe"]
    assert len(moe) == 16                       # every other layer
