"""Partitioner invariants: perfect balance, label validity, cut sanity."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import grid3d, random_geometric
from repro.core.partition import (PartitionConfig, block_sizes, cut_weight,
                                  partition)


@given(st.sampled_from([2, 4, 8, 16]), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_perfect_balance_grid(k, seed):
    g = grid3d(4, 4, 4)
    labels = partition(g, k, seed=seed)
    assert labels.min() >= 0 and labels.max() == k - 1
    assert np.all(block_sizes(labels, k) == g.n // k)


@given(st.integers(2, 6), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_balance_non_power_of_two(k, seed):
    g = random_geometric(60, 0.3, seed=seed)
    labels = partition(g, k, seed=seed)
    sizes = block_sizes(labels, k)
    assert sizes.sum() == g.n
    assert sizes.max() - sizes.min() <= 1     # ±1 when k ∤ n


def test_cut_beats_random():
    """The partitioner must beat a random assignment on structured graphs."""
    g = grid3d(6, 6, 6)
    labels = partition(g, 8, seed=0)
    cut = cut_weight(g, labels)
    rng = np.random.default_rng(0)
    rand_cuts = []
    for _ in range(5):
        rl = rng.permutation(np.repeat(np.arange(8), g.n // 8))
        rand_cuts.append(cut_weight(g, rl))
    assert cut < 0.5 * min(rand_cuts)


def test_preconfigurations():
    g = grid3d(4, 4, 4)
    cuts = {}
    for pre in ("fast", "eco", "strong"):
        cfg = PartitionConfig.preconfiguration(pre)
        cuts[pre] = cut_weight(g, partition(g, 4, cfg, seed=0))
    # strong should not be worse than fast (stochastic; allow equality)
    assert cuts["strong"] <= cuts["fast"] * 1.5
    with pytest.raises(ValueError):
        PartitionConfig.preconfiguration("bogus")


def test_disconnected_graph():
    from repro.core import from_edges
    # two disjoint triangles + 2 isolated vertices
    g = from_edges(8, [0, 1, 2, 3, 4, 5], [1, 2, 0, 4, 5, 3],
                   np.ones(6))
    labels = partition(g, 2, seed=0)
    assert np.all(block_sizes(labels, 2) == 4)
