"""Kernel-layer geometry and quantization invariants.

The contract of the PR that introduced `KernelConfig`:

  * `quantize_table` packs exact-small-integer tables to int8/int16 and
    NEVER silently changes results (auto falls back, explicit raises);
  * quantized matrix-form gathers are bit-identical to float32 gathers
    for every registered topology's distance table;
  * tile geometry (block_rows, lanes) is a performance knob, not a
    semantics knob: sweeping configs over tight/pow2/oversized buckets
    leaves objectives and accept/reject decisions bit-identical;
  * changing the kernel config never retraces a warm engine — a new
    config gets its own pooled engine, old executables stay warm;
  * the padding helpers shared in `kernels.pad` are inert (zero/self
    padding only);
  * `swap_gain_matrix` is a reference path: importable, not exported.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Mapper, MappingSpec, ShapeBucket
from repro.core.graph import DeviceGraph, device_pairs, from_edges
from repro.core.spec import KernelSpec
from repro.kernels import KernelConfig, derive_kernel_config, quantize_table
from repro.kernels.pair_gain import (edge_objective, pair_gains,
                                     pair_gains_pallas)
from repro.kernels import pad as kpad
from repro.topology import list_topologies, make_topology
from repro.topology.matrix import MatrixTopology

INTERPRET = jax.default_backend() != "tpu"

# instantiation recipe per registered topology (integral distances, the
# Schulz–Träff structure the quantizer exploits)
_TOPO_RECIPES = {
    "tree": dict(factors=[4, 4, 4], distances=[1.0, 10.0, 100.0]),
    "torus": dict(dims=[8, 8]),
    "fattree": dict(arities=[4, 4, 4]),
    "dragonfly": dict(),                       # defaults: 4·8·9 = 288 PEs
    "matrix": None,                            # wrapped below
}


def _instance(name):
    if name == "matrix":
        base = make_topology("tree", **_TOPO_RECIPES["tree"])
        return MatrixTopology(base.matrix())
    return make_topology(name, **_TOPO_RECIPES[name])


def _int_graph(n, seed=0, deg=6):
    """Integer-weight workload: every f32 sum below is exact, so tiled /
    quantized paths must match the fused float path bit-for-bit."""
    rng = np.random.default_rng(seed)
    m = n * deg // 2
    u = rng.integers(0, n, m)
    v = (u + 1 + rng.integers(0, n - 1, m)) % n
    keep = u != v
    return from_edges(n, u[keep], v[keep],
                      rng.integers(1, 16, keep.sum()).astype(np.float64))


def _gain_inputs(g, seed=0, n_pairs=256):
    rng = np.random.default_rng(seed)
    dg = DeviceGraph.from_comm(g)
    perm = jnp.asarray(rng.permutation(g.n), jnp.int32)
    pairs = np.stack([rng.integers(0, g.n, n_pairs),
                      rng.integers(0, g.n, n_pairs)], axis=1)
    us, vs = device_pairs(pairs)
    return dg, perm, us, vs


# ------------------------------------------------------------ quantize_table
def test_quantize_table_auto_selects_narrowest_lossless_width():
    small = np.array([[0., 3.], [3., 0.]])
    packed, dt = quantize_table(small)
    assert dt == "int8" and packed.dtype == np.int8
    assert np.array_equal(packed.astype(np.float64), small)
    wide = np.array([[0., 300.], [300., 0.]])
    packed, dt = quantize_table(wide)
    assert dt == "int16" and packed.dtype == np.int16
    huge = np.array([[0., 40000.], [40000., 0.]])
    assert quantize_table(huge) is None          # auto: fall back, no error
    fractional = np.array([[0., 1.5], [1.5, 0.]])
    assert quantize_table(fractional) is None
    assert quantize_table(small, "off") is None


def test_quantize_table_forced_mode_refuses_lossy_packing():
    wide = np.array([[0., 300.], [300., 0.]])
    with pytest.raises(ValueError, match="exceeds"):
        quantize_table(wide, "int8")
    fractional = np.array([[0., 1.5], [1.5, 0.]])
    with pytest.raises(ValueError, match="not exact integers"):
        quantize_table(fractional, "int8")
    with pytest.raises(ValueError, match="unknown quantize mode"):
        quantize_table(wide, "int4")
    # forced int16 on an int8-range table is allowed (wider, still exact)
    small = np.array([[0., 3.], [3., 0.]])
    assert quantize_table(small, "int16")[1] == "int16"


def test_kernel_config_validation_and_identity():
    with pytest.raises(ValueError, match="lanes"):
        KernelConfig(lanes=100).validate()
    with pytest.raises(ValueError, match="block_rows"):
        KernelConfig(block_rows=0).validate()
    with pytest.raises(ValueError, match="acc_dtype"):
        KernelConfig(acc_dtype="bfloat16").validate()
    cfg = KernelConfig(block_rows=2, lanes=256, dist_dtype="int8")
    assert KernelConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.tag() == "b2:l256:float32:int8"
    assert cfg.replace(dist_dtype=None).key() != cfg.key()
    with pytest.raises(ValueError, match="unknown KernelConfig keys"):
        KernelConfig.from_dict({"block_rows": 2, "tile": 8})


def test_derive_kernel_config_is_backend_aware_and_honors_overrides():
    g = _int_graph(256)
    bucket = ShapeBucket.of(g)
    cpu = derive_kernel_config("tree", bucket=bucket, backend="cpu")
    tpu = derive_kernel_config("tree", bucket=bucket, backend="tpu")
    assert cpu.lanes % 128 == 0 and tpu.lanes <= 1024
    # CPU budget covers the bucket in one tile → tiled path == fused path
    assert cpu.block_rows * cpu.lanes >= bucket.num_edges
    pinned = derive_kernel_config("tree", bucket=bucket, backend="cpu",
                                  block_rows=2, lanes=256)
    assert (pinned.block_rows, pinned.lanes) == (2, 256)
    D = _instance("tree").matrix()
    q = derive_kernel_config("matrix", bucket=bucket, table=D)
    assert q.dist_dtype == "int8"
    off = derive_kernel_config("matrix", bucket=bucket, table=D,
                               quantize="off")
    assert off.dist_dtype is None


# ------------------------------------- quantized parity, every topology
@pytest.mark.parametrize("name", list_topologies())
def test_quantized_matrix_gather_bit_identical(name):
    topo = _instance(name)
    D = topo.matrix()
    packed = quantize_table(D)
    assert packed is not None, f"{name} table should quantize losslessly"
    n = topo.n_pe
    g = _int_graph(n, seed=1)
    dg, perm, us, vs = _gain_inputs(g, seed=1)
    D32 = jnp.asarray(D, jnp.float32)
    Dq = jnp.asarray(packed[0])
    obj_f = edge_objective("matrix", (), dg.eu, dg.ev, dg.ew, perm, D32)
    obj_q = edge_objective("matrix", (), dg.eu, dg.ev, dg.ew, perm, Dq)
    assert float(obj_f) == float(obj_q)          # bit-identical
    gains_f = pair_gains("matrix", (), dg.nbr, dg.wgt, perm, us, vs, D32)
    gains_q = pair_gains("matrix", (), dg.nbr, dg.wgt, perm, us, vs, Dq)
    assert np.array_equal(np.asarray(gains_f), np.asarray(gains_q))
    pg_f = pair_gains_pallas("matrix", (), dg.nbr, dg.wgt, perm, us, vs,
                             D32, interpret=INTERPRET)
    pg_q = pair_gains_pallas("matrix", (), dg.nbr, dg.wgt, perm, us, vs,
                             Dq, interpret=INTERPRET)
    assert np.array_equal(np.asarray(pg_f), np.asarray(pg_q))


def test_quantized_end_to_end_identical_mapping():
    """Same graph, same spec, quantize auto vs off: identical perms and
    objectives — the packing is invisible to results."""
    topo = MatrixTopology(_instance("tree").matrix())
    g = _int_graph(64, seed=2)
    spec = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="fast",
                engine="device", seed=1)
    res_q = Mapper(topo, MappingSpec(**spec)).map(g)
    res_f = Mapper(topo, MappingSpec(
        **spec, kernel=KernelSpec(quantize="off"))).map(g)
    assert np.array_equal(res_q.perm, res_f.perm)
    assert res_q.final_objective == res_f.final_objective


# ------------------------------------------------- tile-geometry sweep
_SWEEP = [KernelConfig(block_rows=1, lanes=128),
          KernelConfig(block_rows=2, lanes=256),
          KernelConfig(block_rows=64, lanes=8192)]


@pytest.mark.parametrize("cfg", _SWEEP, ids=lambda c: c.tag())
def test_tile_geometry_sweep_kernels_bit_identical(cfg):
    g = _int_graph(128, seed=3)
    dg, perm, us, vs = _gain_inputs(g, seed=3)
    topo = _instance("tree")
    strides, dists = topo.kernel_params()[1:]
    params = (strides, dists)
    D0 = jnp.zeros((1, 1), jnp.float32)
    base_obj = edge_objective("tree", params, dg.eu, dg.ev, dg.ew,
                              perm, D0)
    base_gain = pair_gains("tree", params, dg.nbr, dg.wgt, perm,
                           us, vs, D0)
    obj = edge_objective("tree", params, dg.eu, dg.ev, dg.ew,
                         perm, D0, config=cfg)
    gain = pair_gains("tree", params, dg.nbr, dg.wgt, perm, us,
                      vs, D0, config=cfg)
    assert float(obj) == float(base_obj)
    assert np.array_equal(np.asarray(gain), np.asarray(base_gain))
    pg = pair_gains_pallas("tree", params, dg.nbr, dg.wgt, perm,
                           us, vs, D0, interpret=INTERPRET, config=cfg)
    assert np.array_equal(np.asarray(pg), np.asarray(base_gain))


@pytest.mark.parametrize("schedule,oversize",
                         [("tight", False), ("pow2", False),
                          ("tight", True)],
                         ids=["tight", "pow2", "oversized"])
def test_tile_geometry_sweep_plans_bit_identical(schedule, oversize):
    """Pinned tile geometries across bucket schedules: the mapping a
    plan produces is independent of both."""
    topo = _instance("tree")
    g = _int_graph(64, seed=4)
    bucket = ShapeBucket.of(g, schedule=schedule)
    if oversize:
        bucket = ShapeBucket(max_deg=bucket.max_deg * 2,
                             num_edges=bucket.num_edges * 4,
                             schedule=bucket.schedule)
    spec = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="fast",
                engine="device", seed=1)
    ref = Mapper(topo, MappingSpec(**spec)).lower_for(g).execute(g)
    for ks in (KernelSpec(block_rows=1, lanes=128),
               KernelSpec(block_rows=2, lanes=256)):
        mapper = Mapper(topo, MappingSpec(**spec, kernel=ks))
        res = mapper.lower(bucket).execute(g)
        assert np.array_equal(res.perm, ref.perm)
        assert res.final_objective == ref.final_objective


# ------------------------------------------------- warm-path no-retrace
def test_kernel_config_changes_never_retrace_warm_engines():
    topo = _instance("tree")
    g = _int_graph(64, seed=5)
    spec = MappingSpec(construction="random",
                       neighborhood="communication", neighborhood_dist=2,
                       preconfiguration="fast", engine="device", seed=1)
    mapper = Mapper(topo, spec)
    plan = mapper.lower_for(g)
    plan.execute(g)
    eng = plan.engines[0]
    assert eng.trace_count() == 1
    for seed in (2, 3, 4):                       # warm serving stays warm
        plan.execute(g, seed=seed)
    assert eng.trace_count() == 1
    # a different kernel config = a different pooled engine; the first
    # engine's executable is untouched
    plan2 = mapper.lower_for(g, spec.replace(
        kernel=KernelSpec(block_rows=1, lanes=128)))
    assert plan2.engines[0] is not eng
    plan2.execute(g)
    assert eng.trace_count() == 1
    assert plan2.engines[0].trace_count() == 1
    plan.execute(g, seed=5)                      # and stays warm after
    assert eng.trace_count() == 1
    # same config → same pooled engine (no silent duplicate compiles)
    plan3 = mapper.lower_for(g, spec.replace(seed=9))
    assert plan3.engines[0] is eng


# ------------------------------------------------------- plan reporting
def test_describe_reports_kernel_configs():
    topo = MatrixTopology(_instance("tree").matrix())
    g = _int_graph(64, seed=6)
    spec = MappingSpec(construction="random",
                       neighborhood="communication", neighborhood_dist=2,
                       preconfiguration="fast", engine="device", seed=1)
    d = Mapper(topo, spec).lower_for(g).describe()
    assert "kernels" in d
    assert d["kernels"]["backend"] == jax.default_backend()
    cfgs = d["kernels"]["configs"]
    assert cfgs and all(KernelConfig.from_dict(c) for c in cfgs)
    assert d["kernels"]["quantized"]             # integral tree table
    assert all("kernel_config" in lvl for lvl in d["levels"])


def test_spec_kernel_block_round_trips_and_validates():
    ks = KernelSpec(block_rows=2, lanes=256, quantize="int8")
    spec = MappingSpec(construction="random", kernel=ks)
    again = MappingSpec.from_dict(spec.to_dict())
    assert again == spec and again.kernel == ks
    with pytest.raises(ValueError, match="lanes"):
        KernelSpec(lanes=100).validate()
    with pytest.raises(ValueError, match="quantize"):
        KernelSpec(quantize="int4").validate()


# ------------------------------------------------------- shared padding
def test_pad_helpers_are_inert():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal(300), jnp.float32)
    p = kpad.pad1(a, 512)
    assert p.shape == (512,)
    assert np.array_equal(np.asarray(p[:300]), np.asarray(a))
    assert not np.asarray(p[300:]).any()
    m = jnp.asarray(rng.standard_normal((5, 7)), jnp.float32)
    p2 = kpad.pad2(m, 8, 16)
    assert p2.shape == (8, 16)
    assert np.array_equal(np.asarray(p2[:5, :7]), np.asarray(m))
    assert float(jnp.sum(p2)) == pytest.approx(float(jnp.sum(m)))
    # pad_edge_arrays: zero-weight padding leaves the objective alone
    g = _int_graph(64, seed=7)
    u, v, w = g.edge_list()
    eu, ev, ew = kpad.pad_edge_arrays(u, v, w)
    assert eu.shape[0] % 128 == 0
    topo = _instance("tree")
    strides, dists = topo.kernel_params()[1:]
    D0 = jnp.zeros((1, 1), jnp.float32)
    padded = edge_objective("tree", (strides, dists), eu, ev, ew,
                            jnp.arange(64, dtype=jnp.int32), D0)
    raw = edge_objective("tree", (strides, dists), jnp.asarray(u),
                         jnp.asarray(v),
                         jnp.asarray(w, dtype=jnp.float32),
                         jnp.arange(64, dtype=jnp.int32), D0)
    assert float(padded) == float(raw)


def test_swap_gain_matrix_is_reference_only():
    import repro.kernels as kernels
    assert "swap_gain_matrix" not in kernels.__all__
    assert callable(kernels.swap_gain_matrix)    # still importable
