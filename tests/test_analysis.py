"""HLO analyzer: trip counts, dot FLOPs, collective pricing — validated
against a hand-built HLO snippet and a real compiled module."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import analyze, roofline_from_cost
from repro.analysis.hlo import (_replica_group_info, _ring_factor,
                                shape_numel_bytes, Instruction)

SYNTH = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,16]{1,0}) tuple(%zero, %arg)
  %w = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_module_trip_count_and_flops():
    cost = analyze(SYNTH, pod_size=256)
    assert cost.trip_counts == {"w": 12}
    # dot: 2 * 8*16 * 16 flops per iteration × 12
    assert cost.dot_flops == 12 * 2 * 8 * 16 * 16
    # all-reduce: g=16 within one pod, f32[8,16] = 512B
    ar = [c for c in cost.collectives if c.op == "all-reduce"]
    assert len(ar) == 1
    assert ar[0].group_size == 16 and not ar[0].cross_pod
    assert np.isclose(ar[0].wire_bytes, 12 * 2 * (15 / 16) * 512)


def test_replica_group_parsing():
    ins = Instruction("x", "f32[4]", "all-reduce",
                      "%y), replica_groups=[16,32]<=[32,16]T(1,0), x")
    g, pods = _replica_group_info(ins, pod_size=256)
    assert g == 32 and pods == 2      # strided groups span both pods
    ins2 = Instruction("x", "f32[4]", "all-reduce",
                       "%y), replica_groups={{0,1,2},{3,4,5}}, x")
    g, pods = _replica_group_info(ins2, pod_size=256)
    assert g == 3 and pods == 1


def test_shape_parsing():
    assert shape_numel_bytes("f32[8,16]{1,0}") == (128, 512)
    assert shape_numel_bytes("bf16[2,3]") == (6, 12)
    assert shape_numel_bytes("(s32[], bf16[4,4]{1,0})") == (17, 36)
    assert shape_numel_bytes("pred[]") == (1, 1)


def test_ring_factors():
    assert _ring_factor("all-reduce", 16) == 2 * 15 / 16
    assert _ring_factor("all-gather", 4) == 3
    assert _ring_factor("reduce-scatter", 8) == 7 / 8
    assert _ring_factor("all-reduce", 1) == 0.0


def test_real_compiled_module_scan_counting():
    """Scanned matmul: analyzer must multiply the trip count that
    cost_analysis() misses (the DESIGN §4 probe, as a regression test)."""
    d = 64
    def step(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), ()
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)
    compiled = jax.jit(step).lower(
        jax.ShapeDtypeStruct((5, d, d), jnp.float32),
        jax.ShapeDtypeStruct((8, d), jnp.float32)).compile()
    cost = analyze(compiled.as_text())
    expected_dot = 5 * 2 * 8 * d * d
    assert cost.dot_flops == expected_dot, (cost.dot_flops, expected_dot)
    rl = roofline_from_cost(cost, model_flops_per_device=expected_dot)
    assert rl.bound in ("memory", "compute")
    assert 0 < rl.model_flops_ratio <= 1.2


def test_roofline_terms():
    from repro.analysis.hlo import HloCost
    c = HloCost(flops=197e12, hbm_bytes=819e9 * 2)
    rl = roofline_from_cost(c, model_flops_per_device=98.5e12)
    assert np.isclose(rl.compute_s, 1.0)
    assert np.isclose(rl.memory_s, 2.0)
    assert rl.bound == "memory"
    assert np.isclose(rl.roofline_fraction, 0.5)
    assert np.isclose(rl.model_flops_ratio, 0.5)
