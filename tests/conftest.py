import os

import jax
import numpy as np
import pytest

# Tests run on the single CPU device; the 512-device forcing happens ONLY
# in launch/dryrun.py (and the dedicated subprocess tests), never here.
jax.config.update("jax_platforms", "cpu")

# Sanitizer lane (CI `sanitizers` job): the suite runs with
# JAX_TRANSFER_GUARD=disallow and JAX_NUMPY_RANK_PROMOTION=raise.
# Test bodies themselves transfer freely by design (np fixtures,
# float() asserts), so an autouse fixture scopes an allow around each
# test; the *library* discipline is enforced by tests/test_sanitizers.py,
# which re-arms disallow around the plan execute paths so only
# host_boundary() scopes may transfer.
_SANITIZE = (os.environ.get("VIEM_SANITIZE") == "1"
             or os.environ.get("JAX_TRANSFER_GUARD") == "disallow")

if _SANITIZE:
    jax.config.update("jax_numpy_rank_promotion", "raise")
    # Collection-time module constants (PRNGKeys, smoke tensors) and
    # worker threads transfer by design, so the process default reverts
    # to allow; test_sanitizers.py re-arms disallow as a *context*
    # around the library paths whose discipline is under test.
    jax.config.update("jax_transfer_guard", "allow")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
