import jax
import numpy as np
import pytest

# Tests run on the single CPU device; the 512-device forcing happens ONLY
# in launch/dryrun.py (and the dedicated subprocess tests), never here.
jax.config.update("jax_platforms", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
