"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp ref oracles
(interpret=True executes the kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Hierarchy, grid3d, qap_objective
from repro.core.objective import dense_gain_matrix
from repro.kernels import ops
from repro.kernels.ref import hier_distance_ref


def _instance(n, seed, density=0.3):
    rng = np.random.default_rng(seed)
    C = np.triu(rng.random((n, n)) * (rng.random((n, n)) < density), 1)
    C = C + C.T
    D = np.triu(rng.random((n, n)), 1)
    D = D + D.T
    perm = rng.permutation(n)
    return C, D, perm


@pytest.mark.parametrize("n,tile", [(8, 8), (16, 8), (40, 16), (64, 32),
                                    (100, 32), (128, 128), (192, 64),
                                    (256, 128)])
def test_swap_gain_kernel_shapes(n, tile):
    C, D, perm = _instance(n, n)
    G_np = dense_gain_matrix(C, D, perm)
    G_ref = np.asarray(ops.gain_matrix_ref(C, D, perm))
    G_ker = np.asarray(ops.gain_matrix(C, D, perm, tile=tile,
                                       interpret=True))
    assert np.allclose(G_ref, G_np, atol=1e-4)
    assert np.allclose(G_ker, G_np, atol=1e-3), \
        f"max err {np.abs(G_ker - G_np).max()}"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swap_gain_kernel_dtypes(dtype):
    C, D, perm = _instance(64, 0)
    G_np = dense_gain_matrix(C, D, perm)
    G_ker = np.asarray(ops.gain_matrix(jnp.asarray(C, dtype),
                                       jnp.asarray(D, dtype), perm,
                                       tile=32, interpret=True))
    tol = 1e-3 if dtype == jnp.float32 else 0.35  # bf16 inputs are coarse
    assert np.max(np.abs(G_ker - G_np)) < tol * max(1, np.abs(G_np).max())


@pytest.mark.parametrize("nx,ny,nz,h", [
    (4, 4, 4, (16, 4)), (8, 8, 8, (16, 8, 4)), (4, 4, 2, (8, 2, 2)),
])
def test_qap_objective_kernel(nx, ny, nz, h):
    g = grid3d(nx, ny, nz)
    dists = tuple(float(10 ** i) for i in range(len(h)))
    hier = Hierarchy(h, dists)
    assert hier.n_pe == g.n
    rng = np.random.default_rng(1)
    for _ in range(3):
        perm = rng.permutation(g.n)
        j_core = qap_objective(g, hier, perm)
        j_ker = ops.objective(g, hier, perm, interpret=True)
        j_ref = ops.objective_ref(g, hier, perm)
        assert np.isclose(j_ker, j_core, rtol=1e-5)
        assert np.isclose(j_ref, j_core, rtol=1e-5)


def test_hier_distance_ref_matches_core():
    h = Hierarchy((4, 2, 2), (1.0, 10.0, 100.0))
    idx = np.arange(16)
    D = h.distance_matrix()
    Dref = np.asarray(hier_distance_ref(
        jnp.asarray(idx[:, None]), jnp.asarray(idx[None, :]),
        tuple(int(s) for s in h.strides),
        tuple(float(d) for d in h.distances)))
    assert np.allclose(D, Dref)


def test_empty_and_tiny_edges():
    from repro.core import from_edges
    g = from_edges(4, [0], [1], [2.0])
    h = Hierarchy((2, 2), (1.0, 10.0))
    perm = np.array([0, 1, 2, 3])
    assert np.isclose(ops.objective(g, h, perm, interpret=True),
                      qap_objective(g, h, perm))
