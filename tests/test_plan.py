"""Staged lower → MappingPlan → execute API: bucket schedules, plan
round-trips (JSON/pickle/fresh-process), bucket-padding inertness, plan
cache accounting, the viem --explain surface, and the shape-bucketed
MappingService (batching parity, warm cache, burst ordering,
backpressure)."""

import json
import pickle
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Hierarchy, Mapper, MappingPlan, MappingSpec,
                        MultilevelSpec, PlanSpec, ShapeBucket, grid3d,
                        random_geometric, write_metis)
from repro.core.spec import bucket_round

REPO = Path(__file__).resolve().parents[1]
H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


def _dev_spec(**kw):
    base = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="fast",
                engine="device", seed=1)
    base.update(kw)
    return MappingSpec(**base)


def _weighted_grids(count):
    out = []
    for i in range(count):
        g = grid3d(4, 4, 4)
        g.adjwgt = g.adjwgt * (1.0 + 0.5 * i)
        out.append(g)
    return out


# ---------------------------------------------------------------- buckets
def test_bucket_round_schedules():
    assert bucket_round(9, "tight", 8) == 16
    assert bucket_round(8, "tight", 8) == 8
    assert bucket_round(1, "tight", 8) == 8
    assert bucket_round(9, "pow2", 8) == 16
    assert bucket_round(20, "pow2", 8) == 32
    assert bucket_round(3, "pow2", 8) == 8       # floor at base
    assert bucket_round(20, "mult:16", 8) == 32
    # mult never drops below the tight rounding — device arrays are
    # padded to base quanta regardless (regression: mult:4 buckets
    # smaller than the padded shapes crashed pad_to)
    assert bucket_round(144, "mult:4", 128) == 256
    with pytest.raises(ValueError, match="schedule"):
        bucket_round(4, "fib", 8)


def test_mult_schedule_plans_execute():
    g = grid3d(4, 4, 4)
    spec = _dev_spec()
    mapper = Mapper(H64, spec)
    want = mapper.map(g)
    got = mapper.lower(mapper.bucket_of(g, schedule="mult:4"),
                       spec).execute(g)
    assert np.array_equal(want.perm, got.perm)
    assert want.final_objective == got.final_objective


def test_bucket_of_admits_and_union():
    g = grid3d(4, 4, 4)
    b = ShapeBucket.of(g)
    assert b.admits(g)
    assert b.max_deg % 8 == 0 and b.num_edges % 128 == 0
    dense = random_geometric(64, 0.5, seed=0)
    assert not ShapeBucket.of(g).admits(dense) or \
        ShapeBucket.of(dense).num_edges <= b.num_edges
    u = b.union(ShapeBucket.of(dense))
    assert u.admits(g) and u.admits(dense)
    # pow2 buckets dominate tight ones (pow2 ≥ the next multiple of base)
    p = ShapeBucket.of(dense, schedule="pow2")
    assert p.max_deg >= ShapeBucket.of(dense).max_deg
    assert p.num_edges >= ShapeBucket.of(dense).num_edges


def test_bucket_dict_round_trip_and_validation():
    b = ShapeBucket(16, 512, 1024, "pow2")
    assert ShapeBucket.from_dict(b.to_dict()) == b
    with pytest.raises(ValueError, match="unknown ShapeBucket keys"):
        ShapeBucket.from_dict({"max_deg": 8, "num_edges": 128, "K": 1})
    with pytest.raises(ValueError):
        ShapeBucket(0, 128).validate()
    assert b.pair_pad(100) == 1024
    with pytest.raises(ValueError, match="exceed"):
        b.pair_pad(2048)


# ------------------------------------------------------------- round trip
@pytest.mark.parametrize("spec", [
    _dev_spec(),
    _dev_spec(multilevel=MultilevelSpec(levels=3, coarsen_min=8),
              preconfiguration="eco"),
    MappingSpec(preconfiguration="fast", neighborhood="communication",
                neighborhood_dist=2, backend="pallas", seed=2),
])
def test_plan_serialization_round_trip_bit_identical(spec):
    g = grid3d(4, 4, 4)
    plan = Mapper(H64, spec).lower_for(g)
    r1 = plan.execute(g)
    # JSON
    plan2 = MappingPlan.from_json(plan.to_json())
    r2 = plan2.execute(g)
    assert np.array_equal(r1.perm, r2.perm)
    assert r1.final_objective == r2.final_objective
    assert r1.initial_objective == r2.initial_objective
    # pickle
    plan3 = pickle.loads(pickle.dumps(plan))
    r3 = plan3.execute(g)
    assert np.array_equal(r1.perm, r3.perm)
    assert r1.final_objective == r3.final_objective
    # the rebuilt plan reports identical geometry ("timings" holds
    # per-instance wall-clock observations, not geometry)
    d1, d2 = plan.describe(), plan2.describe()
    d1.pop("timings"), d2.pop("timings")
    assert d1 == d2


def test_plan_reload_in_fresh_process_bit_identical(tmp_path):
    """The acceptance bar: a serialized plan reloaded in a fresh process
    reproduces the original mapping bit-identically."""
    g = grid3d(4, 4, 4)
    plan = Mapper(H64, _dev_spec()).lower_for(g)
    want = plan.execute(g)
    plan_path = tmp_path / "plan.json"
    gpath = tmp_path / "g.metis"
    plan.save(plan_path)
    write_metis(g, str(gpath))
    script = (
        "from repro.core import MappingPlan, read_metis\n"
        f"plan = MappingPlan.load({str(plan_path)!r})\n"
        f"res = plan.execute(read_metis({str(gpath)!r}))\n"
        "print(' '.join(map(str, res.perm.tolist())))\n"
        "print(repr(res.final_objective))\n")
    r = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=REPO, env={"PYTHONPATH": str(REPO / "src"),
                       "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr
    perm_line, jf_line = r.stdout.strip().splitlines()[-2:]
    assert np.array_equal(np.array(perm_line.split(), dtype=np.int64),
                          want.perm)
    assert float(jf_line) == want.final_objective


def test_plan_spec_requires_topology():
    with pytest.raises(ValueError, match="topology"):
        PlanSpec(mapping=MappingSpec()).validate()
    with pytest.raises(ValueError, match="unknown PlanSpec keys"):
        PlanSpec.from_dict({"mapping": MappingSpec().to_dict(), "x": 1})


# -------------------------------------------------------------- inertness
@pytest.mark.parametrize("spec", [
    _dev_spec(),
    _dev_spec(multilevel=MultilevelSpec(levels=3, coarsen_min=8),
              preconfiguration="eco"),
])
def test_bucket_padding_is_inert(spec):
    """Tight, pow2, and explicitly oversized buckets must produce
    bit-identical mappings — only the compiled shapes differ."""
    g = grid3d(4, 4, 4)
    mapper = Mapper(H64, spec)
    tight = mapper.lower(mapper.bucket_of(g), spec).execute(g)
    pow2 = mapper.lower(mapper.bucket_of(g, schedule="pow2"),
                        spec).execute(g)
    big = mapper.lower(ShapeBucket(max_deg=32, num_edges=1024,
                                   num_pairs=2048), spec).execute(g)
    for other in (pow2, big):
        assert np.array_equal(tight.perm, other.perm)
        assert tight.final_objective == other.final_objective
        assert tight.initial_objective == other.initial_objective


def test_plan_rejects_graph_exceeding_bucket():
    spec = _dev_spec()
    small = ShapeBucket(max_deg=8, num_edges=128)
    plan = Mapper(H64, spec).lower(small, spec)
    dense = random_geometric(64, 0.5, seed=1)
    with pytest.raises(ValueError, match="bucket"):
        plan.execute(dense)


def test_execute_batch_mixed_structures_matches_singles():
    spec = _dev_spec()
    graphs = [grid3d(4, 4, 4), random_geometric(64, 0.25, seed=2)]
    mapper = Mapper(H64, spec)
    batch = mapper.map_many(graphs)
    for got, g in zip(batch, graphs):
        want = Mapper(H64, spec).map(g)
        assert got.final_objective == pytest.approx(want.final_objective,
                                                    rel=1e-5)
        assert sorted(got.perm.tolist()) == list(range(64))


# ----------------------------------------------------------- plan caching
def test_seed_is_a_runtime_input_not_a_plan_key():
    g = grid3d(4, 4, 4)
    spec = _dev_spec(seed=1)
    mapper = Mapper(H64, spec)
    r1 = mapper.map(g)
    r5 = mapper.map(g, spec=spec.replace(seed=5))
    info = mapper.cache_info()
    assert info["plan_builds"] == 1          # seed excluded from the key
    assert info["plan_hits"] == 1
    # and the seed still steers the run: fresh-session parity per seed
    want5 = Mapper(H64, spec.replace(seed=5)).map(g)
    assert np.array_equal(r5.perm, want5.perm)
    assert not np.array_equal(r1.perm, r5.perm)


def test_plan_cache_reports_per_bucket():
    spec = _dev_spec()
    mapper = Mapper(H64, spec)
    g1 = grid3d(4, 4, 4)
    g2 = random_geometric(64, 0.4, seed=0)   # denser → different bucket
    mapper.map(g1)
    mapper.map(g2)
    mapper.map(g1)
    info = mapper.cache_info()
    assert info["plan_builds"] == 2
    assert info["plan_hits"] == 1
    assert len(info["plans"]) == 2
    assert all(tag.startswith("K") for tag in info["plans"])
    # engines are bucket-agnostic and pooled across plans: same machine
    # + sweep budget → ONE build shared by both buckets' plans
    assert info["engine_builds"] == 1
    assert info["requests"] == 3


def test_describe_reports_levels_and_kernel_forms():
    spec = _dev_spec(multilevel=MultilevelSpec(levels=3, coarsen_min=8),
                     preconfiguration="eco")
    plan = Mapper(H64, spec).lower_for(grid3d(4, 4, 4))
    d = plan.describe()
    assert d["machine"] == {"kind": "tree", "n_pe": 64}
    assert d["multilevel"] == {"levels": 3, "coarsen_min": 8}
    assert [lv["n"] for lv in d["levels"]] == [64, 32, 16]
    assert d["levels"][0]["kernel_form"] == "tree"
    assert all(lv["kernel_form"] == "matrix" for lv in d["levels"][1:])
    assert all(lv["engine_compiled"] for lv in d["levels"])
    assert d["compiled"]["engines"] == 3
    json.dumps(d)                             # JSON-safe throughout


# ------------------------------------------------------------ CLI explain
def _run_cli(mod, *args):
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        cwd=REPO, env={"PYTHONPATH": str(REPO / "src"),
                       "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"})


def test_cli_explain_lowers_without_executing(tmp_path):
    g = grid3d(4, 4, 2)
    gpath = tmp_path / "g.metis"
    write_metis(g, str(gpath))
    out = tmp_path / "perm.txt"
    r = _run_cli("repro.cli.viem", str(gpath),
                 "--hierarchy_parameter_string=4:4:2",
                 "--distance_parameter_string=1:10:100",
                 "--engine=device", "--explain",
                 f"--output_filename={out}")
    assert r.returncode == 0, r.stderr
    d = json.loads(r.stdout)
    assert d["machine"]["n_pe"] == 32
    assert d["bucket"]["num_edges"] % 128 == 0
    assert d["levels"][0]["kernel_form"] == "tree"
    assert not out.exists()                   # lowered, never executed


# ---------------------------------------------------------------- service
def _service(mapper, **kw):
    from repro.launch.serve import MappingService
    kw.setdefault("max_wait_s", 0.05)
    return MappingService(mapper, **kw)


def test_service_batching_matches_sequential_singles():
    spec = _dev_spec()
    graphs = _weighted_grids(4) + [random_geometric(64, 0.25, seed=7)]
    singles = [Mapper(H64, spec).map(g) for g in graphs]
    with _service(Mapper(H64, spec)) as svc:
        tickets = [svc.submit(g) for g in graphs]
        got = dict(svc.results.get(timeout=300) for _ in tickets)
    for t, want in zip(tickets, singles):
        res = got[t]
        assert not isinstance(res, Exception)
        assert sorted(res.perm.tolist()) == list(range(64))
        assert res.final_objective == pytest.approx(want.final_objective,
                                                    rel=1e-5)


def test_service_warm_cache_answers_repeats_exactly():
    spec = _dev_spec()
    g = grid3d(4, 4, 4)
    with _service(Mapper(H64, spec)) as svc:
        first = svc.map(g, timeout=300)
        again = svc.map(g, timeout=300)
        stats = svc.stats()
    assert stats["result_cache_hits"] >= 1
    assert np.array_equal(first.perm, again.perm)
    assert first.final_objective == again.final_objective
    # cached results are copies: mutating one must not poison the cache
    again.perm[:] = -1
    assert sorted(first.perm.tolist()) == list(range(64))


def test_service_burst_of_mixed_shapes_orders_and_isolates():
    spec = _dev_spec()
    graphs = (_weighted_grids(3)
              + [random_geometric(64, 0.3, seed=i) for i in range(3)]
              + [grid3d(4, 4, 4)] * 3)          # repeats inside the burst
    with _service(Mapper(H64, spec), max_pending=64) as svc:
        tickets = [svc.submit(g) for g in graphs]
        bad = svc.submit(grid3d(3, 3, 3))       # size mismatch mid-burst
        tickets.append(bad)
        got = dict(svc.results.get(timeout=300) for _ in tickets)
        stats = svc.stats()
    # exactly one result per ticket, in whatever completion order
    assert sorted(got) == sorted(tickets)
    assert isinstance(got[bad], ValueError)
    for t in tickets[:-1]:
        assert not isinstance(got[t], Exception), got[t]
    assert stats["served"] == len(tickets)
    assert stats["errors"] == 1
    assert stats["peak_queue_depth"] >= 1
    assert (stats["result_cache_hits"] + stats["in_tick_deduped"]) >= 2
    assert stats["latency_p99_s"] >= stats["latency_p50_s"] >= 0.0


def test_service_groups_by_seed_and_never_cross_serves():
    """Same spec, different seeds, one burst: each ticket must get its
    own seed's mapping, and the warm cache must not cross-pollinate
    (regression: groups keyed seed-free executed with the first
    request's seed)."""
    spec = _dev_spec(construction="random", seed=0)
    g = grid3d(4, 4, 4)
    want0 = Mapper(H64, spec).map(g)
    want7 = Mapper(H64, spec.replace(seed=7)).map(g)
    assert not np.array_equal(want0.perm, want7.perm)
    with _service(Mapper(H64, spec)) as svc:
        t0 = svc.submit(g)
        t7 = svc.submit(g, spec.replace(seed=7))
        got = dict(svc.results.get(timeout=300) for _ in range(2))
        # and again after the cache is warm
        again7 = svc.map(g, spec.replace(seed=7), timeout=300)
    assert np.array_equal(got[t0].perm, want0.perm)
    assert np.array_equal(got[t7].perm, want7.perm)
    assert np.array_equal(again7.perm, want7.perm)


def test_service_backpressure_bounds_queue_and_close_rejects():
    spec = MappingSpec(construction="identity", neighborhood=None,
                       preconfiguration="fast")
    svc = _service(Mapper(H64, spec), max_pending=2)
    assert svc.requests.maxsize == 2
    with svc:
        t = svc.submit(grid3d(4, 4, 4))
        _, res = svc.results.get(timeout=300)
        assert not isinstance(res, Exception)
        assert t == 0
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(grid3d(4, 4, 4))


def test_service_map_timeout_is_a_deadline():
    """map()'s timeout bounds the total wait even while foreign results
    cycle through the queue (regression: each re-get reset the budget,
    so the timeout never fired)."""
    import time

    from repro.core.construction import CONSTRUCTIONS, \
        register_construction

    @register_construction("_test_slow")
    def _slow(g, h, **_):
        time.sleep(1.5)
        return np.arange(g.n, dtype=np.int64)

    try:
        spec = MappingSpec(construction="_test_slow", neighborhood=None,
                           preconfiguration="fast")
        with _service(Mapper(H64, spec), max_wait_s=0.001) as svc:
            svc.results.put((999_999, "foreign"))  # never-matching ticket
            t0 = time.perf_counter()
            with pytest.raises(TimeoutError, match="within"):
                svc.map(grid3d(4, 4, 4), timeout=0.3)
            assert time.perf_counter() - t0 < 1.2   # fired at the
            # deadline, not after the worker finally answered
    finally:
        del CONSTRUCTIONS["_test_slow"]


def test_placement_service_runs_on_mapping_service():
    from repro.launch.serve import MappingService, placement_service
    h = Hierarchy((4, 4), (1.0, 10.0))
    with placement_service(h, spec=MappingSpec(preconfiguration="fast",
                                               neighborhood=None)) as svc:
        assert isinstance(svc, MappingService)
        res = svc.map(grid3d(4, 4, 1), timeout=300)
    assert sorted(res.perm.tolist()) == list(range(16))
