"""Construction algorithms + local search: bijectivity, quality ordering,
monotone improvement, termination — the paper's §2 behaviors."""

import numpy as np
import pytest

from repro.core import Hierarchy, Mapper, MappingSpec, grid3d, \
    qap_objective, random_geometric
from repro.core.construction import CONSTRUCTIONS, construct
from repro.core.local_search import (communication_pairs, local_search,
                                     nsquare_pairs, parallel_sweep_search,
                                     pruned_pairs)

H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


@pytest.mark.parametrize("name", sorted(CONSTRUCTIONS))
def test_constructions_are_bijections(name):
    g = grid3d(4, 4, 4)
    perm = construct(name, g, H64, seed=3)
    assert sorted(perm) == list(range(64))


def test_topdown_beats_naive_constructions():
    g = grid3d(4, 4, 4)
    js = {name: qap_objective(g, H64, construct(name, g, H64, seed=0))
          for name in CONSTRUCTIONS}
    assert js["hierarchytopdown"] < js["random"]
    assert js["hierarchytopdown"] < js["identity"]
    assert js["hierarchybottomup"] < js["random"]


@pytest.mark.parametrize("nbhd", ["nsquare", "nsquarepruned",
                                  "communication"])
def test_local_search_monotone_and_consistent(nbhd):
    g = random_geometric(64, 0.25, seed=5)
    perm = construct("random", g, H64, seed=1)
    stats = local_search(g, H64, perm, neighborhood=nbhd,
                         communication_neighborhood_dist=3)
    # objective trace strictly decreasing
    tr = stats.objective_trace
    assert all(b <= a + 1e-9 for a, b in zip(tr, tr[1:]))
    # incremental objective equals recomputation (the paper's fast update)
    assert np.isclose(stats.final_objective, qap_objective(g, H64, perm))
    assert stats.final_objective <= stats.initial_objective


def test_neighborhood_nesting():
    """N_C ⊆ N_C^2 ⊆ … ⊆ N² (guide §2.1)."""
    g = random_geometric(24, 0.3, seed=2)
    sizes = [len(communication_pairs(g, d)) for d in (1, 2, 4, 8)]
    assert all(a <= b for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] <= len(nsquare_pairs(24))
    p1 = {tuple(p) for p in communication_pairs(g, 1)}
    p2 = {tuple(p) for p in communication_pairs(g, 2)}
    assert p1 <= p2


def test_pruned_pairs_skip_isolated_pairs():
    from repro.core import from_edges
    g = from_edges(6, [0, 1], [1, 2], [1.0, 1.0])  # 3,4,5 isolated
    pp = {tuple(p) for p in pruned_pairs(g)}
    assert (3, 4) not in pp and (4, 5) not in pp
    assert (0, 1) in pp
    # active-isolated pairs retained
    assert (0, 3) in pp or (3, 0) in pp


def test_parallel_sweep_matches_sequential_quality():
    g = grid3d(4, 4, 4)
    p_seq = construct("random", g, H64, seed=9)
    p_par = p_seq.copy()
    s_seq = local_search(g, H64, p_seq, neighborhood="communication",
                         communication_neighborhood_dist=2)
    s_par = parallel_sweep_search(g, H64, p_par,
                                  communication_pairs(g, 2))
    assert s_par.final_objective <= s_seq.initial_objective * 0.8
    assert np.isclose(s_par.final_objective, qap_objective(g, H64, p_par))


def test_mapper_end_to_end():
    g = grid3d(4, 4, 4)
    spec = MappingSpec(preconfiguration="fast", neighborhood_dist=2, seed=0)
    res = Mapper(H64, spec).map(g)
    assert sorted(res.perm) == list(range(64))
    assert res.final_objective <= res.initial_objective
    with pytest.raises(ValueError):
        Mapper(H64, spec).map(grid3d(3, 3, 3))   # n mismatch
