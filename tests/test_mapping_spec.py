"""Declarative API: MappingSpec round-trips, registry errors and
plugins, Mapper↔staged-plan parity, map_many batching with plan-cache
accounting, and the request-queue serving hook."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro.core
from repro.core import (Hierarchy, Mapper, MappingSpec, grid3d,
                        write_metis)
from repro.core.construction import (CONSTRUCTIONS, construct,
                                     list_constructions,
                                     register_construction,
                                     resolve_construction)
from repro.core.local_search import (NEIGHBORHOODS, list_neighborhoods,
                                     register_neighborhood,
                                     resolve_neighborhood)

REPO = Path(__file__).resolve().parents[1]
H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


def _weighted_grids(count):
    """Structurally identical same-shape graphs with distinct traffic."""
    out = []
    for i in range(count):
        g = grid3d(4, 4, 4)
        g.adjwgt = g.adjwgt * (1.0 + 0.5 * i)
        out.append(g)
    return out


# ------------------------------------------------------------------- spec
def test_spec_dict_round_trip():
    spec = MappingSpec(construction="growing", neighborhood="nsquare",
                       neighborhood_dist=4, preconfiguration="fast",
                       parallel_sweeps=True, backend="pallas", seed=7,
                       max_sweeps=12, max_pairs=1000)
    d = spec.to_dict()
    assert MappingSpec.from_dict(d) == spec
    assert MappingSpec.from_json(spec.to_json()) == spec
    assert json.loads(spec.to_json())["construction"] == "growing"


def test_spec_none_neighborhood_round_trip():
    spec = MappingSpec(neighborhood=None)
    assert MappingSpec.from_dict(spec.to_dict()) == spec
    # "none" strings normalize to None (the CLI's spelling)
    assert MappingSpec(neighborhood="none").neighborhood is None
    assert spec.replace(seed=3).neighborhood is None


def test_spec_rejects_unknown_keys():
    with pytest.raises(ValueError, match="wibble"):
        MappingSpec.from_dict({"wibble": 1})


def test_spec_validate_rejects_bad_values():
    with pytest.raises(ValueError, match="backend"):
        MappingSpec(backend="cuda").validate()
    with pytest.raises(ValueError, match="neighborhood_dist"):
        MappingSpec(neighborhood_dist=0).validate()
    with pytest.raises(ValueError):
        MappingSpec(preconfiguration="turbo").validate()


def test_spec_from_flags_overrides_base():
    import argparse
    base = MappingSpec(construction="random", seed=5)
    ns = argparse.Namespace(construction_algorithm="growing",
                            local_search_neighborhood=None,
                            communication_neighborhood_dist=None,
                            preconfiguration_mapping=None,
                            parallel_sweeps=None, backend=None, seed=None)
    spec = MappingSpec.from_flags(ns, base=base)
    assert spec.construction == "growing"       # flag wins
    assert spec.seed == 5                       # base survives


# --------------------------------------------------------------- registry
def test_unknown_construction_names_algorithm_and_lists_registered():
    with pytest.raises(ValueError) as ei:
        resolve_construction("does-not-exist")
    msg = str(ei.value)
    assert "does-not-exist" in msg
    for name in list_constructions():
        assert name in msg
    with pytest.raises(ValueError, match="does-not-exist"):
        construct("does-not-exist", grid3d(4, 4, 4), H64)


def test_unknown_neighborhood_names_algorithm_and_lists_registered():
    with pytest.raises(ValueError) as ei:
        resolve_neighborhood("bogus")
    msg = str(ei.value)
    assert "bogus" in msg
    for name in list_neighborhoods():
        assert name in msg


def test_spec_validate_uses_registries():
    with pytest.raises(ValueError, match="nope"):
        MappingSpec(construction="nope").validate()
    with pytest.raises(ValueError, match="nope"):
        MappingSpec(neighborhood="nope").validate()


def test_third_party_algorithms_plug_in():
    @register_construction("_test_reversed")
    def _reversed(g, h, **_):
        return np.arange(g.n, dtype=np.int64)[::-1].copy()

    @register_neighborhood("_test_first_k")
    def _first_k(g, **_):
        return np.stack([np.zeros(4, np.int64),
                         np.arange(1, 5, dtype=np.int64)], axis=1)

    try:
        spec = MappingSpec(construction="_test_reversed",
                           neighborhood="_test_first_k").validate()
        res = Mapper(H64, spec).map(grid3d(4, 4, 4))
        assert sorted(res.perm.tolist()) == list(range(64))
        assert res.final_objective <= res.initial_objective
        # double registration is rejected
        with pytest.raises(ValueError, match="already registered"):
            register_construction("_test_reversed")(lambda g, h, **_: None)
    finally:
        del CONSTRUCTIONS["_test_reversed"]
        del NEIGHBORHOODS["_test_first_k"]


# ----------------------------------------------------------------- parity
@pytest.mark.parametrize("construction", sorted(CONSTRUCTIONS))
@pytest.mark.parametrize("neighborhood", sorted(NEIGHBORHOODS))
def test_mapper_matches_explicit_staging_bit_for_bit(construction,
                                                     neighborhood):
    """`Mapper.map` is a thin wrapper over lower → execute: the explicit
    two-stage spelling must reproduce it exactly for every algorithm
    combination."""
    g = grid3d(4, 4, 4)
    spec = MappingSpec(construction=construction, neighborhood=neighborhood,
                       neighborhood_dist=2, preconfiguration="fast", seed=3)
    new = Mapper(H64, spec).map(g)
    staged = Mapper(H64, spec).lower_for(g).execute(g)
    assert np.array_equal(new.perm, staged.perm)
    assert new.initial_objective == staged.initial_objective
    assert new.final_objective == staged.final_objective


@pytest.mark.parametrize("neighborhood", [None, "communication"])
@pytest.mark.parametrize("parallel", [False, True])
def test_mapper_matches_staging_across_modes(neighborhood, parallel):
    g = grid3d(4, 4, 4)
    spec = MappingSpec(neighborhood=neighborhood, neighborhood_dist=2,
                       preconfiguration="fast", parallel_sweeps=parallel,
                       seed=0)
    new = Mapper(H64, spec).map(g)
    staged = Mapper(H64, spec).lower_for(g).execute(g)
    assert np.array_equal(new.perm, staged.perm)
    assert new.final_objective == staged.final_objective


def test_map_processes_shim_is_gone():
    """The PR 1 deprecation shim was removed: the staged Mapper API is
    the only entry point."""
    assert not hasattr(repro.core, "map_processes")
    with pytest.raises(ImportError):
        from repro.core import map_processes  # noqa: F401


def test_mapper_rejects_size_mismatch():
    with pytest.raises(ValueError, match="must match"):
        Mapper(H64, MappingSpec()).map(grid3d(3, 3, 3))


# --------------------------------------------------------------- map_many
def test_map_many_matches_independent_maps_and_builds_once():
    graphs = _weighted_grids(8)
    spec = MappingSpec(neighborhood=None, preconfiguration="fast",
                       backend="pallas", seed=0)
    h = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))   # fresh: no cached oracle
    mapper = Mapper(h, spec)
    batch = mapper.map_many(graphs)
    info = mapper.cache_info()
    assert info["oracle_builds"] == 1        # one oracle for all 8 graphs
    assert info["kernel_compiles"] == 1      # one objective-kernel compile
    assert info["requests"] == 8
    singles = [Mapper(h, spec).map(g) for g in graphs]
    for got, want in zip(batch, singles):
        assert np.array_equal(got.perm, want.perm)
        assert got.final_objective == want.final_objective


def test_map_many_shares_candidate_pairs_across_batch():
    graphs = _weighted_grids(4)
    mapper = Mapper(H64, MappingSpec(neighborhood="communication",
                                     neighborhood_dist=2,
                                     preconfiguration="fast"))
    batch = mapper.map_many(graphs)
    # structurally identical graphs → pairs computed once, 3 cache hits
    assert mapper.cache_info()["pair_cache_hits"] == len(graphs) - 1
    for g, got in zip(graphs, batch):
        want = Mapper(H64, mapper.spec).map(g)
        assert np.array_equal(got.perm, want.perm)
        assert got.final_objective == want.final_objective


def test_map_many_rejects_mixed_shapes():
    with pytest.raises(ValueError, match="same-shape"):
        Mapper(H64, MappingSpec()).map_many([grid3d(4, 4, 4),
                                             grid3d(4, 4, 2)])


def test_pallas_backend_objective_matches_numpy():
    g = grid3d(4, 4, 4)
    spec = MappingSpec(neighborhood=None, preconfiguration="fast")
    res_np = Mapper(H64, spec).map(g)
    res_pl = Mapper(H64, spec.replace(backend="pallas")).map(g)
    assert np.array_equal(res_np.perm, res_pl.perm)
    assert res_pl.initial_objective == pytest.approx(
        res_np.initial_objective, rel=1e-6)


def test_per_call_spec_override_controls_backend():
    g = grid3d(4, 4, 4)
    mapper = Mapper(H64, MappingSpec(neighborhood=None,
                                     preconfiguration="fast"))
    assert mapper.cache_info()["kernel_compiles"] == 0
    res = mapper.map(g, spec=mapper.spec.replace(backend="pallas"))
    # the per-request spec's backend applied: the kernel was compiled
    assert mapper.cache_info()["kernel_compiles"] == 1
    assert res.initial_objective == pytest.approx(
        Mapper(H64, mapper.spec).map(g).initial_objective, rel=1e-6)


def test_pallas_initial_and_final_objectives_are_comparable():
    g = grid3d(4, 4, 4)
    spec = MappingSpec(neighborhood="communication", neighborhood_dist=2,
                       preconfiguration="fast", backend="pallas")
    res = Mapper(H64, spec).map(g)
    # jf recomputed through the same backend as j0 → improvement is sane
    assert res.final_objective <= res.initial_objective + 1e-3
    res_np = Mapper(H64, spec.replace(backend="numpy")).map(g)
    assert np.array_equal(res.perm, res_np.perm)
    assert res.final_objective == pytest.approx(res_np.final_objective,
                                                rel=1e-6)


def test_weight_dependent_neighborhood_is_not_served_stale_pairs():
    @register_neighborhood("_test_heavy_edges", weight_dependent=True)
    def _heavy(g, **_):
        u, v, w = g.edge_list()
        top = np.argsort(-w, kind="stable")[:8]
        return np.stack([u[top], v[top]], axis=1)

    try:
        mapper = Mapper(H64, MappingSpec(neighborhood="_test_heavy_edges",
                                         preconfiguration="fast"))
        g1 = grid3d(4, 4, 4)
        g2 = grid3d(4, 4, 4)
        rng = np.random.default_rng(0)
        g2.adjwgt = g2.adjwgt * rng.uniform(1, 100, size=g2.adjwgt.shape)
        mapper.map_many([g1, g2])
        # same structure but different weights → pairs recomputed, not hit
        assert mapper.cache_info()["pair_cache_hits"] == 0
    finally:
        del NEIGHBORHOODS["_test_heavy_edges"]


# ------------------------------------------------------------------ serve
def test_serve_queue_matches_map():
    mapper = Mapper(H64, MappingSpec(neighborhood="communication",
                                     neighborhood_dist=2,
                                     preconfiguration="fast"))
    graphs = _weighted_grids(3)
    want = {i: mapper.map(g) for i, g in enumerate(graphs)}
    with mapper.serve() as svc:
        tickets = [svc.submit(g) for g in graphs]
        got = dict(svc.results.get(timeout=120) for _ in tickets)
    assert sorted(got) == tickets
    for i in tickets:
        assert np.array_equal(got[i].perm, want[i].perm)
        assert got[i].final_objective == want[i].final_objective


def test_serve_isolates_per_request_failures():
    mapper = Mapper(H64, MappingSpec(preconfiguration="fast",
                                     neighborhood=None))
    with mapper.serve() as svc:
        bad = svc.submit(grid3d(3, 3, 3))    # size mismatch → error result
        good = svc.submit(grid3d(4, 4, 4))
        got = dict(svc.results.get(timeout=120) for _ in range(2))
    assert isinstance(got[bad], ValueError)
    assert sorted(got[good].perm.tolist()) == list(range(64))


def test_serve_rejects_submit_after_close():
    svc = Mapper(H64, MappingSpec(neighborhood=None,
                                  preconfiguration="fast")).serve()
    svc.close()
    svc.close()    # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(grid3d(4, 4, 4))


# -------------------------------------------------------------------- CLI
def _run_cli(mod, *args):
    return subprocess.run(
        [sys.executable, "-m", mod, *args], capture_output=True, text=True,
        cwd=REPO, env={"PYTHONPATH": str(REPO / "src"),
                       "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"})


def test_cli_list_algorithms():
    r = _run_cli("repro.cli.viem", "--list-algorithms")
    assert r.returncode == 0, r.stderr
    for name in list_constructions() + list_neighborhoods():
        assert name in r.stdout


def test_cli_config_with_flag_override(tmp_path):
    g = grid3d(4, 4, 2)
    gpath = tmp_path / "g.metis"
    write_metis(g, str(gpath))
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(MappingSpec(construction="identity",
                                     neighborhood="none",
                                     preconfiguration="fast",
                                     seed=1).to_json())
    out = tmp_path / "perm.txt"
    r = _run_cli("repro.cli.viem", str(gpath),
                 "--hierarchy_parameter_string=4:4:2",
                 "--distance_parameter_string=1:10:100",
                 f"--config={spec_path}",
                 "--construction_algorithm=random",   # overrides the file
                 f"--output_filename={out}")
    assert r.returncode == 0, r.stderr
    perm = np.loadtxt(out, dtype=np.int64)
    assert sorted(perm.tolist()) == list(range(32))
    # random@seed1 with no search — must equal the library result exactly
    want = Mapper(Hierarchy((4, 4, 2), (1.0, 10.0, 100.0)),
                  MappingSpec(construction="random", neighborhood=None,
                              preconfiguration="fast", seed=1)
                  ).map(g).perm
    assert np.array_equal(perm, want)
