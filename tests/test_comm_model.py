"""Comm-graph extraction (`core.comm_model` + `analysis.hlo`) on the
checked-in HLO fixture: collective pricing, symmetry/weight-conservation
invariants, and `logical_traffic_summary` parity with a hand-computed
example."""

from pathlib import Path

import numpy as np
import pytest

from repro.analysis.hlo import analyze, collective_instances
from repro.core.comm_model import (device_comm_graph, generate_model,
                                   logical_traffic_summary)
from repro.core.graph import random_geometric, validate
from repro.core.hierarchy import Hierarchy

FIXTURE = Path(__file__).parent / "fixtures" / "collectives.hlo"
N_DEV = 8

# hand-priced fixture collectives (ring model, core.comm_model docstring):
#   all-reduce  g=4, f32[16,16]=1024B, while trip count 4
#               -> per ring link 4 * 2*(3/4)*1024 = 6144
#   collective-permute f32[8,8]=256B, pairs (0,4),(1,5),(2,6),(3,7)
#   all-to-all  g=4 over {0,2,4,6}, f32[4,4]=64B -> 16 per pair
AR = 4 * 2.0 * (3 / 4) * 1024
CP = 256.0
A2A = 64.0 / 4
EXPECTED = {
    (0, 1): AR, (1, 2): AR, (2, 3): AR, (0, 3): AR,
    (4, 5): AR, (5, 6): AR, (6, 7): AR, (4, 7): AR,
    (0, 4): CP + A2A, (1, 5): CP, (2, 6): CP + A2A, (3, 7): CP,
    (0, 2): A2A, (0, 6): A2A, (2, 4): A2A, (4, 6): A2A,
}


@pytest.fixture(scope="module")
def hlo_text():
    return FIXTURE.read_text()


@pytest.fixture(scope="module")
def comm_graph(hlo_text):
    return device_comm_graph(hlo_text, N_DEV)


def test_collective_instances_fixture(hlo_text):
    got = {(op, tuple(map(tuple, groups)), nbytes, mult)
           for op, groups, nbytes, mult in collective_instances(hlo_text)}
    assert got == {
        ("all-reduce", ((0, 1, 2, 3), (4, 5, 6, 7)), 1024, 4.0),
        ("collective-permute", ((0, 4), (1, 5), (2, 6), (3, 7)), 256, 1.0),
        ("all-to-all", ((0, 2, 4, 6),), 64, 1.0),
    }
    # the analyzer agrees on the loop multiplier
    assert analyze(hlo_text, pod_size=4).trip_counts == {"w": 4}


def test_device_comm_graph_exact_weights(comm_graph):
    u, v, w = comm_graph.edge_list()
    got = {(int(a), int(b)): float(c) for a, b, c in zip(u, v, w)}
    assert got == pytest.approx(EXPECTED)


def test_device_comm_graph_invariants(comm_graph):
    g = comm_graph
    validate(g)
    # CSR symmetry: every (u, v, w) has its (v, u, w) mirror
    fwd = {}
    for a in range(g.n):
        for idx in range(g.xadj[a], g.xadj[a + 1]):
            fwd[(a, int(g.adjncy[idx]))] = float(g.adjwgt[idx])
    assert set(fwd) == {(b, a) for a, b in fwd}
    for (a, b), w in fwd.items():
        assert fwd[(b, a)] == w
    # weight conservation: undirected total equals the ring-priced sum
    _, _, w = g.edge_list()
    assert np.sum(w) == pytest.approx(sum(EXPECTED.values()))
    assert np.sum(g.adjwgt) == pytest.approx(2 * sum(EXPECTED.values()))


def test_device_comm_graph_no_collectives():
    g = device_comm_graph("HloModule empty\n\nENTRY %main () -> f32[] {\n"
                          "  ROOT %c = f32[] constant(0)\n}\n", 4)
    assert g.n == 4 and g.num_edges == 0


def test_logical_traffic_summary_hand_computed(comm_graph):
    h = Hierarchy((2, 2, 2), (1.0, 10.0, 100.0))
    perm = np.arange(N_DEV)
    out = logical_traffic_summary(comm_graph, h, perm)
    # level 1 (pairs sharing a size-2 subtree): (0,1),(2,3),(4,5),(6,7)
    assert out["level_1_bytes"] == pytest.approx(4 * AR)
    # level 2 (size-4 subtree, different size-2): (0,3),(1,2),(0,2),
    # (4,7),(5,6),(4,6)
    assert out["level_2_bytes"] == pytest.approx(4 * AR + 2 * A2A)
    # level 3 (cross-half): the permutes plus (0,4),(2,6),(2,4),(0,6)
    assert out["level_3_bytes"] == pytest.approx(4 * CP + 4 * A2A)
    # levels partition every byte
    assert sum(out.values()) == pytest.approx(sum(EXPECTED.values()))


def test_logical_traffic_summary_tracks_permutation(comm_graph):
    h = Hierarchy((2, 2, 2), (1.0, 10.0, 100.0))
    # map the two all-reduce rings onto the two halves contiguously but
    # scramble within: cross-half bytes must not change
    perm = np.array([1, 0, 3, 2, 5, 4, 7, 6])
    out = logical_traffic_summary(comm_graph, h, perm)
    assert out["level_3_bytes"] == pytest.approx(4 * CP + 4 * A2A)
    assert sum(out.values()) == pytest.approx(sum(EXPECTED.values()))


def test_generate_model_quotient_conserves_cut_weight():
    g = random_geometric(64, radius=0.3, seed=3)
    model, labels = generate_model(g, k=4, seed=0)
    assert model.n == 4 and len(labels) == 64
    validate(model)
    u, v, w = g.edge_list()
    cross = labels[u] != labels[v]
    _, _, mw = model.edge_list()
    assert np.sum(mw) == pytest.approx(np.sum(w[cross]))
