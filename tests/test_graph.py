"""Graph substrate: CSR construction, Metis IO, graphchecker semantics."""

import io

import numpy as np
import pytest

from repro.core import (GraphFormatError, from_dense, from_edges,
                        grid3d, random_geometric, read_metis, validate,
                        write_metis)


def test_from_edges_symmetry():
    g = from_edges(4, [0, 1, 2], [1, 2, 3], [1.0, 2.0, 3.0])
    assert g.n == 4 and g.num_edges == 3
    # backward edges present with equal weight
    assert set(g.neighbors(1)) == {0, 2}
    validate(g)


def test_from_edges_merges_parallel():
    g = from_edges(3, [0, 0], [1, 1], [1.0, 2.0])
    assert g.num_edges == 1
    assert g.weights(0)[0] == 3.0


def test_self_loop_rejected():
    with pytest.raises(GraphFormatError):
        from_edges(3, [0], [0], [1.0])


def test_dense_roundtrip(rng):
    g = random_geometric(20, 0.5, seed=3)
    C = g.to_dense()
    g2 = from_dense(C)
    assert g2.num_edges == g.num_edges
    assert np.allclose(g2.to_dense(), C)


def test_metis_roundtrip():
    g = grid3d(3, 3, 3)
    buf = io.StringIO()
    write_metis(g, buf)
    g2 = read_metis(io.StringIO(buf.getvalue()))
    assert g2.n == g.n and g2.num_edges == g.num_edges
    assert np.array_equal(g2.xadj, g.xadj)
    assert np.array_equal(g2.adjncy, g.adjncy)


def test_metis_comment_lines():
    txt = "% a comment\n3 2\n% another\n2\n1 3\n2\n"
    g = read_metis(io.StringIO(txt))
    assert g.n == 3 and g.num_edges == 2


def test_metis_edge_weights():
    txt = "3 2 1\n2 7\n1 7 3 9\n2 9\n"
    g = read_metis(io.StringIO(txt))
    assert g.weights(0)[0] == 7.0
    assert set(g.weights(1)) == {7.0, 9.0}


@pytest.mark.parametrize("bad,why", [
    ("3 2\n2\n1 3\n1\n", "missing backward edge"),          # 3 claims 1, not 2
    ("3 1\n2\n1 3\n2\n", "edge count mismatch"),
    ("2 1\n1\n1\n", "self-loop"),
    ("2 1 1\n2 0\n1 0\n", "edge weight <= 0"),
    ("2 1\n2 2\n1\n", "parallel edges"),
])
def test_graphchecker_rejects(bad, why):
    with pytest.raises(GraphFormatError):
        read_metis(io.StringIO(bad))


def test_generators():
    g = grid3d(4, 4, 4, torus=True)
    assert g.n == 64
    deg = np.diff(g.xadj)
    assert np.all(deg == 6)  # torus is 6-regular
    g2 = random_geometric(30, 0.3, seed=1)
    validate(g2)
