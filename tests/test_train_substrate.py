"""Optimizer math, loss masking, data determinism, gradient compression."""

import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.train import OptConfig, adamw_update, cross_entropy, \
    init_opt_state, schedule
from repro.train.compression import dequantize, quantize
from repro.train.loss import IGNORE


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    opt = OptConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                    weight_decay=0.1, clip_norm=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    st = init_opt_state(p)
    new_p, st2, _ = adamw_update(p, g, st, opt)
    lr = float(schedule(opt, jnp.int32(1)))
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    ref = (np.asarray(p["w"])
           - lr * (mh / (np.sqrt(vh) + opt.eps)
                   + 0.1 * np.asarray(p["w"])))
    assert np.allclose(np.asarray(new_p["w"]), ref, atol=1e-6)
    assert int(st2["step"]) == 1


def test_grad_clipping():
    opt = OptConfig(lr=1e-2, warmup_steps=0, clip_norm=0.1)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    st = init_opt_state(p)
    _, _, metrics = adamw_update(p, g, st, opt)
    assert float(metrics["grad_norm"]) == 200.0
    # effective update is bounded by clip: m = 0.1 * clipped_g
    # clipped_g = 100 * (0.1/200) = 0.05


def test_schedule_shape():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    lrs = [float(schedule(opt, jnp.int32(s))) for s in (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert np.isclose(lrs[1], 0.5)
    assert np.isclose(lrs[2], 1.0)
    assert lrs[2] > lrs[3] > lrs[4]
    assert np.isclose(lrs[4], 0.1, atol=1e-3)


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, IGNORE, IGNORE]])
    loss, count = cross_entropy(logits, labels)
    assert int(count) == 2
    assert np.isclose(float(loss), np.log(8.0), atol=1e-5)


def test_synthetic_data_deterministic_and_host_sliced():
    src = SyntheticLM(1000, 16, 8, seed=3)
    a = src.batch_at(5)
    b = src.batch_at(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host slicing agrees with the global batch
    half = src.batch_at(5, host_start=4, host_size=4)
    assert np.array_equal(half["tokens"], a["tokens"][4:8])
    # causal structure: labels are next tokens
    assert np.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_prefetcher_orders_batches():
    src = SyntheticLM(1000, 8, 4, seed=1)
    pre = Prefetcher(src, start_step=0)
    try:
        b0 = pre.next()
        b1 = pre.next()
        assert np.array_equal(b0["tokens"], src.batch_at(0)["tokens"])
        assert np.array_equal(b1["tokens"], src.batch_at(1)["tokens"])
    finally:
        pre.close()


def test_int8_error_feedback_quantization():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = jnp.zeros_like(g)
    q, scale, err2 = quantize(g, err)
    assert q.dtype == jnp.int8
    deq = dequantize(q, scale)
    # quantization error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.51
    # error feedback: residual carried exactly
    assert np.allclose(np.asarray(g - deq), np.asarray(err2), atol=1e-7)
    # accumulated over steps, the error doesn't drift
    total_err = err2
    for _ in range(10):
        q, scale, total_err = quantize(g, total_err)
    assert float(jnp.max(jnp.abs(total_err))) < 0.1
