"""Flash-attention Pallas kernel sweeps vs a dense jnp oracle
(shapes × GQA groups × windows × dtypes, interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_kernel


def ref_attn(q, k, v, window=0):
    b, t, h, hd = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, t, kvh, g, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32)
    s *= hd ** -0.5
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(s_len)[None, :]
    mask = qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskh->btkgh", p.astype(v.dtype), v)
    return o.reshape(b, t, h, hd)


CASES = [
    # b, t, h, kv, hd, window, qb, kb
    (2, 128, 4, 2, 32, 0, 64, 64),      # GQA g=2, full causal
    (1, 256, 8, 2, 64, 0, 128, 64),     # deeper GQA
    (2, 128, 4, 4, 32, 48, 32, 32),     # MHA + window
    (1, 192, 6, 2, 32, 64, 64, 32),     # non-pow2 T → block fallback
    (2, 96, 2, 1, 16, 0, 32, 96),       # single kv head (MQA)
    (1, 128, 4, 4, 32, 16, 64, 64),     # tiny window
]


@pytest.mark.parametrize("b,t,h,kv,hd,win,qb,kb", CASES)
def test_flash_kernel_matches_oracle(b, t, h, kv, hd, win, qb, kb):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, t, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, hd),
                          jnp.float32)
    o_ref = ref_attn(q, k, v, win)
    o_ker = flash_attention_kernel(q, k, v, window=win, q_block=qb,
                                   kv_block=kb, interpret=True)
    err = float(jnp.max(jnp.abs(o_ref - o_ker)))
    assert err < 2e-5, err


def test_flash_kernel_bf16():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 128, 4, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 32),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 32),
                          jnp.bfloat16)
    o_ref = ref_attn(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32))
    o_ker = flash_attention_kernel(q, k, v, q_block=64, kv_block=64,
                                   interpret=True)
    assert o_ker.dtype == jnp.bfloat16
    err = float(jnp.max(jnp.abs(o_ref - o_ker.astype(jnp.float32))))
    assert err < 0.05, err


def test_flash_matches_model_attention_path():
    """The kernel must agree with the model's pure-JAX blocked attention
    (the §Perf A3 swap is a drop-in)."""
    from repro.configs import get_smoke_config
    from repro.models.attention import flash_attention

    cfg = get_smoke_config("granite-3-2b")
    key = jax.random.PRNGKey(1)
    b, t = 2, 128
    q = jax.random.normal(key, (b, t, 4, cfg.head_dim_), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, t, 2, cfg.head_dim_), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (b, t, 2, cfg.head_dim_), jnp.float32)
    o_model = flash_attention(q, k, v, cfg)
    o_kernel = flash_attention_kernel(q, k, v,
                                      window=cfg.sliding_window,
                                      q_block=cfg.q_block,
                                      kv_block=cfg.kv_block,
                                      interpret=True)
    err = float(jnp.max(jnp.abs(o_model - o_kernel)))
    assert err < 2e-5, err


def test_head_padding_is_inert():
    """§Perf A2: padded-head configs produce identical logits."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.attention import init_attention, attention_block

    cfg = get_smoke_config("llava-next-34b")  # 4 heads, kv=2 in smoke
    cfg_pad = dataclasses.replace(cfg, pad_heads_to=3)  # 4 → kv*3=6 heads
    assert cfg_pad.n_heads_eff == 6
    key = jax.random.PRNGKey(5)
    p = init_attention(key, cfg)
    p_pad = init_attention(key, cfg_pad)
    # copy the real heads into the padded layout: head (kvh, g) major
    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    g_eff = cfg_pad.n_heads_eff // kv
    wq = np.zeros(p_pad["wq"].shape, np.float32)
    wo = np.zeros(p_pad["wo"].shape, np.float32)
    for kvh in range(kv):
        for gg in range(g):
            src, dst = kvh * g + gg, kvh * g_eff + gg
            wq[:, dst] = np.asarray(p["wq"][:, src], np.float32)
            wo[dst] = np.asarray(p["wo"][src], np.float32)
    p_pad = {"wq": jnp.asarray(wq, cfg.jnp_dtype), "wk": p["wk"],
             "wv": p["wv"], "wo": jnp.asarray(wo, cfg.jnp_dtype)}
    x = jax.random.normal(key, (2, 64, cfg.d_model), cfg.jnp_dtype) * 0.5
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    o1 = attention_block(p, x, pos, cfg)
    o2 = attention_block(p_pad, x, pos, cfg_pad)
    err = float(jnp.max(jnp.abs(o1.astype(jnp.float32)
                                - o2.astype(jnp.float32))))
    assert err < 2e-2, err
