"""Transfer-guard discipline of the core execute paths.

Under ``jax.transfer_guard("disallow")`` every *implicit* host<->device
transfer raises; the library's deliberate crossings are scoped with
:func:`repro.runtime.boundary.host_boundary`, so the device engine's
lower/execute/execute_warm/execute_batch paths must run clean with the
guard armed.  A new implicit transfer anywhere on these paths (a stray
``np.asarray`` readback, a Python-scalar promotion in eager jnp code)
fails these tests — the runtime counterpart of the VIEM001 lint rule.
"""

import jax
import numpy as np
import pytest

from repro.core import (Hierarchy, Mapper, MappingSpec, MultilevelSpec,
                        grid3d)

H64 = Hierarchy((4, 4, 4), (1.0, 10.0, 100.0))


def _dev_spec(**kw):
    base = dict(construction="random", neighborhood="communication",
                neighborhood_dist=2, preconfiguration="fast",
                engine="device", seed=1)
    base.update(kw)
    return MappingSpec(**base)


@pytest.fixture()
def plan():
    g = grid3d(4, 4, 4)
    # lower (compiles) outside the guard: XLA constant staging is not
    # the discipline under test, the steady-state execute path is
    mapper = Mapper(H64, _dev_spec())
    return g, mapper.lower_for(g)


def test_execute_transfer_clean(plan):
    g, p = plan
    p.execute(g)                                  # warm the executable
    with jax.transfer_guard("disallow"):
        r = p.execute(g)
    assert r.final_objective <= r.initial_objective


def test_execute_warm_transfer_clean(plan):
    g, p = plan
    r0 = p.execute(g)
    with jax.transfer_guard("disallow"):
        r = p.execute_warm(g, r0.perm.copy())
    assert r.final_objective <= r0.final_objective


def test_execute_batch_transfer_clean(plan):
    g, p = plan
    graphs = [g, grid3d(4, 4, 4)]
    p.execute_batch(graphs)                       # warm
    with jax.transfer_guard("disallow"):
        rs = p.execute_batch(graphs)
    assert len(rs) == 2
    for r in rs:
        assert r.final_objective <= r.initial_objective


def test_multilevel_execute_transfer_clean():
    g = grid3d(4, 4, 4)
    spec = _dev_spec(multilevel=MultilevelSpec(levels=3, coarsen_min=8))
    p = Mapper(H64, spec).lower_for(g)
    p.execute(g)                                  # warm
    with jax.transfer_guard("disallow"):
        r = p.execute(g)
    assert np.array_equal(np.sort(r.perm), np.arange(g.n))
