"""Chunked-scan ⇔ sequential-decode consistency for SSM mixers, and
prefill-with-cache ⇔ forward equivalence (the serving correctness
contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.mamba import (decode_mamba_block, init_mamba, mamba_block)
from repro.models.rwkv import (decode_rwkv_time_mix, init_rwkv_time_mix,
                               rwkv_time_mix)
from repro.models.transformer import (decode_step, forward, init_params,
                                      prefill_with_cache)

KEY = jax.random.PRNGKey(7)


def test_rwkv_chunked_equals_sequential():
    cfg = get_smoke_config("rwkv6-3b")
    p = init_rwkv_time_mix(KEY, cfg)
    b, t = 2, 48           # forces chunk-size fallback 32 → 16
    x = jax.random.normal(KEY, (b, t, cfg.d_model), jnp.float32) * 0.5
    out, (last_x, s_f) = rwkv_time_mix(p, x, cfg)
    h = cfg.d_model // cfg.rwkv_head_size
    cache = {"x": jnp.zeros((b, cfg.d_model)),
             "s": jnp.zeros((b, h, cfg.rwkv_head_size,
                             cfg.rwkv_head_size))}
    outs = []
    for i in range(t):
        o, cache = decode_rwkv_time_mix(p, x[:, i:i + 1], cache, cfg)
        outs.append(o)
    seq = jnp.concatenate(outs, 1)
    assert np.max(np.abs(np.asarray(out - seq, np.float32))) < 2e-2
    assert np.max(np.abs(np.asarray(s_f - cache["s"]))) < 2e-2


def test_mamba_chunked_equals_sequential():
    cfg = get_smoke_config("jamba-v0.1-52b")
    p = init_mamba(KEY, cfg)
    b, t = 2, 32
    x = jax.random.normal(KEY, (b, t, cfg.d_model), jnp.float32) * 0.5
    full = mamba_block(p, x, cfg)
    cache = {"conv": jnp.zeros((b, cfg.mamba_d_conv - 1, cfg.d_inner)),
             "ssm": jnp.zeros((b, cfg.d_inner, cfg.mamba_d_state))}
    ys = []
    for i in range(t):
        o, cache = decode_mamba_block(p, x[:, i:i + 1], cache, cfg)
        ys.append(o)
    seq = jnp.concatenate(ys, 1)
    assert np.max(np.abs(np.asarray(full - seq, np.float32))) < 2e-2


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b",
                                  "jamba-v0.1-52b", "mixtral-8x7b",
                                  "starcoder2-7b"])
def test_prefill_cache_consistent_with_forward(arch):
    """prefill(prompt) then decode(t) must equal forward(prompt + t) —
    the cache correctness contract across attention/SSM/hybrid/SWA."""
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    b, t_prompt = 2, 16
    tokens = jax.random.randint(KEY, (b, t_prompt + 1), 0, cfg.vocab_size)
    prompt = tokens[:, :t_prompt]

    full_logits, _ = forward(params, tokens, cfg)
    pre_logits, caches = prefill_with_cache(params, prompt, cfg,
                                            max_len=t_prompt + 4)
    # prefill last-position logits match the full forward at that position
    a = np.asarray(full_logits[:, t_prompt - 1], np.float32)
    bb = np.asarray(pre_logits[:, t_prompt - 1], np.float32)
    assert np.max(np.abs(a - bb)) < 2e-2, np.max(np.abs(a - bb))

    # decode one token and compare with the full forward's next position
    dec_logits, _ = decode_step(params, tokens[:, t_prompt:t_prompt + 1],
                                caches, jnp.int32(t_prompt), cfg)
    a = np.asarray(full_logits[:, t_prompt], np.float32)
    bb = np.asarray(dec_logits[:, 0], np.float32)
    assert np.max(np.abs(a - bb)) < 5e-2, np.max(np.abs(a - bb))


def test_sliding_window_masks_distant_tokens():
    """Tokens beyond the *stacked* receptive field (window × n_layers)
    must not influence logits; window-local tokens must."""
    cfg = get_smoke_config("mixtral-8x7b")   # window 64, 2 layers in smoke
    assert cfg.sliding_window == 64
    params = init_params(KEY, cfg)
    b, t = 1, 160                            # 159 − 1 > 64 × 2
    base = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    pert = base.at[0, 1].set((base[0, 1] + 1) % cfg.vocab_size)
    l1, _ = forward(params, base, cfg)
    l2, _ = forward(params, pert, cfg)
    last1 = np.asarray(l1[0, -1], np.float32)
    last2 = np.asarray(l2[0, -1], np.float32)
    assert np.max(np.abs(last1 - last2)) < 1e-3
    # ...but a token inside the window does influence it
    pert2 = base.at[0, t - 2].set((base[0, t - 2] + 1) % cfg.vocab_size)
    l3, _ = forward(params, pert2, cfg)
    assert np.max(np.abs(np.asarray(l3[0, -1] - l1[0, -1],
                                    np.float32))) > 1e-4
