"""Deterministic synthetic LM data pipeline, host-sharded with prefetch.

Production layout: each host materializes only its slice of the global
batch (``host_slice``), determined by the mesh's batch axes — the same
contract a file-backed loader would honor.  A background thread keeps a
double buffer ahead of the training loop (overlaps host data work with
device steps).  Data is deterministic in (seed, step) so elastic restarts
resume mid-epoch without a data-order fork.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    """Zipf-ish token stream with causal structure (next = f(prev) + noise),
    so cross-entropy actually decreases during smoke training runs."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, frontend_tokens: int = 0, d_model: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.frontend_tokens = frontend_tokens
        self.d_model = d_model

    def batch_at(self, step: int, host_start: int = 0,
                 host_size: int | None = None) -> dict:
        """The (sub-)batch for a given step; deterministic in (seed, step)."""
        host_size = host_size or self.batch
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) % (2 ** 63))
        # zipf-ish marginals with a deterministic bigram drift
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tok = (z + np.arange(self.seq + 1)[None, :] * 7) % self.vocab
        tok = tok.astype(np.int32)
        sl = slice(host_start, host_start + host_size)
        out = {"tokens": tok[sl, :-1], "labels": tok[sl, 1:]}
        if self.frontend_tokens:
            out["frontend"] = rng.standard_normal(
                (host_size, self.frontend_tokens, self.d_model),
                dtype=np.float32) * 0.02
        return out


class Prefetcher:
    """Double-buffered background prefetch of host batches."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2, host_start: int = 0,
                 host_size: int | None = None):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._host = (host_start, host_size)
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        while not self._stop.is_set():
            b = self.source.batch_at(self._step, *self._host)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
