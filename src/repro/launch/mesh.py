"""Production mesh construction + VieM-optimized device placement.

``make_production_mesh`` builds the logical mesh (DESIGN §5):
  single-pod: (data=16, model=16)            — 256 chips
  multi-pod:  (pod=2, data=16, model=16)     — 512 chips

``viem_device_order`` is the paper integrated as a launch feature: given a
compiled step's HLO, extract the logical-device traffic graph
(core.comm_model), model the physical fleet as the paper's hierarchy
(core.hierarchy.tpu_v5e_fleet), and solve the sparse QAP for the
logical→physical assignment.  The returned device list feeds
``make_production_mesh(devices=...)`` so heavy-traffic logical neighbors
land on physically close chips.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False, devices=None):
    """Build the production mesh.  Defined as a function so importing this
    module never touches jax device state (the dry-run must set XLA_FLAGS
    before any jax initialization)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if devices is not None:
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def viem_device_order(hlo_text: str, n_devices: int, pods: int = 2,
                      preconfiguration: str = "eco",
                      neighborhood_dist: int = 10, seed: int = 0):
    """Logical→physical assignment minimizing modeled collective cost.

    Returns (device_order, result): ``device_order[i]`` is the physical
    chip that logical device i should use — pass
    ``np.array(jax.devices())[device_order]`` to
    :func:`make_production_mesh`.
    """
    from ..core import Mapper, MappingSpec, tpu_v5e_fleet
    from ..core.comm_model import device_comm_graph

    g = device_comm_graph(hlo_text, n_devices)
    h = tpu_v5e_fleet(pods=pods)
    if h.n_pe != n_devices:
        raise ValueError(f"fleet has {h.n_pe} PEs but program uses "
                         f"{n_devices} devices")
    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication",
                       neighborhood_dist=neighborhood_dist,
                       preconfiguration=preconfiguration, seed=seed)
    res = Mapper(h, spec).map(g)
    # res.perm[logical] = physical  →  device_order[logical] = physical
    return np.asarray(res.perm, dtype=np.int64), res
