"""Production mesh construction + VieM-optimized device placement.

``make_production_mesh`` builds the logical mesh (DESIGN §5):
  single-pod: (data=16, model=16)            — 256 chips
  multi-pod:  (pod=2, data=16, model=16)     — 512 chips

``viem_device_order`` is the paper integrated as a launch feature: given a
compiled step's HLO, extract the logical-device traffic graph
(core.comm_model), model the physical fleet — either the paper-style tree
hierarchy (core.hierarchy.tpu_v5e_fleet) or the honest ICI model, a 2D
torus per pod (repro.topology.tpu_v5e_torus) — and solve the sparse QAP
for the logical→physical assignment.  The returned device list feeds
``make_production_mesh(devices=...)`` so heavy-traffic logical neighbors
land on physically close chips.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False, devices=None):
    """Build the production mesh.  Defined as a function so importing this
    module never touches jax device state (the dry-run must set XLA_FLAGS
    before any jax initialization)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if devices is not None:
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def fleet_model(machine_model: str = "tree", pods: int = 2):
    """The physical-fleet machine model by name: ``tree`` (the paper-style
    nested distance classes), ``torus`` (the honest per-pod 2D ICI torus
    with a DCN pod axis), or any registered topology name (built with its
    default parameters).  A live ``Topology``/``Hierarchy`` passes
    through."""
    if not isinstance(machine_model, str):
        return machine_model
    if machine_model == "tree":
        from ..core import tpu_v5e_fleet
        return tpu_v5e_fleet(pods=pods)
    if machine_model == "torus":
        from ..topology import tpu_v5e_torus
        return tpu_v5e_torus(pods=pods)
    from ..topology import make_topology
    return make_topology(machine_model)


def viem_device_order(hlo_text: str, n_devices: int, pods: int = 2,
                      preconfiguration: str = "eco",
                      neighborhood_dist: int = 10, seed: int = 0,
                      machine_model: str = "tree"):
    """Logical→physical assignment minimizing modeled collective cost.

    ``machine_model`` selects the fleet model (see :func:`fleet_model`);
    the default stays the paper-style tree hierarchy.

    Returns (device_order, result): ``device_order[i]`` is the physical
    chip that logical device i should use — pass
    ``np.array(jax.devices())[device_order]`` to
    :func:`make_production_mesh`.
    """
    from ..core import Mapper, MappingSpec
    from ..core.comm_model import device_comm_graph

    g = device_comm_graph(hlo_text, n_devices)
    h = fleet_model(machine_model, pods=pods)
    if h.n_pe != n_devices:
        raise ValueError(f"fleet has {h.n_pe} PEs but program uses "
                         f"{n_devices} devices")
    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication",
                       neighborhood_dist=neighborhood_dist,
                       preconfiguration=preconfiguration, seed=seed)
    res = Mapper(h, spec).map(g)
    # res.perm[logical] = physical  →  device_order[logical] = physical
    return np.asarray(res.perm, dtype=np.int64), res


def fleet_monitor(hlo_text: str, n_devices: int, pods: int = 2,
                  preconfiguration: str = "eco",
                  neighborhood_dist: int = 10, seed: int = 0,
                  machine_model: str = "tree", config=None,
                  cost=None, registry=None, on_remap=None):
    """Closed-loop counterpart of :func:`viem_device_order`: map once,
    then keep watching.

    Builds a :class:`~repro.monitor.RemapMonitor` whose incumbent is the
    initial VieM device order for this program, lowered with ``pow2``
    bucket headroom so drifted traffic keeps fitting the compiled
    executables.  Feed it windows (``observe_hlo`` on recompiles,
    ``observe_edges`` from transport counters), ``tick()`` per window,
    and ``attach(straggler_monitor)`` so ``REBALANCE`` signals flow
    through the same replay gate.  Committed remaps invoke
    ``on_remap(device_order, verdict)`` — rebuild the mesh with
    ``make_production_mesh(devices=np.array(jax.devices())
    [device_order])``.

    Returns ``(monitor, device_order)``.
    """
    from ..core import Mapper, MappingSpec
    from ..core.comm_model import device_comm_graph
    from ..monitor import MonitorConfig, RemapMonitor

    g = device_comm_graph(hlo_text, n_devices)
    h = fleet_model(machine_model, pods=pods)
    if h.n_pe != n_devices:
        raise ValueError(f"fleet has {h.n_pe} PEs but program uses "
                         f"{n_devices} devices")
    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication",
                       neighborhood_dist=neighborhood_dist,
                       preconfiguration=preconfiguration, seed=seed,
                       engine="device")
    plan = Mapper(h, spec).lower_for(g, schedule="pow2")
    monitor = RemapMonitor(plan, g,
                           config=config or MonitorConfig(),
                           cost=cost, registry=registry,
                           on_remap=on_remap, seed=seed)
    return monitor, monitor.incumbent.copy()
