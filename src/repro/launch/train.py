"""Training driver: data pipeline → train loop → checkpoints → fault
tolerance.  Runs real steps on whatever devices exist (CPU smoke, TPU
fleet); the mesh collapses to the available device count for local runs.

Usage (local smoke):
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint.checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data.pipeline import Prefetcher, SyntheticLM
from ..runtime.fault_tolerance import Action, StragglerMonitor
from ..train import OptConfig
from ..train.steps import build_train_step, init_train_state


def make_local_mesh():
    n = len(jax.devices())
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def train(arch: str, steps: int, global_batch: int, seq_len: int,
          smoke: bool = False, ckpt_dir: str | None = None,
          ckpt_every: int = 10, microbatches: int = 1,
          log_every: int = 1) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_local_mesh()
    opt = OptConfig(total_steps=steps, warmup_steps=max(1, steps // 10))
    step_fn, sspec, _ = build_train_step(
        cfg, mesh, opt=opt, global_batch=global_batch,
        microbatches=microbatches)

    data = SyntheticLM(cfg.vocab_size, seq_len, global_batch,
                       frontend_tokens=cfg.frontend_tokens,
                       d_model=cfg.d_model)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    monitor = StragglerMonitor(n_hosts=1)

    start_step = 0
    state = None
    if mgr is not None and mgr.latest_step() is not None:
        start_step = mgr.latest_step()
        target = jax.eval_shape(
            lambda k: init_train_state(k, cfg), jax.random.PRNGKey(0))
        state = mgr.restore(start_step, target)
        print(f"restored checkpoint at step {start_step}")
    if state is None:
        state = init_train_state(jax.random.PRNGKey(0), cfg)

    pre = Prefetcher(data, start_step=start_step)
    metrics = {}
    try:
        for step in range(start_step, steps):
            batch = pre.next()
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            action, slow = monitor.record_step({0: dt})
            if action is not Action.CONTINUE:
                print(f"[ft] straggler action: {action} hosts={slow}")
            if step % log_every == 0:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms",
                      flush=True)
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, state,
                               mesh_shape=tuple(mesh.shape.values()))
    finally:
        pre.close()
        if mgr is not None:
            mgr.wait()
    return {"final_loss": float(metrics.get("loss", np.nan)),
            "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq,
                smoke=args.smoke, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every,
                microbatches=args.microbatches)
    print(f"done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
