"""Serving driver: batched prefill + decode loop with KV/SSM caches,
plus the fleet-placement mapping service (a `Mapper.serve()` queue).

Usage (local smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --placement-smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models.transformer import init_params, prefill_with_cache
from ..train.steps import serve_step


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          smoke: bool = False, seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    max_len = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, t: prefill_with_cache(p, t, cfg, max_len))(params, prompts)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    step_fn = jax.jit(lambda p, t, c, s: serve_step(p, t, c, s, cfg))
    generated = [next_tok]
    t0 = time.time()
    for i in range(gen - 1):
        next_tok, caches = step_fn(params, next_tok, caches,
                                   jnp.int32(prompt_len + i))
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(generated, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


# ------------------------------------------------------ placement service
def placement_service(hierarchy=None, spec=None, requests=None,
                      results=None):
    """Long-lived device-placement service for the serving fleet.

    One `Mapper` session per fleet hierarchy: the distance oracle and any
    compiled Pallas kernels are built once, then every traffic graph pushed
    onto the request queue (e.g. extracted from newly compiled serving
    programs via ``repro.core.comm_model.device_comm_graph``) is mapped by
    the same session.  Returns the started
    :class:`~repro.core.mapping.MapperService`.
    """
    from ..core import Mapper, tpu_v5e_fleet
    from .specs import placement_spec
    h = hierarchy if hierarchy is not None else tpu_v5e_fleet(pods=2)
    return Mapper(h, spec or placement_spec()).serve(
        requests=requests, results=results)


def _placement_smoke():
    """Round-trip a few synthetic fleet traffic graphs through the
    placement queue and print objectives vs identity placement."""
    import numpy as np

    from ..core import from_edges, qap_objective, tpu_v5e_fleet

    h = tpu_v5e_fleet(pods=1)   # 256 PEs
    n = h.n_pe
    graphs = []
    for shift in (1, 2, 4):
        us = np.arange(n)
        vs = (us + shift * 16) % n
        graphs.append(from_edges(n, us, vs, np.full(n, 1e6)))
    with placement_service(h) as svc:
        tickets = {svc.submit(g): g for g in graphs}
        for _ in tickets:
            ticket, res = svc.results.get(timeout=300)
            if isinstance(res, Exception):
                raise res
            g = tickets[ticket]
            j_id = qap_objective(g, h, np.arange(n))
            print(f"request {ticket}: J={res.final_objective:.3e} "
                  f"(identity {j_id:.3e}, "
                  f"{res.final_objective / j_id:.2f}x)")
    print("placement service:", "ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--placement-smoke", action="store_true",
                    help="exercise the Mapper placement queue and exit")
    args = ap.parse_args()
    if args.placement_smoke:
        _placement_smoke()
        return
    if not args.arch:
        ap.error("--arch is required unless --placement-smoke")
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                smoke=args.smoke)
    print(f"prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_tok_per_s']:.1f} tok/s")
    print("sample:", out["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
