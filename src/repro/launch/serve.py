"""Serving driver: batched prefill + decode loop with KV/SSM caches,
plus the shape-bucketed fleet-placement `MappingService`.

`MappingService` is the high-throughput front end of the staged
``lower → MappingPlan → execute`` API: incoming graphs are bucketed by
padded device shape (configurable schedule, pow2 by default), same-bucket
requests are dynamically batched into ONE vmapped ``plan.execute_batch``
per tick (max-batch/max-wait knobs), repeat graphs are answered from a
warm result cache keyed on graph content, and queue-depth backpressure is
visible through ``stats()``.

Usage (local smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --placement-smoke
"""

from __future__ import annotations

import argparse
import functools
import itertools
import queue
import threading
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models.transformer import init_params, prefill_with_cache
from ..obs import MetricsRegistry, get_tracer
from ..train.steps import serve_step

_TR = get_tracer()


@functools.lru_cache(maxsize=8)
def _compiled_prefill(cfg, max_len: int):
    # one jitted prefill per (cfg, max_len): repeated serve() calls hit
    # the compiled artifact instead of retracing a fresh lambda
    return jax.jit(functools.partial(prefill_with_cache, cfg=cfg,
                                     max_len=max_len))


@functools.lru_cache(maxsize=8)
def _compiled_serve_step(cfg):
    return jax.jit(functools.partial(serve_step, cfg=cfg))


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          smoke: bool = False, seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    max_len = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.time()
    logits, caches = _compiled_prefill(cfg, max_len)(params, prompts)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    step_fn = _compiled_serve_step(cfg)
    generated = [next_tok]
    t0 = time.time()
    for i in range(gen - 1):
        next_tok, caches = step_fn(params, next_tok, caches,
                                   jnp.int32(prompt_len + i))
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(generated, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


# ------------------------------------------------------- mapping service
class MappingService:
    """Shape-bucketed, dynamically-batched mapping service over one
    :class:`~repro.core.Mapper` session (see module docstring).

    ``submit(g)`` returns a ticket; ``(ticket, MappingResult)`` tuples
    (or ``(ticket, Exception)`` on per-request failure) arrive on
    ``results``.  Per tick the worker drains up to ``max_batch`` requests
    (waiting at most ``max_wait_s`` for stragglers), answers repeats from
    the warm result cache, groups the rest by (spec, shape bucket), and
    runs each group through one ``plan.execute_batch`` — so steady-state
    traffic executes pre-compiled plans with zero Python-side rebuild.
    ``max_pending > 0`` bounds the request queue: ``submit`` then blocks
    when the service falls behind (backpressure), and ``stats()`` exposes
    queue depth, batch shape, cache hits, and latency percentiles.

    ``quality_classes`` maps per-request quality names to
    :class:`~repro.core.spec.PortfolioSpec` overlays (``None`` = strip
    any portfolio — the single-trajectory fast path).  ``submit(g,
    quality="strong")`` rewrites the request's spec with that overlay, so
    both classes share the one plan cache (distinct specs, distinct
    plans) and the fast path stays zero-overhead.  Defaults:
    ``{"fast": None, "strong": PortfolioSpec()}``.

    Accounting lives in ``self.metrics`` — a
    :class:`~repro.obs.MetricsRegistry`; ``stats()`` is the legacy dict
    view over its snapshot.  ``collect_telemetry=True`` asks every
    executed plan for device engine counters, aggregated into
    ``engine_*`` metrics (a runtime toggle — no recompiles).
    """

    def __init__(self, mapper, *, schedule: str = "pow2",
                 max_batch: int = 8, max_wait_s: float = 0.005,
                 result_cache_size: int = 256, max_pending: int = 0,
                 quality_classes: "dict | None" = None,
                 collect_telemetry: bool = False,
                 requests: "queue.Queue | None" = None,
                 results: "queue.Queue | None" = None):
        from ..core.spec import PortfolioSpec
        self.mapper = mapper
        self.schedule = schedule
        self.quality_classes = (
            {"fast": None, "strong": PortfolioSpec()}
            if quality_classes is None else dict(quality_classes))
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.collect_telemetry = bool(collect_telemetry)
        self.requests = (requests if requests is not None else
                         queue.Queue(maxsize=max_pending))
        self.results = results if results is not None else queue.Queue()
        self._result_cache: OrderedDict = OrderedDict()
        self._result_cache_size = int(result_cache_size)
        self._tickets = itertools.count()
        self._closed = False
        self._lock = threading.Lock()
        self.metrics = MetricsRegistry()
        m = self.metrics
        self._c_served = m.counter("served")
        self._c_batches = m.counter("batches")
        self._c_batched = m.counter("batched_requests")
        self._c_cache_hits = m.counter("result_cache_hits")
        self._c_deduped = m.counter("in_tick_deduped")
        self._c_errors = m.counter("errors")
        self._g_max_batch = m.gauge("max_batch_seen")
        self._g_peak_depth = m.gauge("peak_queue_depth")
        # engine aggregates (sweeps from every result's objective trace;
        # the rest only when collect_telemetry attaches engine counters)
        self._c_sweeps = m.counter("engine_sweeps")
        self._c_passes = m.counter("engine_passes")
        self._c_exchanges = m.counter("engine_exchanges")
        self._c_aspirations = m.counter("engine_aspirations")
        self._c_downhill = m.counter("engine_downhill_escapes")
        self._c_telemetry = m.counter("telemetry_requests")
        # sliding latency window: long-lived services keep reporting
        # *recent* p50/p99, not the first N requests forever
        self._h_latency = m.histogram("latency_s", window=65536)
        self._thread = threading.Thread(target=self._run,
                                        name="viem-mapping-service",
                                        daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- client
    def submit(self, g, spec=None, quality: str | None = None,
               timeout: float | None = None) -> int:
        """Enqueue one graph; blocks when ``max_pending`` is set and the
        queue is full (backpressure) — ``timeout`` bounds that wait
        (``queue.Full`` on expiry; no ticket was consumed from the
        caller's perspective).  ``quality`` selects a quality class from
        ``quality_classes`` (``None`` = the spec as-is).  The put happens
        under the close lock so an accepted ticket can never race the
        shutdown sentinel onto a dead queue (close() waits on the same
        lock; the worker keeps draining meanwhile, so a full queue cannot
        deadlock)."""
        if quality is not None and quality not in self.quality_classes:
            raise ValueError(f"unknown quality class {quality!r}; "
                             f"registered: "
                             f"{sorted(self.quality_classes)}")
        with self._lock:
            if self._closed:
                raise RuntimeError("MappingService is closed; requests "
                                   "submitted now would never be served")
            ticket = next(self._tickets)
            self.requests.put(
                (ticket, g, spec, quality, time.perf_counter()),
                timeout=timeout)
        self._g_peak_depth.set_max(self.requests.qsize())
        return ticket

    def map(self, g, spec=None, quality: str | None = None,
            timeout: float | None = None):
        """Synchronous convenience: submit one graph and wait for its
        result (other clients' results are requeued, so concurrent use is
        safe only through ``submit``/``results``).  ``timeout`` bounds
        the TOTAL wait — backpressure on submit included — and raises
        ``TimeoutError`` when it expires."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        try:
            ticket = self.submit(g, spec, quality=quality,
                                 timeout=timeout)
        except queue.Full:
            raise TimeoutError(
                f"MappingService.map: request queue still full after "
                f"{timeout}s (backpressure)") from None
        while True:
            remaining = (None if deadline is None
                         else deadline - time.perf_counter())
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"MappingService.map: no result for ticket {ticket} "
                    f"within {timeout}s")
            try:
                t, res = self.results.get(timeout=remaining)
            except queue.Empty:
                continue                      # deadline check re-raises
            if t == ticket:
                if isinstance(res, Exception):
                    raise res
                return res
            self.results.put((t, res))
            time.sleep(0.001)    # don't spin hot on a foreign result

    def reset_stats(self) -> None:
        """Zero every metric in the registry — counters, gauges, the
        latency window, engine aggregates — atomically (keeps
        caches/plans); call after warm-up so ``stats()`` reflects steady
        state."""
        self.metrics.reset()

    def prometheus(self) -> str:
        """The registry as Prometheus text exposition — serve this at a
        ``/metrics`` endpoint (or dump via ``viem --metrics-out``) so
        service and monitor counters are scrapeable."""
        return self.metrics.to_prometheus()

    def stats(self) -> dict:
        """Legacy-keyed view over ``self.metrics.snapshot()``.

        The snapshot is taken atomically under the registry lock and is
        a deep copy — the returned dict never aliases live state, and
        grouped updates (``served`` + latency, see ``_emit``) are always
        observed together: a monitoring thread polling during a burst
        never sees ``served`` ahead of the latency count."""
        snap = self.metrics.snapshot()
        lat = snap["latency_s"]
        served = snap["served"]
        passes = snap["engine_passes"]
        return {
            "served": served,
            "batches": snap["batches"],
            "batched_requests": snap["batched_requests"],
            "max_batch_seen": int(snap["max_batch_seen"]),
            "result_cache_hits": snap["result_cache_hits"],
            "in_tick_deduped": snap["in_tick_deduped"],
            "result_cache_size": len(self._result_cache),
            "errors": snap["errors"],
            "quality_served": {
                name.split(".", 1)[1]: v for name, v in snap.items()
                if name.startswith("quality_served.")},
            "queue_depth": self.requests.qsize(),
            "peak_queue_depth": int(snap["peak_queue_depth"]),
            "latency_p50_s": lat["p50"],
            "latency_p99_s": lat["p99"],
            "latency_count": lat["count"],
            # engine aggregates (sweeps for every request; the counter
            # block only when collect_telemetry is on)
            "engine_sweeps_total": snap["engine_sweeps"],
            "engine_mean_sweeps_per_request":
                snap["engine_sweeps"] / served if served else 0.0,
            "engine_exchanges_total": snap["engine_exchanges"],
            "engine_downhill_escapes": snap["engine_downhill_escapes"],
            "aspiration_rate":
                snap["engine_aspirations"] / passes if passes else 0.0,
            "telemetry_requests": snap["telemetry_requests"],
        }

    def close(self, timeout: float | None = None):
        with self._lock:
            if not self._closed:
                self._closed = True
                self.requests.put(None)
        self._thread.join(timeout)

    def __enter__(self) -> "MappingService":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- worker
    def _gather(self) -> "tuple[list, bool]":
        """One tick's worth of requests: block for the first, then wait
        up to ``max_wait_s`` for up to ``max_batch`` total."""
        item = self.requests.get()
        if item is None:
            return [], True
        batch = [item]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                nxt = self.requests.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:
                return batch, True
            batch.append(nxt)
        return batch, False

    def _run(self):
        while True:
            batch, stop = self._gather()
            if batch:
                with _TR.span("service.tick", batch=len(batch)):
                    self._process(batch)
            if stop:
                break

    def _resolve_quality(self, spec, quality):
        """Overlay a quality class onto a request spec: ``None`` strips
        the portfolio (fast path), a PortfolioSpec enables it (forcing
        the device engine it requires)."""
        overlay = self.quality_classes[quality]
        spec = spec.replace(portfolio=overlay)
        if overlay is not None and spec.engine != "device":
            spec = spec.replace(engine="device")
        return spec

    def _process(self, batch):
        """Answer warm repeats from the result cache, then group misses
        by (resolved spec, shape bucket) and run each group through one
        ``plan.execute_batch``.  Quality classes resolve here, once per
        (spec, quality) per tick — both classes share the one plan
        cache."""
        from ..core.plan import _structure_key
        groups: "OrderedDict[tuple, list]" = OrderedDict()
        resolved: dict = {}    # (id(spec), quality) → (spec, spec key)
        for ticket, g, spec, quality, t_sub in batch:
            spec = self.mapper.spec if spec is None else spec
            try:
                rkey = (id(spec), quality)
                hit = resolved.get(rkey)
                if hit is None:
                    eff = spec.validate()
                    if quality is not None:
                        eff = self._resolve_quality(eff, quality
                                                    ).validate()
                    hit = (eff, self.mapper._plan_key(eff, None)[0])
                    resolved[rkey] = hit
                spec, skey = hit
                self.mapper._check_size(g)
                ckey = (skey, spec.seed,
                        _structure_key(g, with_weights=True))
                qname = quality or "default"
                self.metrics.counter(f"quality_served.{qname}").inc()
            except Exception as exc:
                self._emit(ticket, exc, t_sub)
                continue
            hit = self._result_cache.get(ckey)
            if hit is not None:
                self._result_cache.move_to_end(ckey)
                self._c_cache_hits.inc()
                self._emit(ticket, self._copy_result(hit), t_sub)
                continue
            bucket = self.mapper.bucket_of(g, schedule=self.schedule)
            # the plan key is seed-free (plans are shared across seeds),
            # but a group executes with ONE runtime seed — so the seed
            # is part of the grouping identity
            groups.setdefault((skey, bucket, spec.seed), []
                              ).append((ticket, g, spec, t_sub, ckey))
        for (_, bucket, _), items in groups.items():
            self._execute_group(items, bucket)

    def _execute_group(self, items, bucket):
        """All items share one (spec, bucket, seed) group key — one
        lower (or plan-cache hit), one vmapped batch.  Identical graphs
        inside the tick (same content key) execute once and fan out.
        Multi-request batches are padded to exactly ``max_batch`` lanes
        (cycling the tick's own graphs) so the batch axis is bucketed
        too: per plan there are exactly two executables — single and
        full batch — and no batch-size recompiles ever hit the hot
        path."""
        spec = items[0][2]
        tel = self.collect_telemetry
        uniq: "OrderedDict[tuple, object]" = OrderedDict()
        for _, g, _, _, ckey in items:
            uniq.setdefault(ckey, g)
        graphs = list(uniq.values())
        try:
            plan = self.mapper.lower(bucket, spec)
            b = len(graphs)
            if plan.engines is None:
                # host engine executes serially — no vmapped executable,
                # so neither lane padding nor batching helps
                results = [plan.execute(g, seed=spec.seed, telemetry=tel)
                           for g in graphs]
            elif 2 * b > self.max_batch:
                # at least half the padded lanes are real work: one
                # vmapped call wins; padding the batch axis to exactly
                # max_batch keeps a single compiled batch shape
                lanes = graphs + [graphs[i % b]
                                  for i in range(self.max_batch - b)]
                results = plan.execute_batch(lanes, seed=spec.seed,
                                             telemetry=tel)[:b]
                self._c_batches.inc()
                self._c_batched.inc(len(items))
                self._g_max_batch.set_max(len(items))
            else:
                # under-utilized batch: padded lanes would outweigh the
                # dispatch savings, so run the few uniques singly (they
                # still share the plan's compiled single executable)
                results = [plan.execute(g, seed=spec.seed, telemetry=tel)
                           for g in graphs]
            self.mapper._requests += len(graphs)
        except Exception:
            # batch-level failure: isolate per request
            results = []
            for ckey, g in uniq.items():
                try:
                    results.append(self.mapper.map(g, spec=spec,
                                                   telemetry=tel))
                except Exception as exc:
                    results.append(exc)
        by_key = dict(zip(uniq.keys(), results))
        for ticket, g, sp, t_sub, ckey in items:
            res = by_key[ckey]
            if not isinstance(res, Exception):
                self._result_cache[ckey] = self._copy_result(res)
                while len(self._result_cache) > self._result_cache_size:
                    self._result_cache.popitem(last=False)
                res = self._copy_result(res)
            self._emit(ticket, res, t_sub)
        self._c_deduped.inc(len(items) - len(graphs))

    @staticmethod
    def _copy_result(res):
        """Results are shared between the warm cache and (possibly many)
        clients — hand out copies so nobody can mutate cached state
        (the perm array *and* the SearchStats with its trace list)."""
        import copy
        import dataclasses
        return dataclasses.replace(
            res, perm=res.perm.copy(),
            search_stats=copy.deepcopy(res.search_stats))

    def _emit(self, ticket, res, t_sub):
        # one lock around the whole group: served, errors, the latency
        # histogram, and the engine aggregates land as ONE observable
        # step — stats() can never catch served ahead of latency_count
        lat = time.perf_counter() - t_sub
        with self.metrics.lock:
            self._c_served.inc()
            if isinstance(res, Exception):
                self._c_errors.inc()
            else:
                st = getattr(res, "search_stats", None)
                trace = None if st is None else \
                    getattr(st, "objective_trace", None)
                if trace is not None and len(trace) > 1:
                    self._c_sweeps.inc(len(trace) - 1)
                tel = None if st is None else \
                    getattr(st, "telemetry", None)
                if tel is not None:
                    self._c_telemetry.inc()
                    self._c_passes.inc(int(tel.passes))
                    self._c_exchanges.inc(int(tel.total_exchanges))
                    self._c_aspirations.inc(int(tel.aspiration_fires))
                    self._c_downhill.inc(int(tel.downhill_escapes))
            self._h_latency.observe(lat)
        self.results.put((ticket, res))


# ------------------------------------------------------ placement service
def placement_service(hierarchy=None, spec=None, requests=None,
                      results=None, **knobs):
    """Long-lived device-placement service for the serving fleet.

    One `Mapper` session per fleet hierarchy: plans (distance oracle,
    compiled kernels, jitted engines) are lowered once per shape bucket,
    then every traffic graph pushed onto the request queue (e.g.
    extracted from newly compiled serving programs via
    ``repro.core.comm_model.device_comm_graph``) executes a pre-compiled
    plan — same-bucket bursts batch into one vmapped call.  Returns the
    started :class:`MappingService`.
    """
    from ..core import Mapper, tpu_v5e_fleet
    from .specs import placement_service_config, placement_spec
    h = hierarchy if hierarchy is not None else tpu_v5e_fleet(pods=2)
    cfg = placement_service_config()
    cfg.update(knobs)
    return MappingService(Mapper(h, spec or placement_spec()),
                          requests=requests, results=results, **cfg)


def _placement_smoke():
    """Round-trip a few synthetic fleet traffic graphs through the
    placement queue and print objectives vs identity placement, plus the
    session's plan-cache and service accounting."""
    import numpy as np

    from ..core import from_edges, qap_objective, tpu_v5e_fleet

    h = tpu_v5e_fleet(pods=1)   # 256 PEs
    n = h.n_pe
    graphs = []
    for shift in (1, 2, 4):
        us = np.arange(n)
        vs = (us + shift * 16) % n
        graphs.append(from_edges(n, us, vs, np.full(n, 1e6)))
    graphs.append(graphs[0])    # a repeat: exercises the warm cache
    with placement_service(h) as svc:
        tickets = {}
        for g in graphs:
            tickets[svc.submit(g)] = g
        for _ in tickets:
            ticket, res = svc.results.get(timeout=300)
            if isinstance(res, Exception):
                raise res
            g = tickets[ticket]
            j_id = qap_objective(g, h, np.arange(n))
            print(f"request {ticket}: J={res.final_objective:.3e} "
                  f"(identity {j_id:.3e}, "
                  f"{res.final_objective / j_id:.2f}x)")
        stats = svc.stats()
        info = svc.mapper.cache_info()
    print(f"service: served={stats['served']} "
          f"batches={stats['batches']} "
          f"warm_hits={stats['result_cache_hits']} "
          f"peak_queue_depth={stats['peak_queue_depth']} "
          f"p50={stats['latency_p50_s']:.3f}s "
          f"p99={stats['latency_p99_s']:.3f}s")
    print(f"plan cache: builds={info['plan_builds']} "
          f"hits={info['plan_hits']} evictions={info['plan_evictions']}")
    for tag, pinfo in info["plans"].items():
        print(f"  bucket {tag}: executes={pinfo['executes']} "
              f"pair_hits={pinfo['pair_hits']} "
              f"engines={pinfo['engine_builds']}")
    print("placement service:", "ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--placement-smoke", action="store_true",
                    help="exercise the Mapper placement queue and exit")
    args = ap.parse_args()
    if args.placement_smoke:
        _placement_smoke()
        return
    if not args.arch:
        ap.error("--arch is required unless --placement-smoke")
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                smoke=args.smoke)
    print(f"prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_tok_per_s']:.1f} tok/s")
    print("sample:", out["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
