"""Serving driver: batched prefill + decode loop with KV/SSM caches.

Usage (local smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models.transformer import init_params, prefill_with_cache
from ..train.steps import serve_step
from .train import make_local_mesh


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          smoke: bool = False, seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    max_len = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, t: prefill_with_cache(p, t, cfg, max_len))(params, prompts)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    step_fn = jax.jit(lambda p, t, c, s: serve_step(p, t, c, s, cfg))
    generated = [next_tok]
    t0 = time.time()
    for i in range(gen - 1):
        next_tok, caches = step_fn(params, next_tok, caches,
                                   jnp.int32(prompt_len + i))
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(generated, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                smoke=args.smoke)
    print(f"prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_tok_per_s']:.1f} tok/s")
    print("sample:", out["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
