"""Serving driver: batched prefill + decode loop with KV/SSM caches,
plus the shape-bucketed fleet-placement `MappingService`.

`MappingService` is the high-throughput front end of the staged
``lower → MappingPlan → execute`` API: incoming graphs are bucketed by
padded device shape (configurable schedule, pow2 by default), same-bucket
requests are dynamically batched into ONE vmapped ``plan.execute_batch``
per tick (max-batch/max-wait knobs), repeat graphs are answered from a
warm result cache keyed on graph content, and queue-depth backpressure is
visible through ``stats()``.

Usage (local smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --placement-smoke
"""

from __future__ import annotations

import argparse
import itertools
import queue
import threading
import time
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models.transformer import init_params, prefill_with_cache
from ..train.steps import serve_step


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          smoke: bool = False, seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    max_len = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, t: prefill_with_cache(p, t, cfg, max_len))(params, prompts)
    next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    step_fn = jax.jit(lambda p, t, c, s: serve_step(p, t, c, s, cfg))
    generated = [next_tok]
    t0 = time.time()
    for i in range(gen - 1):
        next_tok, caches = step_fn(params, next_tok, caches,
                                   jnp.int32(prompt_len + i))
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(generated, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


# ------------------------------------------------------- mapping service
class MappingService:
    """Shape-bucketed, dynamically-batched mapping service over one
    :class:`~repro.core.Mapper` session (see module docstring).

    ``submit(g)`` returns a ticket; ``(ticket, MappingResult)`` tuples
    (or ``(ticket, Exception)`` on per-request failure) arrive on
    ``results``.  Per tick the worker drains up to ``max_batch`` requests
    (waiting at most ``max_wait_s`` for stragglers), answers repeats from
    the warm result cache, groups the rest by (spec, shape bucket), and
    runs each group through one ``plan.execute_batch`` — so steady-state
    traffic executes pre-compiled plans with zero Python-side rebuild.
    ``max_pending > 0`` bounds the request queue: ``submit`` then blocks
    when the service falls behind (backpressure), and ``stats()`` exposes
    queue depth, batch shape, cache hits, and latency percentiles.

    ``quality_classes`` maps per-request quality names to
    :class:`~repro.core.spec.PortfolioSpec` overlays (``None`` = strip
    any portfolio — the single-trajectory fast path).  ``submit(g,
    quality="strong")`` rewrites the request's spec with that overlay, so
    both classes share the one plan cache (distinct specs, distinct
    plans) and the fast path stays zero-overhead.  Defaults:
    ``{"fast": None, "strong": PortfolioSpec()}``.
    """

    def __init__(self, mapper, *, schedule: str = "pow2",
                 max_batch: int = 8, max_wait_s: float = 0.005,
                 result_cache_size: int = 256, max_pending: int = 0,
                 quality_classes: "dict | None" = None,
                 requests: "queue.Queue | None" = None,
                 results: "queue.Queue | None" = None):
        from ..core.spec import PortfolioSpec
        self.mapper = mapper
        self.schedule = schedule
        self.quality_classes = (
            {"fast": None, "strong": PortfolioSpec()}
            if quality_classes is None else dict(quality_classes))
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.requests = (requests if requests is not None else
                         queue.Queue(maxsize=max_pending))
        self.results = results if results is not None else queue.Queue()
        self._result_cache: OrderedDict = OrderedDict()
        self._result_cache_size = int(result_cache_size)
        self._tickets = itertools.count()
        self._closed = False
        self._lock = threading.Lock()
        self._served = 0
        self._batches = 0
        self._batched_requests = 0
        self._max_batch_seen = 0
        self._cache_hits = 0
        self._deduped = 0
        self._errors = 0
        self._peak_depth = 0
        self._quality_served: "dict[str, int]" = {}
        # sliding latency window: long-lived services keep reporting
        # *recent* p50/p99, not the first N requests forever
        self._latencies: "deque[float]" = deque(maxlen=65536)
        self._thread = threading.Thread(target=self._run,
                                        name="viem-mapping-service",
                                        daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- client
    def submit(self, g, spec=None, quality: str | None = None,
               timeout: float | None = None) -> int:
        """Enqueue one graph; blocks when ``max_pending`` is set and the
        queue is full (backpressure) — ``timeout`` bounds that wait
        (``queue.Full`` on expiry; no ticket was consumed from the
        caller's perspective).  ``quality`` selects a quality class from
        ``quality_classes`` (``None`` = the spec as-is).  The put happens
        under the close lock so an accepted ticket can never race the
        shutdown sentinel onto a dead queue (close() waits on the same
        lock; the worker keeps draining meanwhile, so a full queue cannot
        deadlock)."""
        if quality is not None and quality not in self.quality_classes:
            raise ValueError(f"unknown quality class {quality!r}; "
                             f"registered: "
                             f"{sorted(self.quality_classes)}")
        with self._lock:
            if self._closed:
                raise RuntimeError("MappingService is closed; requests "
                                   "submitted now would never be served")
            ticket = next(self._tickets)
            self.requests.put(
                (ticket, g, spec, quality, time.perf_counter()),
                timeout=timeout)
        self._peak_depth = max(self._peak_depth, self.requests.qsize())
        return ticket

    def map(self, g, spec=None, quality: str | None = None,
            timeout: float | None = None):
        """Synchronous convenience: submit one graph and wait for its
        result (other clients' results are requeued, so concurrent use is
        safe only through ``submit``/``results``).  ``timeout`` bounds
        the TOTAL wait — backpressure on submit included — and raises
        ``TimeoutError`` when it expires."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        try:
            ticket = self.submit(g, spec, quality=quality,
                                 timeout=timeout)
        except queue.Full:
            raise TimeoutError(
                f"MappingService.map: request queue still full after "
                f"{timeout}s (backpressure)") from None
        while True:
            remaining = (None if deadline is None
                         else deadline - time.perf_counter())
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"MappingService.map: no result for ticket {ticket} "
                    f"within {timeout}s")
            try:
                t, res = self.results.get(timeout=remaining)
            except queue.Empty:
                continue                      # deadline check re-raises
            if t == ticket:
                if isinstance(res, Exception):
                    raise res
                return res
            self.results.put((t, res))
            time.sleep(0.001)    # don't spin hot on a foreign result

    def reset_stats(self) -> None:
        """Zero the counters and latency window (keeps caches/plans) —
        call after warm-up so ``stats()`` reflects steady state."""
        self._served = self._batches = self._batched_requests = 0
        self._max_batch_seen = self._cache_hits = self._deduped = 0
        self._errors = self._peak_depth = 0
        self._quality_served = {}
        self._latencies = deque(maxlen=65536)

    def stats(self) -> dict:
        # list() first: the worker thread appends concurrently, and
        # sorting the live deque would race its mutation
        lat = sorted(list(self._latencies))

        def pct(q: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(q * len(lat)))]

        return {
            "served": self._served,
            "batches": self._batches,
            "batched_requests": self._batched_requests,
            "max_batch_seen": self._max_batch_seen,
            "result_cache_hits": self._cache_hits,
            "in_tick_deduped": self._deduped,
            "result_cache_size": len(self._result_cache),
            "errors": self._errors,
            "quality_served": dict(self._quality_served),
            "queue_depth": self.requests.qsize(),
            "peak_queue_depth": self._peak_depth,
            "latency_p50_s": pct(0.50),
            "latency_p99_s": pct(0.99),
        }

    def close(self, timeout: float | None = None):
        with self._lock:
            if not self._closed:
                self._closed = True
                self.requests.put(None)
        self._thread.join(timeout)

    def __enter__(self) -> "MappingService":
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- worker
    def _gather(self) -> "tuple[list, bool]":
        """One tick's worth of requests: block for the first, then wait
        up to ``max_wait_s`` for up to ``max_batch`` total."""
        item = self.requests.get()
        if item is None:
            return [], True
        batch = [item]
        deadline = time.perf_counter() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                nxt = self.requests.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:
                return batch, True
            batch.append(nxt)
        return batch, False

    def _run(self):
        while True:
            batch, stop = self._gather()
            if batch:
                self._process(batch)
            if stop:
                break

    def _resolve_quality(self, spec, quality):
        """Overlay a quality class onto a request spec: ``None`` strips
        the portfolio (fast path), a PortfolioSpec enables it (forcing
        the device engine it requires)."""
        overlay = self.quality_classes[quality]
        spec = spec.replace(portfolio=overlay)
        if overlay is not None and spec.engine != "device":
            spec = spec.replace(engine="device")
        return spec

    def _process(self, batch):
        """Answer warm repeats from the result cache, then group misses
        by (resolved spec, shape bucket) and run each group through one
        ``plan.execute_batch``.  Quality classes resolve here, once per
        (spec, quality) per tick — both classes share the one plan
        cache."""
        from ..core.plan import _structure_key
        groups: "OrderedDict[tuple, list]" = OrderedDict()
        resolved: dict = {}    # (id(spec), quality) → (spec, spec key)
        for ticket, g, spec, quality, t_sub in batch:
            spec = self.mapper.spec if spec is None else spec
            try:
                rkey = (id(spec), quality)
                hit = resolved.get(rkey)
                if hit is None:
                    eff = spec.validate()
                    if quality is not None:
                        eff = self._resolve_quality(eff, quality
                                                    ).validate()
                    hit = (eff, self.mapper._plan_key(eff, None)[0])
                    resolved[rkey] = hit
                spec, skey = hit
                self.mapper._check_size(g)
                ckey = (skey, spec.seed,
                        _structure_key(g, with_weights=True))
                qname = quality or "default"
                self._quality_served[qname] = \
                    self._quality_served.get(qname, 0) + 1
            except Exception as exc:
                self._emit(ticket, exc, t_sub)
                continue
            hit = self._result_cache.get(ckey)
            if hit is not None:
                self._result_cache.move_to_end(ckey)
                self._cache_hits += 1
                self._emit(ticket, self._copy_result(hit), t_sub)
                continue
            bucket = self.mapper.bucket_of(g, schedule=self.schedule)
            # the plan key is seed-free (plans are shared across seeds),
            # but a group executes with ONE runtime seed — so the seed
            # is part of the grouping identity
            groups.setdefault((skey, bucket, spec.seed), []
                              ).append((ticket, g, spec, t_sub, ckey))
        for (_, bucket, _), items in groups.items():
            self._execute_group(items, bucket)

    def _execute_group(self, items, bucket):
        """All items share one (spec, bucket, seed) group key — one
        lower (or plan-cache hit), one vmapped batch.  Identical graphs
        inside the tick (same content key) execute once and fan out.
        Multi-request batches are padded to exactly ``max_batch`` lanes
        (cycling the tick's own graphs) so the batch axis is bucketed
        too: per plan there are exactly two executables — single and
        full batch — and no batch-size recompiles ever hit the hot
        path."""
        spec = items[0][2]
        uniq: "OrderedDict[tuple, object]" = OrderedDict()
        for _, g, _, _, ckey in items:
            uniq.setdefault(ckey, g)
        graphs = list(uniq.values())
        try:
            plan = self.mapper.lower(bucket, spec)
            b = len(graphs)
            if plan.engines is None:
                # host engine executes serially — no vmapped executable,
                # so neither lane padding nor batching helps
                results = [plan.execute(g, seed=spec.seed)
                           for g in graphs]
            elif 2 * b > self.max_batch:
                # at least half the padded lanes are real work: one
                # vmapped call wins; padding the batch axis to exactly
                # max_batch keeps a single compiled batch shape
                lanes = graphs + [graphs[i % b]
                                  for i in range(self.max_batch - b)]
                results = plan.execute_batch(lanes, seed=spec.seed)[:b]
                self._batches += 1
                self._batched_requests += len(items)
                self._max_batch_seen = max(self._max_batch_seen,
                                           len(items))
            else:
                # under-utilized batch: padded lanes would outweigh the
                # dispatch savings, so run the few uniques singly (they
                # still share the plan's compiled single executable)
                results = [plan.execute(g, seed=spec.seed)
                           for g in graphs]
            self.mapper._requests += len(graphs)
        except Exception:
            # batch-level failure: isolate per request
            results = []
            for ckey, g in uniq.items():
                try:
                    results.append(self.mapper.map(g, spec=spec))
                except Exception as exc:
                    results.append(exc)
        by_key = dict(zip(uniq.keys(), results))
        for ticket, g, sp, t_sub, ckey in items:
            res = by_key[ckey]
            if not isinstance(res, Exception):
                self._result_cache[ckey] = self._copy_result(res)
                while len(self._result_cache) > self._result_cache_size:
                    self._result_cache.popitem(last=False)
                res = self._copy_result(res)
            self._emit(ticket, res, t_sub)
        self._deduped += len(items) - len(graphs)

    @staticmethod
    def _copy_result(res):
        """Results are shared between the warm cache and (possibly many)
        clients — hand out copies so nobody can mutate cached state
        (the perm array *and* the SearchStats with its trace list)."""
        import copy
        import dataclasses
        return dataclasses.replace(
            res, perm=res.perm.copy(),
            search_stats=copy.deepcopy(res.search_stats))

    def _emit(self, ticket, res, t_sub):
        self._served += 1
        if isinstance(res, Exception):
            self._errors += 1
        self._latencies.append(time.perf_counter() - t_sub)
        self.results.put((ticket, res))


# ------------------------------------------------------ placement service
def placement_service(hierarchy=None, spec=None, requests=None,
                      results=None, **knobs):
    """Long-lived device-placement service for the serving fleet.

    One `Mapper` session per fleet hierarchy: plans (distance oracle,
    compiled kernels, jitted engines) are lowered once per shape bucket,
    then every traffic graph pushed onto the request queue (e.g.
    extracted from newly compiled serving programs via
    ``repro.core.comm_model.device_comm_graph``) executes a pre-compiled
    plan — same-bucket bursts batch into one vmapped call.  Returns the
    started :class:`MappingService`.
    """
    from ..core import Mapper, tpu_v5e_fleet
    from .specs import placement_service_config, placement_spec
    h = hierarchy if hierarchy is not None else tpu_v5e_fleet(pods=2)
    cfg = placement_service_config()
    cfg.update(knobs)
    return MappingService(Mapper(h, spec or placement_spec()),
                          requests=requests, results=results, **cfg)


def _placement_smoke():
    """Round-trip a few synthetic fleet traffic graphs through the
    placement queue and print objectives vs identity placement, plus the
    session's plan-cache and service accounting."""
    import numpy as np

    from ..core import from_edges, qap_objective, tpu_v5e_fleet

    h = tpu_v5e_fleet(pods=1)   # 256 PEs
    n = h.n_pe
    graphs = []
    for shift in (1, 2, 4):
        us = np.arange(n)
        vs = (us + shift * 16) % n
        graphs.append(from_edges(n, us, vs, np.full(n, 1e6)))
    graphs.append(graphs[0])    # a repeat: exercises the warm cache
    with placement_service(h) as svc:
        tickets = {}
        for g in graphs:
            tickets[svc.submit(g)] = g
        for _ in tickets:
            ticket, res = svc.results.get(timeout=300)
            if isinstance(res, Exception):
                raise res
            g = tickets[ticket]
            j_id = qap_objective(g, h, np.arange(n))
            print(f"request {ticket}: J={res.final_objective:.3e} "
                  f"(identity {j_id:.3e}, "
                  f"{res.final_objective / j_id:.2f}x)")
        stats = svc.stats()
        info = svc.mapper.cache_info()
    print(f"service: served={stats['served']} "
          f"batches={stats['batches']} "
          f"warm_hits={stats['result_cache_hits']} "
          f"peak_queue_depth={stats['peak_queue_depth']} "
          f"p50={stats['latency_p50_s']:.3f}s "
          f"p99={stats['latency_p99_s']:.3f}s")
    print(f"plan cache: builds={info['plan_builds']} "
          f"hits={info['plan_hits']} evictions={info['plan_evictions']}")
    for tag, pinfo in info["plans"].items():
        print(f"  bucket {tag}: executes={pinfo['executes']} "
              f"pair_hits={pinfo['pair_hits']} "
              f"engines={pinfo['engine_builds']}")
    print("placement service:", "ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--placement-smoke", action="store_true",
                    help="exercise the Mapper placement queue and exit")
    args = ap.parse_args()
    if args.placement_smoke:
        _placement_smoke()
        return
    if not args.arch:
        ap.error("--arch is required unless --placement-smoke")
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                smoke=args.smoke)
    print(f"prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_tok_per_s']:.1f} tok/s")
    print("sample:", out["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
