import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the sharding plan is coherent at production
scale (compile succeeds, memory fits) and extracts the roofline terms
(repro.analysis) from the optimized HLO.  Results land in
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` and a summary row is
printed per cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k --mesh single          # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

from ..analysis import analyze, roofline_from_cost
from ..configs import ARCHS, SHAPES, get_config, supports_shape
from .mesh import make_production_mesh
from . import specs as sp

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def lower_cell(cfg, shape_name: str, mesh):
    """Returns (lowered, kind)."""
    from ..train.steps import (build_prefill_step, build_serve_step,
                               build_train_step)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        import jax.numpy as _jnp
        gsd = _jnp.bfloat16 if os.environ.get("DRYRUN_GRAD_BF16") else None
        jit_fn, _, _ = build_train_step(cfg, mesh, donate=True,
                                        global_batch=shape.global_batch,
                                        grad_sync_dtype=gsd)
        state, batch = sp.train_input_specs(cfg, shape_name)
        return jit_fn.lower(state, batch), "train_step"
    if shape.kind == "prefill":
        jit_fn, _, _ = build_prefill_step(cfg, mesh,
                                          global_batch=shape.global_batch)
        params, batch = sp.prefill_input_specs(cfg, shape_name)
        return jit_fn.lower(params, batch), "prefill_step"
    jit_fn, *_ = build_serve_step(cfg, mesh, shape.global_batch,
                                  shape.seq_len, donate=True)
    params, token, caches, step = sp.serve_input_specs(cfg, shape_name)
    return jit_fn.lower(params, token, caches, step), "serve_step"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, hlo_dir: Path | None = None,
             overrides: dict | None = None, tag: str = "") -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh_name = ("multi" if multi_pod else "single") + (
        f"+{tag}" if tag else "")
    ok, why = supports_shape(cfg, shape_name)
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        row["status"] = "skipped"
        row["reason"] = why
        if save:
            OUT_DIR.mkdir(parents=True, exist_ok=True)
            (OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
             ).write_text(json.dumps(row, indent=1))
        return row
    shape = SHAPES[shape_name]
    n_chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered, kind = lower_cell(cfg, shape_name, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as e:  # sharding bug — fail loudly with context
        row["status"] = "FAILED"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-2000:]
        return row

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    cost = analyze(hlo, pod_size=256)
    # analytic model flops (per device): tokens/step × flops/token / chips
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = tokens * cfg.model_flops_per_token("train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = tokens * cfg.model_flops_per_token("infer")
    else:
        tokens = shape.global_batch
        mf = tokens * cfg.model_flops_per_token("infer")
    rl = roofline_from_cost(cost, model_flops_per_device=mf / n_chips)

    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    row.update({
        "status": "ok", "kind": kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "arg_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "out_bytes": mem.output_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "per_device_bytes": per_dev_bytes,
        "fits_16g": bool(per_dev_bytes < 16 * 1024 ** 3),
        "collectives_by_type": cost.by_type(),
        "trip_counts": cost.trip_counts,
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in rl.row().items()},
    })
    if hlo_dir is not None:
        hlo_dir.mkdir(parents=True, exist_ok=True)
        (hlo_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.txt"
         ).write_text(hlo)
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        (OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json").write_text(
            json.dumps(row, indent=1, default=str))
    return row


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:6s} SKIP "
                f"({r['reason'][:60]})")
    if r["status"] != "ok":
        return (f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:6s} FAIL "
                f"{r['error'][:90]}")
    return (f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:6s} ok "
            f"mem={r['per_device_bytes']/2**30:5.1f}G "
            f"c={r['compute_s']*1e3:8.2f}ms m={r['memory_s']*1e3:8.2f}ms "
            f"i={r['ici_s']*1e3:7.2f}ms d={r['dcn_s']*1e3:7.2f}ms "
            f"{r['bound'][:4]:4s} rf={r['roofline_fraction']:.2f} "
            f"(compile {r['compile_s']}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="§Perf A2: pad attention heads to this multiple")
    ap.add_argument("--flash", action="store_true",
                    help="§Perf A3: Pallas fused-attention kernel")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--capacity", type=float, default=0.0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = {}
    if args.pad_heads:
        overrides["pad_heads_to"] = args.pad_heads
    if args.flash:
        overrides["use_flash_kernel"] = True
    if args.remat:
        overrides["remat"] = args.remat
    if args.microbatches:
        overrides["train_microbatches"] = args.microbatches
    if args.capacity:
        overrides["capacity_factor"] = args.capacity

    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    hlo_dir = (OUT_DIR / "hlo") if args.save_hlo else None

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, hlo_dir=hlo_dir,
                             overrides=overrides or None, tag=args.tag)
                print(fmt_row(r), flush=True)
                failures += r["status"] == "FAILED"
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")


if __name__ == "__main__":
    main()
