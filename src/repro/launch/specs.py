"""ShapeDtypeStruct input stand-ins for every (arch × shape) dry-run cell.

No device allocation: shapes come from ``jax.eval_shape`` over the real
init functions, so the dry-run lowers exactly what the launcher would run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import SHAPES
from ..models.transformer import init_caches, init_params
from ..train.steps import init_train_state


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def train_input_specs(cfg, shape_name: str):
    shape = SHAPES[shape_name]
    b, t = shape.global_batch, shape.seq_len
    t_tok = t - cfg.frontend_tokens
    state = _sds(jax.eval_shape(
        lambda k: init_train_state(k, cfg), jax.random.PRNGKey(0)))
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, t_tok), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, t_tok), jnp.int32),
    }
    if cfg.frontend_tokens:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), cfg.jnp_dtype)
    return state, batch


def prefill_input_specs(cfg, shape_name: str):
    shape = SHAPES[shape_name]
    b, t = shape.global_batch, shape.seq_len
    t_tok = t - cfg.frontend_tokens
    params = _sds(jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((b, t_tok), jnp.int32)}
    if cfg.frontend_tokens:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), cfg.jnp_dtype)
    return params, batch


def placement_spec(seed: int = 0):
    """The fleet-placement ``MappingSpec`` shared by the serving placement
    service (``repro.launch.serve``) and the mesh-mapping benchmark — the
    same config language the ``viem`` CLI speaks (``--config``).

    d=3 keeps the N_C^d neighborhood tractable at fleet scale (hundreds to
    thousands of devices) while still crossing tray/superblock boundaries.
    """
    from ..core import MappingSpec
    return MappingSpec(preconfiguration="eco", neighborhood="communication",
                       neighborhood_dist=3, seed=seed)


def placement_service_config() -> dict:
    """Knobs for the fleet :class:`~repro.launch.serve.MappingService`,
    shared by the placement service and ``benchmarks.bench_serve`` so
    both measure the same configuration.

    ``pow2`` shape buckets collapse mixed traffic onto a handful of
    compiled plans; a small ``max_wait_s`` trades a few milliseconds of
    latency for whole-bucket vmapped batches; the warm result cache
    answers repeat traffic graphs (recompiled serving programs usually
    re-emit the same communication pattern) without touching the device.
    """
    return {"schedule": "pow2", "max_batch": 4, "max_wait_s": 0.005,
            "result_cache_size": 256}


def serve_input_specs(cfg, shape_name: str):
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    params = _sds(jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)))
    caches = _sds(jax.eval_shape(
        lambda: init_caches(b, cfg, max_len=s)))
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return params, token, caches, step
