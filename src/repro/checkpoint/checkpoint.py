"""Sharded, async, elastic checkpointing.

Layout (one directory per step):
    step_000100/
      manifest.json      — pytree structure, shapes, dtypes, mesh shape
      shard_<host>.npz   — this host's param/opt shards (here: 1 host)

Properties required at fleet scale (DESIGN §7):
  * async — `save_async` snapshots to host RAM on the training thread and
    writes in a background thread; the device step continues immediately,
  * atomic — writes go to ``<dir>.tmp`` then rename, so a host failure
    mid-save never corrupts the latest checkpoint,
  * elastic — `restore` reshapes/reshards to a *different* mesh: the
    manifest stores logical shapes, so a survivor fleet with fewer data
    shards just re-slices (parameters are logically replicated across DP;
    FSDP shards re-partition along the stored logical axes),
  * self-describing — restore needs no model code, only the manifest.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _savable(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bf16 → void '|V2'); view as uint16 and
    record the true dtype in the manifest."""
    if arr.dtype == ml_dtypes.bfloat16:
        return arr.view(np.uint16)
    return arr


def _restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str == "bfloat16" and arr.dtype != ml_dtypes.bfloat16:
        return arr.view(ml_dtypes.bfloat16)
    return arr


def tree_paths(tree) -> list[str]:
    # jax.tree_util spelling: jax.tree.map_with_path only exists on newer
    # jax releases than the pinned toolchain provides.
    paths = []
    jax.tree_util.tree_map_with_path(
        lambda p, _: paths.append(jax.tree_util.keystr(p)), tree)
    return paths


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state, mesh_shape=None) -> Path:
        """Synchronous atomic save."""
        leaves, _ = _flatten(state)
        leaves = [np.asarray(x) for x in leaves]
        host = {f"leaf_{i}": _savable(x) for i, x in enumerate(leaves)}
        paths = tree_paths(state)
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "shard_0.npz", **host)
        manifest = {
            "step": step,
            "paths": paths,
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
            "mesh_shape": list(mesh_shape) if mesh_shape else None,
            "n_hosts": 1,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def save_async(self, step: int, state, mesh_shape=None):
        """Snapshot on the caller thread (device→host copy), write in the
        background.  Joins any in-flight save first (ordering)."""
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(x), state)
        self._thread = threading.Thread(
            target=self.save, args=(step, snapshot, mesh_shape),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, mesh=None, shardings=None):
        """Restore into the structure of ``target_tree``; if ``mesh`` and
        ``shardings`` are given, place shards directly onto the (possibly
        different-size) target mesh — the elastic-restart path."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "shard_0.npz")
        leaves = [_restore_dtype(data[f"leaf_{i}"], dt)
                  for i, dt in enumerate(manifest["dtypes"])]
        _, treedef = _flatten(target_tree)
        t_leaves = jax.tree.leaves(target_tree)
        if len(t_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target expects "
                f"{len(t_leaves)} — structure changed since save")
        for saved, tgt, path in zip(leaves, t_leaves, manifest["paths"]):
            if tuple(saved.shape) != tuple(tgt.shape):
                raise ValueError(f"shape mismatch at {path}: "
                                 f"{saved.shape} vs {tgt.shape}")
        if mesh is not None and shardings is not None:
            s_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            leaves = [jax.device_put(x, s)
                      for x, s in zip(leaves, s_leaves)]
        else:
            leaves = [jax.numpy.asarray(x) for x in leaves]
        return jax.tree.unflatten(treedef, leaves)
