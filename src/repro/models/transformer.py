"""Composable decoder: embeddings + scanned layer periods + LM head.

A model is a repeating *period* of layers (period = 1 for homogeneous
models; 8 for Jamba's [6×mamba, attn, mamba] × MoE-every-2 interleave).
Parameters for each period position are stacked over n_periods and the
forward pass is a single `lax.scan` — HLO size and compile time stay flat
in depth, which is what makes 70+ multi-pod dry-run compiles tractable.

Decode carries a cache pytree with the same period structure:
  attn  → {k, v} ring/linear KV cache
  mamba → {conv, ssm}
  rwkv  → {tm_x, tm_s, cm_x}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention_block, decode_attention_block,
                        init_attention, init_kv_cache, _qkv)
from .layers import (bcast_right, embed_tokens, init_embeddings, init_mlp,
                     lm_logits, mlp, rms_norm)
from .mamba import (decode_mamba_block, init_mamba, init_mamba_cache,
                    mamba_block)
from .moe import init_moe, moe_ffn
from .rwkv import (decode_rwkv_channel_mix, decode_rwkv_time_mix,
                   init_rwkv_channel_mix, init_rwkv_time_mix,
                   rwkv_channel_mix, rwkv_time_mix)


# ---------------------------------------------------------------- params
def init_layer(key, cfg, kind):
    mixer, ffn = kind
    k1, k2 = jax.random.split(key)
    dt = cfg.jnp_dtype
    p = {"norm1": jnp.ones((cfg.d_model,), dt),
         "norm2": jnp.ones((cfg.d_model,), dt)}
    if mixer == "attn":
        p["mixer"] = init_attention(k1, cfg)
    elif mixer == "mamba":
        p["mixer"] = init_mamba(k1, cfg)
    elif mixer == "rwkv":
        p["mixer"] = init_rwkv_time_mix(k1, cfg)
    if ffn == "mlp":
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dt)
    elif ffn == "moe":
        p["ffn"] = init_moe(k2, cfg, split=cfg.moe_ep_split)
    elif ffn == "channelmix":
        p["ffn"] = init_rwkv_channel_mix(k2, cfg)
    return p


def init_params(key, cfg):
    ke, kl = jax.random.split(key)
    kinds = cfg.period_kinds()
    periods = []
    for pos, kind in enumerate(kinds):
        keys = jax.random.split(jax.random.fold_in(kl, pos), cfg.n_periods)
        periods.append(jax.vmap(lambda k: init_layer(k, cfg, kind))(keys))
    return {
        "embeddings": init_embeddings(ke, cfg.padded_vocab, cfg.d_model,
                                      cfg.jnp_dtype),
        "periods": periods,
    }


# --------------------------------------------------------------- forward
def _layer_apply(p, h, positions, cfg, kind, moe_c=None):
    mixer, ffn = kind
    ep_c, bt_c = moe_c if moe_c else (None, None)
    aux = jnp.zeros((), jnp.float32)
    if mixer == "attn":
        h = h + attention_block(p["mixer"], rms_norm(h, p["norm1"]),
                                positions, cfg)
    elif mixer == "mamba":
        h = h + mamba_block(p["mixer"], rms_norm(h, p["norm1"]), cfg)
    elif mixer == "rwkv":
        out, _ = rwkv_time_mix(p["mixer"], rms_norm(h, p["norm1"]), cfg)
        h = h + out
    if ffn == "mlp":
        h = h + mlp(p["ffn"], rms_norm(h, p["norm2"]), cfg.mlp_type)
    elif ffn == "moe":
        out, aux = moe_ffn(p["ffn"], rms_norm(h, p["norm2"]), cfg,
                           ep_constrain=ep_c, batch_constrain=bt_c)
        h = h + out
    elif ffn == "channelmix":
        out, _ = rwkv_channel_mix(p["ffn"], rms_norm(h, p["norm2"]))
        h = h + out
    return h, aux


def forward(params, tokens, cfg, frontend=None, constrain=None,
            moe_c=None, logits_last_only: bool = False):
    """Train/prefill forward.  tokens: (B, T_tok) int32; frontend: optional
    (B, F, D) precomputed modality embeddings prepended to the sequence.
    ``constrain``: optional fn pinning (B, T, D) activation sharding —
    without it GSPMD lets the embedding's FSDP layout unshard the batch.
    ``logits_last_only``: serving prefill needs only the last position —
    skipping the (B, T, V) projection saves the largest single tensor of
    the 32k prefill cells (§Perf A1).
    Returns (logits (B, T, V_padded), aux_loss)."""
    constrain = constrain or (lambda x: x)
    h = embed_tokens(params["embeddings"], tokens)
    if frontend is not None:
        h = jnp.concatenate([frontend.astype(h.dtype), h], axis=1)
    h = constrain(h)
    b, t, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    kinds = cfg.period_kinds()

    def period_body(h, period_params):
        aux = jnp.zeros((), jnp.float32)
        h = constrain(h)
        for pos, kind in enumerate(kinds):
            h, a = _layer_apply(period_params[pos], h, positions, cfg,
                                kind, moe_c=moe_c)
            aux += a
        return constrain(h), aux

    if cfg.remat == "full":
        period_body = jax.checkpoint(period_body,
                                     prevent_cse=False)
    elif cfg.remat == "dots":
        period_body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    h, auxs = jax.lax.scan(period_body, h, params["periods"])
    if logits_last_only:
        h = h[:, -1:]
    logits = lm_logits(params["embeddings"], h, cfg.vocab_size)
    return logits, auxs.sum()


# ---------------------------------------------------------------- decode
def init_caches(batch: int, cfg, max_len: int):
    dt = cfg.jnp_dtype
    caches = []
    for kind in cfg.period_kinds():
        mixer, ffn = kind
        c = {}
        if mixer == "attn":
            c["attn"] = init_kv_cache(batch, cfg, max_len, dt)
        elif mixer == "mamba":
            c["mamba"] = init_mamba_cache(batch, cfg, dt)
        elif mixer == "rwkv":
            d = cfg.d_model
            h = d // cfg.rwkv_head_size
            c["rwkv"] = {
                "x": jnp.zeros((batch, d), dt),
                "s": jnp.zeros((batch, h, cfg.rwkv_head_size,
                                cfg.rwkv_head_size), jnp.float32),
            }
        if ffn == "channelmix":
            c["cmix"] = {"x": jnp.zeros((batch, cfg.d_model), dt)}
        # stack over periods
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), c))
    return caches


def decode_step(params, token, caches, step, cfg, constrain=None,
                moe_c=None):
    """One decode step.  token: (B, 1) int32; step: scalar int32 count of
    tokens already in the caches.  Returns (logits (B,1,V), new caches)."""
    constrain = constrain or (lambda x: x)
    h = constrain(embed_tokens(params["embeddings"], token))
    kinds = cfg.period_kinds()

    def period_body(h, xs):
        period_params, cache = xs
        new_cache = []
        h = constrain(h)
        for pos, kind in enumerate(kinds):
            mixer, ffn = kind
            p = period_params[pos]
            c = cache[pos]
            nc = {}
            if mixer == "attn":
                out, nc["attn"] = decode_attention_block(
                    p["mixer"], rms_norm(h, p["norm1"]), c["attn"], step,
                    cfg)
                h = h + out
            elif mixer == "mamba":
                out, nc["mamba"] = decode_mamba_block(
                    p["mixer"], rms_norm(h, p["norm1"]), c["mamba"], cfg)
                h = h + out
            elif mixer == "rwkv":
                out, nc["rwkv"] = decode_rwkv_time_mix(
                    p["mixer"], rms_norm(h, p["norm1"]), c["rwkv"], cfg)
                h = h + out
            if ffn == "mlp":
                h = h + mlp(p["ffn"], rms_norm(h, p["norm2"]), cfg.mlp_type)
            elif ffn == "moe":
                ep_c, bt_c = moe_c if moe_c else (None, None)
                out, _ = moe_ffn(p["ffn"], rms_norm(h, p["norm2"]), cfg,
                                 ep_constrain=ep_c, batch_constrain=bt_c)
                h = h + out
            elif ffn == "channelmix":
                out, nc["cmix"] = decode_rwkv_channel_mix(
                    p["ffn"], rms_norm(h, p["norm2"]), c["cmix"])
                h = h + out
            new_cache.append(nc)
        return h, new_cache

    h, new_caches = jax.lax.scan(period_body, h,
                                 (params["periods"], caches))
    logits = lm_logits(params["embeddings"], h, cfg.vocab_size)
    return logits, new_caches


# -------------------------------------------------- prefill with cache
def prefill_with_cache(params, tokens, cfg, max_len: int):
    """Forward pass that also fills decode caches (serving path).  Uses the
    state-returning layer variants; intended for the runnable examples and
    integration tests (small models) — the 32k dry-run prefill lowers
    :func:`forward`."""
    b, t = tokens.shape
    h = embed_tokens(params["embeddings"], tokens)
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    kinds = cfg.period_kinds()
    caches = init_caches(b, cfg, max_len)

    def period_body(h, xs):
        period_params, cache = xs
        new_cache = []
        for pos, kind in enumerate(kinds):
            mixer, ffn = kind
            p = period_params[pos]
            nc = {}
            if mixer == "attn":
                x = rms_norm(h, p["norm1"])
                q, k, v = _qkv(p["mixer"], x, positions, cfg)
                c = cache[pos]["attn"]
                s_cache = c["k"].shape[1]
                if cfg.sliding_window and t > s_cache:
                    ck = c["k"].at[:, :].set(k[:, -s_cache:])
                    cv = c["v"].at[:, :].set(v[:, -s_cache:])
                else:
                    ck = jax.lax.dynamic_update_slice_in_dim(
                        c["k"], k, 0, axis=1)
                    cv = jax.lax.dynamic_update_slice_in_dim(
                        c["v"], v, 0, axis=1)
                nc["attn"] = {"k": ck, "v": cv}
                from .attention import flash_attention
                o = flash_attention(q, k, v, cfg)
                h = h + jnp.einsum("bthk,hkd->btd", o, p["mixer"]["wo"])
            elif mixer == "mamba":
                from .mamba import _causal_conv, _chunked_ssm
                x = rms_norm(h, p["norm1"])
                xz = x @ p["mixer"]["w_in"]
                x_p, z = jnp.split(xz, 2, axis=-1)
                dc = cfg.mamba_d_conv
                xc, _ = _causal_conv(x_p, p["mixer"]["conv_w"],
                                     p["mixer"]["conv_b"])
                conv_state = jnp.pad(
                    x_p, ((0, 0), (max(dc - 1 - t, 0), 0), (0, 0))
                )[:, -(dc - 1):]
                xc = jax.nn.silu(xc)
                h0 = jnp.zeros((b, cfg.d_inner, cfg.mamba_d_state),
                               jnp.float32)
                y, h_f = _chunked_ssm(p["mixer"], xc, cfg, h0)
                y = y + bcast_right(p["mixer"]["d_skip"], 3) \
                    * xc.astype(jnp.float32)
                y = y.astype(x.dtype) * jax.nn.silu(z)
                nc["mamba"] = {"conv": conv_state, "ssm": h_f}
                h = h + y @ p["mixer"]["w_out"]
            elif mixer == "rwkv":
                out, (last_x, s_f) = rwkv_time_mix(
                    p["mixer"], rms_norm(h, p["norm1"]), cfg)
                nc["rwkv"] = {"x": last_x, "s": s_f}
                h = h + out
            if ffn == "mlp":
                h = h + mlp(p["ffn"], rms_norm(h, p["norm2"]), cfg.mlp_type)
            elif ffn == "moe":
                out, _ = moe_ffn(p["ffn"], rms_norm(h, p["norm2"]), cfg)
                h = h + out
            elif ffn == "channelmix":
                out, last_x = rwkv_channel_mix(p["ffn"],
                                               rms_norm(h, p["norm2"]))
                nc["cmix"] = {"x": last_x}
                h = h + out
            new_cache.append(nc)
        return h, new_cache

    h, new_caches = jax.lax.scan(period_body, h,
                                 (params["periods"], caches))
    logits = lm_logits(params["embeddings"], h, cfg.vocab_size)
    return logits, new_caches
