"""Sharding plans: parameter / cache / batch PartitionSpecs per DESIGN §5.

Axes: `model` = tensor parallel, `data` = FSDP (params) + batch,
`pod` = pure DP across DCN.  Rules are divisibility-aware: dims that don't
divide the axis (e.g. 8 KV heads on a 16-way model axis, RWKV's 40 heads)
fall back to replication on that axis — Megatron-style KV replication —
rather than relying on GSPMD's padded sharding.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# Mesh handle for kernel paths (set by the step builders before tracing;
# shard_map needs the concrete mesh, which cfg/functions don't carry).
FLASH_MESH: Mesh | None = None


def set_flash_mesh(mesh: Mesh | None):
    global FLASH_MESH
    FLASH_MESH = mesh


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def param_specs(cfg, mesh: Mesh):
    """PartitionSpec pytree mirroring transformer.init_params output.

    Layout rule (dry-run finding, DESIGN §5): the FSDP (`data`) shard goes
    on a **non-contracting** dim of every forward matmul, so GSPMD's only
    strategy is to all-gather the (small) weight shards at use — ZeRO-3.
    Sharding the contracting dim makes GSPMD partial-sum the (huge, f32)
    activations over `data` instead, and in the vmapped MoE it replicated
    the batch outright.  TP (`model`) stays on the conventional Megatron
    dims (heads / d_ff / d_inner), whose activation all-reduce is the
    intrinsic TP cost."""
    dm_ok = _div(cfg.d_model, mesh, "data")
    da = "data" if dm_ok else None     # FSDP axis (storage only)
    hd_ok = _div(cfg.head_dim_, mesh, "data")
    hda = "data" if hd_ok else None

    def _dm(n: int):
        """(data, model) two-axis storage sharding for an output dim."""
        both = n % (mesh.shape["data"] * mesh.shape["model"]) == 0
        if both:
            return ("data", "model")
        return "model" if _div(n, mesh, "model") else None

    def attn_specs():
        kv_ok = _div(cfg.n_kv_heads, mesh, "model")
        h_ok = _div(cfg.n_heads_eff, mesh, "model")
        return {
            "wq": P(None, "model" if h_ok else None, hda),
            "wk": P(None, "model" if kv_ok else None, hda),
            "wv": P(None, "model" if kv_ok else None, hda),
            "wo": P("model" if h_ok else None, None, da),
        }

    def mlp_specs():
        fa_use = "model" if _div(cfg.d_ff, mesh, "model") else None
        s = {"w1": P(None, _dm(cfg.d_ff)), "w2": P(fa_use, da)}
        if cfg.mlp_type == "swiglu":
            s["w3"] = P(None, _dm(cfg.d_ff))
        return s

    def moe_specs():
        # virtual-expert EP: weights (E_v, D, F/s) live E_v@data (true
        # expert parallelism — dispatch travels, weights don't)
        ev = cfg.moe_experts * cfg.moe_ep_split
        fs = cfg.d_ff // cfg.moe_ep_split
        ea = "data" if _div(ev, mesh, "data") else None
        fa = "model" if fs % mesh.shape["model"] == 0 else None
        return {
            "router": P(None, None),
            "w1": P(ea, None, fa),
            "w2": P(ea, fa, None),
            "w3": P(ea, None, fa),
        }

    def mamba_specs():
        di_ok = _div(cfg.d_inner, mesh, "model")
        ma = "model" if di_ok else None
        return {
            "w_in": P(None, _dm(2 * cfg.d_inner)),
            "conv_w": P(None, ma), "conv_b": P(ma),
            "w_x": P(ma, "data" if _div(cfg.dt_rank_
                                        + 2 * cfg.mamba_d_state,
                                        mesh, "data") else None),
            "w_dt": P(None, _dm(cfg.d_inner)), "b_dt": P(ma),
            "a_log": P(ma, None), "d_skip": P(ma),
            "w_out": P(ma, da),
        }

    def rwkv_tm_specs():
        # heads rarely divide the model axis → FSDP-only projections
        return {
            "mu_r": P(None), "mu_k": P(None), "mu_v": P(None),
            "mu_g": P(None), "mu_w": P(None),
            "wr": P(None, da), "wk": P(None, da), "wv": P(None, da),
            "wg": P(None, da), "wo": P(None, da),
            "w0": P(None), "w_lora_a": P(None, None),
            "w_lora_b": P(None, da),
            "u": P(None, None), "ln_g": P(None), "ln_b": P(None),
        }

    def cmix_specs():
        fa_use = "model" if _div(cfg.d_ff, mesh, "model") else None
        return {"mu_k": P(None), "mu_r": P(None),
                "wk": P(None, _dm(cfg.d_ff)), "wv": P(fa_use, da),
                "wr": P(None, da)}

    periods = []
    for kind in cfg.period_kinds():
        mixer, ffn = kind
        spec = {"norm1": P(None), "norm2": P(None)}
        if mixer == "attn":
            spec["mixer"] = attn_specs()
        elif mixer == "mamba":
            spec["mixer"] = mamba_specs()
        elif mixer == "rwkv":
            spec["mixer"] = rwkv_tm_specs()
        if ffn == "mlp":
            spec["ffn"] = mlp_specs()
        elif ffn == "moe":
            spec["ffn"] = moe_specs()
        elif ffn == "channelmix":
            spec["ffn"] = cmix_specs()
        # stacked period axis in front
        periods.append(jax.tree.map(
            lambda p: P(None, *p), spec,
            is_leaf=lambda x: isinstance(x, P)))

    return {
        "embeddings": {
            # embed: vocab over `data` (FSDP) with d_model replicated — a
            # vocab+d_model doubly-sharded table makes the token gather
            # unshardable and GSPMD replicates the batch (dry-run finding).
            "embed": P("data" if _div(cfg.padded_vocab, mesh, "data")
                       else None, None),
            # lm_head contracts d_model — keep d_model replicated, store
            # vocab over both axes, compute with vocab@model.
            "lm_head": P(_dm(cfg.padded_vocab), None),
            "final_norm": P(None),
        },
        "periods": periods,
    }


def cache_specs(cfg, mesh: Mesh, batch: int, seq_shard: bool = False):
    """Decode-cache PartitionSpecs.

    KV heads rarely divide the model axis, so the cache's *sequence* dim is
    sharded over `model` instead (flash-decode: GSPMD turns the masked
    softmax/PV reductions into cheap per-head all-reduces).  With
    ``seq_shard=True`` (long-context B=1 — batch can't shard) the sequence
    is sharded over both (`data`, `model`)."""
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    b_ok = batch % n_b == 0 and not seq_shard
    bsp = ba if b_ok else None
    kv_ok = _div(cfg.n_kv_heads, mesh, "model")
    kva = "model" if kv_ok else None
    seq = None
    if seq_shard:
        seq = ("data", "model") if kva is None else ("data",)
    elif kva is None:
        seq = "model"           # heads can't shard → shard the sequence
    di_ok = _div(cfg.d_inner, mesh, "model")
    ma = "model" if di_ok else None

    caches = []
    for kind in cfg.period_kinds():
        mixer, ffn = kind
        c = {}
        if mixer == "attn":
            c["attn"] = {"k": P(None, bsp, seq, kva, None),
                         "v": P(None, bsp, seq, kva, None)}
        elif mixer == "mamba":
            c["mamba"] = {"conv": P(None, bsp, None, ma),
                          "ssm": P(None, bsp, ma, None)}
        elif mixer == "rwkv":
            c["rwkv"] = {"x": P(None, bsp, None),
                         "s": P(None, bsp, None, None, None)}
        if ffn == "channelmix":
            c["cmix"] = {"x": P(None, bsp, None)}
        caches.append(c)
    return caches


def train_batch_specs(mesh: Mesh, has_frontend: bool = False):
    ba = batch_axes(mesh)
    spec = {"tokens": P(ba, None), "labels": P(ba, None)}
    if has_frontend:
        spec["frontend"] = P(ba, None, None)
    return spec


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def moe_constrainers(cfg, mesh: Mesh, batch: int):
    """(ep_constrain, batch_constrain) for (B, E_v, cap, D) MoE buffers.

    ep_constrain reshards to E_v@data (the EP all-to-all: tokens travel to
    the experts).  batch_constrain brings the result back to B@batch-axes.
    With a `pod` axis, B stays pod-sharded throughout (EP never crosses
    DCN)."""
    if not cfg.moe_experts:
        return None
    ev = cfg.moe_experts * cfg.moe_ep_split
    if ev % mesh.shape["data"] != 0:
        return None
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    pod = ("pod",) if "pod" in mesh.axis_names and batch % mesh.shape[
        "pod"] == 0 else None
    fs = cfg.d_ff // cfg.moe_ep_split
    fa = "model" if fs % mesh.shape["model"] == 0 else None

    def ep_c(z):
        # last dim is d_model (buf/y) or the expert hidden F/s (h) — pin
        # F/s to `model` or the constraint would silently replicate it
        # (16× expert FLOPs, the mixtral dry-run regression)
        last = fa if z.shape[-1] == fs else None
        return jax.lax.with_sharding_constraint(
            z, NamedSharding(mesh, P(pod, "data", None, last)))

    if batch % n_b == 0:
        def bt_c(z):
            return jax.lax.with_sharding_constraint(
                z, NamedSharding(mesh, P(ba, None, None, None)))
    else:
        bt_c = ep_c          # keep EP layout; combine handles it

    return ep_c, bt_c


def activation_constrainer(mesh: Mesh, batch: int):
    """Pin (B, T, D) / (B, T) activations to batch-over-(pod,data).

    Without this, the FSDP embedding layout would re-shard activations onto
    d_model and replicate the batch — the 500×-memory failure mode the
    first granite dry-run exposed.  Batch sizes that don't divide the batch
    axes (long-context B=1) stay replicated on batch but keep other dims
    unsharded as well (returns identity)."""
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    if batch % n_b != 0:
        return lambda x: x

    def constrain(x):
        spec = P(ba, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
