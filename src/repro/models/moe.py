"""Top-k routed mixture-of-experts FFN with virtual-expert EP.

Expert parallelism on a fixed (data=16, model=16) mesh (DESIGN §5):
expert weights live sharded E@data — but E (8 or 16) must equal the axis
size.  For E < data we split each expert's FFN dim into s = data/E
*virtual experts* (w1: (E, D, F) → (E·s, D, F/s)); a token routed to
expert e is dispatched to all s of its halves.  SwiGLU splits cleanly over
F (silu(x@W1)∘(x@W3) is elementwise in F) and w2's contraction sums over
halves via the combine-add, so the math is exact and zero extra FLOPs.

Dataflow per layer (the classic EP all-to-all, expressed via GSPMD
sharding constraints rather than manual collectives):

  tokens (B@data, T, D)
    → route (vmapped per row: the sort stays device-local)
    → scatter into buf (B, E_v, cap, D)   constrained E_v@data   [a2a]
    → expert einsums (E_v@data, F/s@model local)
    → y constrained B@data                                       [a2a back]
    → gather + weighted combine (vmapped per row)

Overflow tokens beyond capacity drop (combine weight 0) — the standard
production trade-off.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import bcast_right


def ep_split(cfg, n_data: int) -> int:
    """Virtual-expert split factor: E·s == data axis when possible."""
    e = cfg.moe_experts
    if e >= n_data:
        return 1
    if n_data % e == 0:
        return n_data // e
    return 1


def init_moe(key, cfg, split: int = 1):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    ev, fs = e * split, f // split
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5
                   ).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (ev, d, fs)) * d ** -0.5
               ).astype(dt),
        "w2": (jax.random.normal(ks[2], (ev, fs, d)) * f ** -0.5
               ).astype(dt),
        "w3": (jax.random.normal(ks[3], (ev, d, fs)) * d ** -0.5
               ).astype(dt),
    }


def _route_row(x, router, e: int, k: int, cap: int, split: int):
    """Per batch-row dispatch plan over *virtual* experts.  x: (T, D)."""
    t = x.shape[0]
    logits = x.astype(jnp.float32) @ router            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)               # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # expand to virtual experts: assignment (token, e) → s × (token, e·s+j)
    flat_e = (topi[..., None] * split
              + bcast_right(jnp.arange(split), 3)).reshape(-1)  # (T·k·s,)
    flat_w = jnp.repeat(topw.reshape(-1), split)
    flat_t = jnp.repeat(jnp.arange(t), k * split)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(len(se)) - first                  # position in expert
    keep = pos < cap
    aux = _load_balance_loss(probs, topi, e)
    return se, st, sw, pos, keep, aux


def _load_balance_loss(probs, topi, e: int):
    """Switch-style auxiliary loss: E · Σ_e f_e · P_e."""
    counts = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p = probs.mean(0)
    return e * jnp.sum(f * p)


def moe_ffn(params, x, cfg, ep_constrain=None, batch_constrain=None):
    """x: (B, T, D) → (B, T, D), aux_loss scalar.

    ``ep_constrain``  pins (B, E_v, cap, D) buffers to E_v@data (the a2a);
    ``batch_constrain`` pins them back to B@data after expert compute."""
    b, t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    ev = params["w1"].shape[0]
    split = ev // e
    cap = int(cfg.capacity_factor * k * t / e + 0.999)
    cap = max(8, -(-cap // 8) * 8)
    cap = min(cap, t * k)
    ep_constrain = ep_constrain or (lambda z: z)
    batch_constrain = batch_constrain or (lambda z: z)

    def plan(xr):
        return _route_row(xr, params["router"], e, k, cap, split)

    se, st, sw, pos, keep, aux = jax.vmap(plan)(x)
    pos_c = jnp.where(keep, pos, cap)                  # cap → dropped

    def scatter_row(xr, se_r, st_r, pos_r):
        buf = jnp.zeros((ev, cap, d), xr.dtype)
        return buf.at[se_r, pos_r].set(xr[st_r], mode="drop")

    buf = jax.vmap(scatter_row)(x, se, st, pos_c)      # (B, E_v, cap, D)
    buf = ep_constrain(buf)                            # → E_v@data  [a2a]
    h = jnp.einsum("becd,edf->becf", buf, params["w1"])
    h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf, params["w3"])
    # constraint on h pins the backward cotangent to the EP layout too —
    # without it GSPMD recomputes the expert backward with E_v and B both
    # replicated (the 29.9 GB jamba dry-run finding)
    h = ep_constrain(h)
    y = jnp.einsum("becf,efd->becd", h, params["w2"])
    y = ep_constrain(y)
    y = batch_constrain(y)                             # → B@data  [a2a back]

    def combine_row(y_r, se_r, st_r, sw_r, pos_r):
        gathered = y_r.at[se_r, pos_r].get(mode="fill",
                                           fill_value=0)   # (T·k·s, D)
        return jnp.zeros((t, d), y_r.dtype).at[st_r].add(
            sw_r[:, None].astype(y_r.dtype) * gathered)

    out = jax.vmap(combine_row)(y, se, st, sw, pos_c)
    return out, aux.mean()
