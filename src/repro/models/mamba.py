"""Mamba-1 selective SSM block (Jamba's sequence mixer).

TPU adaptation: the CUDA selective-scan kernel becomes a *time-chunked*
associative scan — `lax.scan` over T/chunk steps carrying the (B, d_inner,
d_state) state, with a parallel `lax.associative_scan` inside each chunk.
Only one chunk's (B, c, d_inner, d_state) decay tensor is ever live, so
activation memory is O(T·d_inner·d_state / n_chunks) instead of O(T·…)
(the naive full-T associative scan would need ~GBs/device at 4k–32k seq).
The depthwise causal conv is expressed as k static shifts (no conv op —
better GSPMD behavior on the TP-sharded channel dim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import bcast_right


def init_mamba(key, cfg):
    d, di = cfg.d_model, cfg.d_inner
    ds, dc, dr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.dt_rank_
    ks = jax.random.split(key, 6)
    dt = cfg.jnp_dtype
    # S4D-real A init: -(1..ds) per channel
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5
                 ).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * dc ** -0.5
                   ).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "w_x": (jax.random.normal(ks[2], (di, dr + 2 * ds)) * di ** -0.5
                ).astype(dt),
        "w_dt": (jax.random.normal(ks[3], (dr, di)) * dr ** -0.5).astype(dt),
        "b_dt": jnp.full((di,), -4.6, dt),      # softplus⁻¹(0.01)-ish
        "a_log": jnp.log(a),                    # (di, ds) f32
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(ks[4], (di, d)) * di ** -0.5).astype(dt),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv via static shifts.  x: (B, T, di);
    w: (dc, di).  state: (B, dc-1, di) trailing context or None."""
    dc = w.shape[0]
    if state is not None:
        x_ext = jnp.concatenate([state, x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    t = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(dc):
        out = out + x_ext[:, i:i + t].astype(jnp.float32) \
            * bcast_right(w[i].astype(jnp.float32), 3)
    new_state = x_ext[:, -(dc - 1):] if dc > 1 else None
    return (out + bcast_right(b.astype(jnp.float32), 3)).astype(
        x.dtype), new_state


def _ssm_params(params, xc, cfg):
    """Per-token SSM tensors from the conv output xc (B, T, di)."""
    ds, dr = cfg.mamba_d_state, cfg.dt_rank_
    proj = xc @ params["w_x"]                        # (B,T,dr+2ds)
    dt_r, b_mat, c_mat = jnp.split(proj, [dr, dr + ds], axis=-1)
    delta = jax.nn.softplus(
        (dt_r @ params["w_dt"]).astype(jnp.float32)
        + bcast_right(params["b_dt"].astype(jnp.float32), 3))  # (B,T,di)
    a = -jnp.exp(params["a_log"])                    # (di, ds)
    abar = jnp.exp(delta[..., None] * bcast_right(a, 4))  # (B,T,di,ds)
    bx = (delta[..., None] * b_mat[:, :, None, :].astype(jnp.float32)
          * xc[..., None].astype(jnp.float32))       # (B,T,di,ds)
    return abar, bx, c_mat.astype(jnp.float32)


def _chunked_ssm(params, xc, cfg, h0):
    """y_t = C_t·h_t, h_t = abar_t∘h_{t-1} + bx_t — chunked scan.

    The (B, c, di, ds) decay/input tensors are built *inside* the chunk
    body from a (B, c, di) slice of xc, so only one chunk's 4-D tensors
    are ever live (the full-T (B,T,di,ds) restack was the dominant
    HBM-traffic term in the first jamba dry-run).

    xc: (B, T, di) post-conv activations; h0: (B, di, ds).
    Returns (y (B, T, di) f32, h_final)."""
    b, t, di = xc.shape
    c = min(cfg.time_chunk, t)
    while t % c:
        c //= 2
    nc = t // c

    def comb(l, r):
        return r[0] * l[0], r[0] * l[1] + r[1]

    def body(h, xc_c):
        abar, bx, cm = _ssm_params(params, xc_c, cfg)    # (B,c,di,ds)
        aa, bb = jax.lax.associative_scan(comb, (abar, bx), axis=1)
        h_all = aa * h[:, None] + bb             # states at each step
        y = jnp.einsum("btds,bts->btd", h_all, cm)
        return h_all[:, -1], y

    xc_r = xc.reshape(b, nc, c, di).swapaxes(0, 1)       # (nc, B, c, di)
    h_f, ys = jax.lax.scan(body, h0, xc_r)
    return ys.swapaxes(0, 1).reshape(b, t, di), h_f


def mamba_block(params, x, cfg):
    """Train/prefill: x (B, T, D) → (B, T, D)."""
    b, t, _ = x.shape
    di, ds = cfg.d_inner, cfg.mamba_d_state
    xz = x @ params["w_in"]
    x_p, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(x_p, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    y, _ = _chunked_ssm(params, xc, cfg, h0)
    y = y + bcast_right(params["d_skip"], 3) * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["w_out"]


# ------------------------------------------------------------------ decode
def init_mamba_cache(batch: int, cfg, dtype):
    di, ds, dc = cfg.d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, ds), jnp.float32),
    }


def decode_mamba_block(params, x, cache, cfg):
    """One-token step.  x: (B, 1, D)."""
    xz = x @ params["w_in"]
    x_p, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(x_p, params["conv_w"], params["conv_b"],
                                  state=cache["conv"])
    xc = jax.nn.silu(xc)
    abar, bx, c_mat = _ssm_params(params, xc, cfg)     # T = 1
    h = abar[:, 0] * cache["ssm"] + bx[:, 0]           # (B, di, ds)
    y = jnp.einsum("bds,bs->bd", h, c_mat[:, 0])[:, None]
    y = y + bcast_right(params["d_skip"], 3) * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out, {"conv": conv_state, "ssm": h}
