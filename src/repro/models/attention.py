"""GQA attention: blocked-flash for train/prefill, cached decode step.

Design (DESIGN §5):
  * query/output projections TP-sharded over `model` (heads dim); K/V
    projections replicated over `model` when n_kv_heads doesn't divide the
    axis (GQA with few KV heads — Megatron-style KV replication),
  * train/prefill uses a pure-JAX flash formulation: outer scan over query
    blocks, inner scan over KV blocks with an online softmax — activation
    memory O(q_block · kv_block) instead of O(T²),
  * sliding-window attention slices a static (window + q_block) KV span
    per query block, so SWA prefill FLOPs are O(T · window), not O(T²),
  * decode attends a (B, 1) query against the cache in one einsum; with
    B=1 long-context shapes the cache is sequence-sharded and GSPMD turns
    the softmax/PV reductions into cheap scalar all-reduces (flash-decode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope

NEG_INF = -1e30


def init_attention(key, cfg):
    d, kv, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim_
    h = cfg.n_heads_eff
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    so = (cfg.n_heads * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5
    dt = cfg.jnp_dtype
    wq = (jax.random.normal(ks[0], (d, h, hd)) * s).astype(dt)
    wo = (jax.random.normal(ks[3], (h, hd, d)) * so).astype(dt)
    if h != cfg.n_heads:
        # padded heads sit at the tail of each KV group (head layout is
        # (kv, g)-major); zero wo rows make them exactly inert (§Perf A2)
        g_eff = h // kv
        g_real = cfg.n_heads // kv
        inert = (jnp.arange(h) % g_eff) >= g_real
        wo = jnp.where(inert[:, None, None], 0.0, wo)
    return {
        "wq": wq,
        "wk": (jax.random.normal(ks[1], (d, kv, hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kv, hd)) * s).astype(dt),
        "wo": wo,
    }


def _qkv(params, x, positions, cfg):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _online_block(q, k, v, mask, m, l, acc, scale):
    """One online-softmax step.  q: (B,KV,G,qb,hd); k/v: (B,KV,kb,hd);
    mask: (qb,kb) or broadcastable; m/l: (B,KV,G,qb); acc like q."""
    s = jnp.einsum("bkgqh,bkth->bkgqt", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgqt,bkth->bkgqh", p.astype(v.dtype), v).astype(jnp.float32)
    return m_new, l_new, acc_new


def flash_attention(q, k, v, cfg, q_offset: int = 0):
    """Causal (optionally sliding-window) blocked attention.

    q: (B, T, H, hd); k, v: (B, S, KV, hd).  q_offset: absolute position of
    q[0] within the kv sequence (prefill continuation).  Returns (B,T,H,hd).
    """
    b, t, h, hd = q.shape
    s_len = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    scale = hd ** -0.5
    qb = min(cfg.q_block, t)
    while t % qb:
        qb //= 2
    n_qb = t // qb
    window = cfg.sliding_window

    # (B, KV, G, T, hd) grouped layout
    qg = q.reshape(b, t, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)    # (B, KV, S, hd)
    vg = v.transpose(0, 2, 1, 3)

    if window and window < s_len:
        span = window + qb            # static KV span per query block
        kb = min(cfg.kv_block, span)
        while span % kb:
            kb //= 2
        n_kb = span // kb
    else:
        window = 0
        kb = min(cfg.kv_block, s_len)
        while s_len % kb:
            kb //= 2
        n_kb = s_len // kb

    def q_block_fn(qi):
        qblk = jax.lax.dynamic_slice_in_dim(qg, qi * qb, qb, axis=3)
        q_pos = q_offset + qi * qb + jnp.arange(qb)
        if window:
            # static-size span ending at the block's last query
            start = jnp.maximum(0, q_offset + (qi + 1) * qb - span)
        else:
            start = 0

        def kv_step(carry, ki):
            m, l, acc = carry
            k_start = start + ki * kb
            kblk = jax.lax.dynamic_slice_in_dim(kg, k_start, kb, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(vg, k_start, kb, axis=2)
            k_pos = k_start + jnp.arange(kb)
            mask = q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            m, l, acc = _online_block(qblk, kblk, vblk, mask, m, l, acc,
                                      scale)
            return (m, l, acc), None

        m0 = jnp.full((b, kvh, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qb), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(n_kb))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = jax.lax.map(q_block_fn, jnp.arange(n_qb))   # (n_qb,B,KV,G,qb,hd)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, kvh, g, t, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, hd)


def _flash_kernel_sharded(q, k, v, cfg):
    """Pallas flash kernel under shard_map: heads@model, batch@batch-axes;
    each device expands its local heads' KV (GQA) and runs the fused
    kernel on its shard (§Perf A3)."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from ..models import sharding as shd
    from ..kernels.flash_attention import flash_attention_kernel

    mesh = shd.FLASH_MESH
    ba = shd.batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    if q.shape[0] % n_b:
        ba, n_b = (), 1          # small batch: replicate
    h_ok = cfg.n_heads_eff % mesh.shape["model"] == 0
    ha = "model" if h_ok else None
    qspec = P(ba if ba else None, None, ha, None)
    kvspec = P(ba if ba else None, None, None, None)
    g = cfg.n_heads_eff // cfg.n_kv_heads

    def local(qv, kv, vv):
        hl = qv.shape[2]
        base = _jax.lax.axis_index("model") * hl if ha else 0
        kv_ids = (base + jnp.arange(hl)) // g
        kl = jnp.take(kv, kv_ids, axis=2)
        vl = jnp.take(vv, kv_ids, axis=2)
        return flash_attention_kernel(qv, kl, vl,
                                      window=cfg.sliding_window,
                                      q_block=cfg.q_block,
                                      kv_block=cfg.kv_block)

    return _jax.shard_map(local, mesh=mesh,
                          in_specs=(qspec, kvspec, kvspec),
                          out_specs=qspec, check_vma=False)(q, k, v)


def attention_block(params, x, positions, cfg):
    """Full attention sub-layer for train/prefill: qkv → flash → out proj."""
    from ..models import sharding as shd
    q, k, v = _qkv(params, x, positions, cfg)
    if cfg.use_flash_kernel and shd.FLASH_MESH is not None:
        o = _flash_kernel_sharded(q, k, v, cfg)
    else:
        o = flash_attention(q, k, v, cfg)
    return jnp.einsum("bthk,hkd->btd", o, params["wo"])


# ------------------------------------------------------------------ decode
def init_kv_cache(batch: int, cfg, max_len: int, dtype):
    """Cache length: SWA models only keep the window (ring buffer)."""
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, s, kv, hd), dtype),
        "v": jnp.zeros((batch, s, kv, hd), dtype),
    }


def decode_attention_block(params, x, cache, step, cfg):
    """One-token decode.  x: (B, 1, D); step: scalar int32 (tokens already
    in cache).  Returns (out (B,1,D), new_cache)."""
    b = x.shape[0]
    s_cache = cache["k"].shape[1]
    q, k, v = _qkv(params, x, jnp.full((b, 1), step), cfg)
    slot = step % s_cache if cfg.sliding_window else step
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)

    h, kvh, hd = q.shape[2], cfg.n_kv_heads, cfg.head_dim_
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd).transpose(0, 2, 3, 1, 4)
    s = jnp.einsum("bkgqh,bskh->bkgqs", qg, ck).astype(jnp.float32)
    s *= hd ** -0.5
    idx = jnp.arange(s_cache)
    valid = idx <= slot if not cfg.sliding_window else (
        (idx <= slot) | (step >= s_cache))
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(cv.dtype), cv)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, hd)
    out = jnp.einsum("bthk,hkd->btd", o, params["wo"])
    return out, {"k": ck, "v": cv}
