"""Shared model layers: norms, MLPs, rotary embeddings, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bcast_right(a, ndim: int):
    """Right-align ``a`` against an ``ndim``-rank operand by prepending
    unit axes — the explicit form of numpy rank promotion, legal under
    ``jax_numpy_rank_promotion='raise'``."""
    return a.reshape((1,) * (ndim - a.ndim) + a.shape)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    scale = bcast_right(scale.astype(jnp.float32), x.ndim)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dt)


def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w1": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if mlp_type == "swiglu":
        p["w3"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp(params, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    else:  # gelu
        h = jax.nn.gelu(x @ params["w1"])
    return h @ params["w2"]


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) \
        * bcast_right(freqs, positions.ndim + 1)        # (..., T, hd/2)
    cos = bcast_right(jnp.cos(ang)[..., None, :], x.ndim)
    sin = bcast_right(jnp.sin(ang)[..., None, :], x.ndim)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- embeddings
def init_embeddings(key, padded_vocab: int, d_model: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "embed": (jax.random.normal(k1, (padded_vocab, d_model))
                  * 0.02).astype(dtype),
        "lm_head": (jax.random.normal(k2, (padded_vocab, d_model))
                    * d_model ** -0.5).astype(dtype),
        "final_norm": jnp.ones((d_model,), dtype),
    }


def embed_tokens(params, tokens):
    return params["embed"][tokens]


def lm_logits(params, h, vocab_size: int):
    """Final norm + projection; padded vocab tail masked to -inf."""
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", h, params["lm_head"])
    padded = logits.shape[-1]
    if padded > vocab_size:
        mask = jnp.arange(padded) < vocab_size
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits
