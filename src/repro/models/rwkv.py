"""RWKV-6 (Finch) block: data-dependent decay time-mix + channel-mix.

TPU adaptation: the recurrence S_t = diag(w_t)·S_{t−1} + k_t⊗v_t is
evaluated in *chunks* (GLA-style): within a chunk all pairwise decay
factors are exponentials of **non-positive** log-decay differences
(Λ_{i−1}−Λ_j ≤ 0 for j < i), so the chunked form is numerically safe with
no divisions; across chunks a `lax.scan` carries the (B, H, K, V) state.
Wall-clock-wise this trades the sequential T-step recurrence for
T/c matmul-shaped chunk updates — the MXU-friendly formulation.

Head count (d_model/64 = 40 for rwkv6-3b) does not divide the 16-way
`model` axis, so time-mix projections are FSDP-sharded only and the
`model` axis earns its keep in the channel-mix (DESIGN §5/§6 note).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import bcast_right

LOG_W_MIN = -5.0        # decay floor: w ≥ e^-5 ≈ 0.007 — bounds the
LOG_W_MAX = -1e-4       # factored-chunk exponents to e^{|min|·c/2} ≤ e^80


def init_rwkv_time_mix(key, cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    h = d // hd
    ks = jax.random.split(key, 10)
    dt = cfg.jnp_dtype
    lora = 64
    return {
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_g": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "wr": (jax.random.normal(ks[0], (d, d)) * d ** -0.5).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, d)) * d ** -0.5).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, d)) * d ** -0.5).astype(dt),
        "wg": (jax.random.normal(ks[3], (d, d)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[4], (d, d)) * d ** -0.5
               / (2 * cfg.n_layers) ** 0.5).astype(dt),
        "w0": jnp.zeros((d,), jnp.float32),          # decay base
        "w_lora_a": (jax.random.normal(ks[5], (d, lora)) * d ** -0.5
                     ).astype(dt),
        "w_lora_b": (jax.random.normal(ks[6], (lora, d)) * lora ** -0.5
                     ).astype(dt),
        "u": (jax.random.normal(ks[7], (h, hd)) * 0.1).astype(jnp.float32),
        "ln_g": jnp.ones((d,), dt), "ln_b": jnp.zeros((d,), dt),
    }


def _shift(x, state=None):
    """Token shift: previous token's features (0 / carried state at t=0)."""
    if state is None:
        return jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    return jnp.concatenate([state[:, None], x[:, :-1]], axis=1)


def _heads(x, hd):
    b, t, d = x.shape
    return x.reshape(b, t, d // hd, hd)


def _group_norm(y, gamma, beta, eps=1e-5):
    """Per-head normalization over the head dim.  y: (B, T, H, hd)."""
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    b_, t, h, hd = y.shape
    yn = yn.reshape(b_, t, h * hd)
    return (yn * bcast_right(gamma.astype(jnp.float32), yn.ndim)
            + bcast_right(beta.astype(jnp.float32), yn.ndim))


def _rkvgw(params, x, xx, cfg):
    def mix(mu):
        return x + (xx - x) * bcast_right(mu, x.ndim)
    hd = cfg.rwkv_head_size
    r = _heads(mix(params["mu_r"]) @ params["wr"], hd)
    k = _heads(mix(params["mu_k"]) @ params["wk"], hd)
    v = _heads(mix(params["mu_v"]) @ params["wv"], hd)
    g = jax.nn.silu(mix(params["mu_g"]) @ params["wg"])
    w_pre = (bcast_right(params["w0"], x.ndim)
             + (jnp.tanh(mix(params["mu_w"]) @ params["w_lora_a"])
                @ params["w_lora_b"]).astype(jnp.float32))
    log_w = jnp.clip(-jnp.exp(w_pre), LOG_W_MIN, LOG_W_MAX)
    return (r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), g, _heads(log_w, hd))


def _wkv_chunk(r, k, v, lw, u, s0):
    """One chunk, GLA-style factored matmuls (MXU-shaped, DESIGN §3).

    r/k/lw: (B, c, H, K); v: (B, c, H, V); s0: (B, H, K, V).
    Intra-chunk scores factor as
      sc[i,j] = Σ_k (r_i e^{Λ_{i−1}−Λ̄}) (k_j e^{Λ̄−Λ_j})
    with Λ̄ the mid-chunk cumulative log-decay — exponents are bounded by
    |LOG_W_MIN|·c/2 ≤ 80, safe in f32, and the (c,c,K) pairwise tensor of
    the naive form (which dominated HBM traffic in the first rwkv dry-run)
    never materializes.  Returns (y (B, c, H, V), s_end)."""
    c = r.shape[1]
    lam = jnp.cumsum(lw, axis=1)             # Λ_i inclusive
    lam_m1 = lam - lw                        # Λ_{i-1} (Λ_0 = 0)
    base = lam[:, c // 2][:, None]           # Λ̄ per (B, 1, H, K)
    # state passthrough: exp(Λ_{i-1}) ≤ 1, always safe
    y = jnp.einsum("bchk,bhkv->bchv", r * jnp.exp(lam_m1), s0)
    # intra-chunk pairs j < i via two bounded factors
    r_f = r * jnp.exp(lam_m1 - base)         # (B, c, H, K)
    k_f = k * jnp.exp(base - lam)            # (B, c, H, K)
    sc = jnp.einsum("bihk,bjhk->bhij", r_f, k_f)     # (B, H, c, c)
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, None]
    sc = jnp.where(mask, sc, 0.0)
    # diagonal bonus u
    bonus = jnp.einsum("bchk,hk,bchk->bch", r, u, k)
    y = y + jnp.einsum("bhij,bjhv->bihv", sc, v) + bonus[..., None] * v
    # state update: S' = exp(Λ_last)∘S0 + Σ_j exp(Λ_last − Λ_j) k_j ⊗ v_j
    k_dec = k * jnp.exp(lam[:, -1:] - lam)   # exponents ≤ 0, safe
    s_end = (jnp.exp(lam[:, -1])[..., None] * s0
             + jnp.einsum("bjhk,bjhv->bhkv", k_dec, v))
    return y, s_end


def rwkv_time_mix(params, x, cfg, shift_state=None, wkv_state=None):
    """x: (B, T, D) → (out, (last_x, wkv_state))."""
    b, t, d = x.shape
    hd = cfg.rwkv_head_size
    h = d // hd
    xx = _shift(x, shift_state)
    r, k, v, g, lw = _rkvgw(params, x, xx, cfg)
    c = min(cfg.time_chunk, t)
    while t % c:
        c //= 2
    nc = t // c
    s0 = (jnp.zeros((b, h, hd, hd), jnp.float32)
          if wkv_state is None else wkv_state)

    def body(s, ci):
        # dynamic_slice per chunk — no full-T (nc, B, c, H, K) restack copy
        sl = [jax.lax.dynamic_slice_in_dim(a, ci * c, c, axis=1)
              for a in (r, k, v, lw)]
        y, s2 = _wkv_chunk(*sl, params["u"], s)
        return s2, y

    s_f, ys = jax.lax.scan(body, s0, jnp.arange(nc))
    y = ys.swapaxes(0, 1).reshape(b, t, h, hd)
    y = _group_norm(y, params["ln_g"], params["ln_b"])
    out = (y.astype(x.dtype) * g) @ params["wo"]
    return out, (x[:, -1], s_f)


def decode_rwkv_time_mix(params, x, cache, cfg):
    """One token.  x: (B, 1, D); cache: {"x": (B,D), "s": (B,H,K,V)}."""
    xx = cache["x"][:, None]
    r, k, v, g, lw = _rkvgw(params, x, xx, cfg)
    s = cache["s"]
    kv = jnp.einsum("bchk,bchv->bhkv", k, v)          # c = 1
    y = (jnp.einsum("bchk,bhkv->bchv", r, s)
         + jnp.einsum("bchk,hk,bchk->bch", r, params["u"], k)[..., None]
         * v)
    s_new = jnp.exp(lw[:, 0])[..., None] * s + kv
    y = _group_norm(y, params["ln_g"], params["ln_b"])
    out = (y.astype(x.dtype) * g) @ params["wo"]
    return out, {"x": x[:, -1], "s": s_new}


# ------------------------------------------------------------ channel mix
def init_rwkv_channel_mix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.jnp_dtype
    return {
        "mu_k": jnp.full((d,), 0.5, dt), "mu_r": jnp.full((d,), 0.5, dt),
        "wk": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt),
        "wv": (jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dt),
        "wr": (jax.random.normal(ks[2], (d, d)) * d ** -0.5).astype(dt),
    }


def rwkv_channel_mix(params, x, shift_state=None):
    xx = _shift(x, shift_state)
    xk = x + (xx - x) * bcast_right(params["mu_k"], x.ndim)
    xr = x + (xx - x) * bcast_right(params["mu_r"], x.ndim)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"]))
    return jax.nn.sigmoid(xr @ params["wr"]) * (kk @ params["wv"]), x[:, -1]


def decode_rwkv_channel_mix(params, x, cache):
    out, last = rwkv_channel_mix(params, x, shift_state=cache["x"])
    return out, {"x": last}
