"""Sparse QAP objective and O(deg) delta-gain machinery (guide §2.1).

The paper's speedups over Brandfass et al.:
  * initial objective in O(m) over the edges of G_C instead of O(n²),
  * swap gain in O(deg(u) + deg(v)) with the online distance oracle instead
    of O(n) rows of dense matrices.

Conventions: ``perm[u]`` = PE assigned to process u (a bijection).  The
guide writes J(C,D,Π) = Σ C_{Π(i),Π(j)} D_{i,j} over PE pairs (i,j); with
perm as process→PE this is identically Σ_{(u,v)∈E[C]} C_uv · D(perm[u],
perm[v]) which is the form we compute (each undirected edge counted once;
multiply by 2 for the double-sum convention — we keep the single-count form
consistently across construction, search, evaluator, and tests).
"""

from __future__ import annotations

import numpy as np

from .graph import CommGraph, csr_expand
from .hierarchy import Hierarchy   # noqa: F401  (re-exported type hint)


def qap_objective(g: CommGraph, h, perm: np.ndarray) -> float:
    """J(C, D, Π) in O(m) using the online distance oracle.  ``h`` is any
    machine model with a vectorized ``distance`` (Hierarchy or
    :class:`~repro.topology.Topology`)."""
    u, v, w = g.edge_list()
    return float(np.sum(w * h.distance(perm[u], perm[v])))


def qap_objective_dense(C: np.ndarray, D: np.ndarray,
                        perm: np.ndarray) -> float:
    """O(n²) dense reference (the Brandfass-et-al. formulation); used as the
    oracle in tests.  Counts each unordered pair once to match
    :func:`qap_objective`."""
    Dp = D[np.ix_(perm, perm)]
    return float(np.sum(np.triu(C * Dp, k=1)))


def swap_gain(g: CommGraph, h, perm: np.ndarray,
              u: int, v: int) -> float:
    """Gain (objective decrease, positive = improvement) of swapping the PEs
    assigned to processes u and v.  O(deg(u) + deg(v))."""
    pu, pv = perm[u], perm[v]
    gain = 0.0
    nb_u, w_u = g.neighbors(u), g.weights(u)
    mask = nb_u != v
    nb, w = nb_u[mask], w_u[mask]
    tgt = perm[nb]
    gain += float(np.sum(w * (h.distance(pu, tgt) - h.distance(pv, tgt))))
    nb_v, w_v = g.neighbors(v), g.weights(v)
    mask = nb_v != u
    nb, w = nb_v[mask], w_v[mask]
    tgt = perm[nb]
    gain += float(np.sum(w * (h.distance(pv, tgt) - h.distance(pu, tgt))))
    # the (u,v) edge itself contributes C_uv * D(pu,pv) before and after the
    # swap (D symmetric) — no delta.
    return gain


def apply_swap(perm: np.ndarray, u: int, v: int) -> None:
    perm[u], perm[v] = perm[v], perm[u]


def batched_swap_gains(g: CommGraph, h, perm: np.ndarray,
                       pairs: np.ndarray) -> np.ndarray:
    """Vectorized gains for many candidate pairs at once (host/numpy path).

    ``pairs``: (P, 2) int array of process pairs.  Complexity
    O(Σ deg(u)+deg(v)) — the paper's sparse bound, batched.
    """
    pairs = np.asarray(pairs, dtype=np.int64)
    if len(pairs) == 0:
        return np.zeros(0)
    us, vs = pairs[:, 0], pairs[:, 1]

    def side(a_arr, b_arr):
        # flattened neighbor expansion for all a in a_arr — one
        # repeat/offset gather, no per-pair Python loop on this hot path
        idx, _, cnt = csr_expand(g.xadj, a_arr)
        nb = g.adjncy[idx]
        w = g.adjwgt[idx]
        rep_a = np.repeat(a_arr, cnt)
        rep_b = np.repeat(b_arr, cnt)
        valid = nb != rep_b
        pa, pb, tgt = perm[rep_a], perm[rep_b], perm[nb]
        contrib = np.where(valid,
                           w * (h.distance(pa, tgt) - h.distance(pb, tgt)),
                           0.0)
        out = np.zeros(len(a_arr))
        seg = np.repeat(np.arange(len(a_arr)), cnt)
        np.add.at(out, seg, contrib)
        return out

    return side(us, vs) + side(vs, us)


def dense_gain_matrix(C: np.ndarray, D: np.ndarray,
                      perm: np.ndarray) -> np.ndarray:
    """Full pair-exchange gain matrix via the matmul formulation (DESIGN §3).

    Derivation (C, D symmetric, zero diagonal; B[u,v] = D[perm[u], perm[v]]):
      gain(u,v) = Σ_{k∉{u,v}} (C[u,k] − C[v,k]) (B[u,k] − B[v,k])
    Extending the sum over all k adds 2·C[u,v]·B[u,v], and with
    M := C @ B.T (M[a,b] = Σ_k C[a,k] B[b,k]):
      gain(u,v) = M[u,u] + M[v,v] − M[u,v] − M[v,u] − 2·C[u,v]·B[u,v]
    Positive = improvement (objective decreases by gain).

    This dense form is the TPU-friendly target of the Pallas kernel
    ``repro.kernels.swap_gain``; this numpy version is its semantic spec.
    """
    B = D[np.ix_(perm, perm)]
    M = C @ B.T
    d = np.diag(M)
    G = d[:, None] + d[None, :] - M - M.T - 2.0 * C * B
    np.fill_diagonal(G, 0.0)
    return G
