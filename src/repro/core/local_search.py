"""Pair-exchange local search (guide §2.1).

``--local_search_neighborhood=`` one of
  nsquare        — Heider's cyclic N² pair exchange,
  nsquarepruned  — Brandfass et al.'s pruned N²,
  communication  — the paper's N_C^d neighborhood over the communication
                   graph (default, with --communication_neighborhood_dist=10).

All variants use the paper's *sparse* O(deg) gain (objective.swap_gain) and
update the objective incrementally — the guide's central speedup over the
O(n)-per-swap dense formulation.

Neighborhoods live in a registry: ``@register_neighborhood("name")``
wraps a candidate-pair generator ``fn(g, *, dist, max_pairs)`` — plus a
``seed`` kwarg for randomized generators (auto-detected from the
signature; see :func:`register_neighborhood`) — and makes it addressable
from ``MappingSpec``, the ``viem`` CLI, and ``Mapper`` without touching
core dispatch.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .graph import CommGraph, csr_expand
from .objective import batched_swap_gains, qap_objective, swap_gain


@dataclass
class SearchStats:
    swaps: int = 0
    evaluated: int = 0
    initial_objective: float = 0.0
    final_objective: float = 0.0
    objective_trace: list = field(default_factory=list)
    # engine counter telemetry (repro.obs.telemetry.EngineTelemetry) —
    # attached by the device engine when collection is requested; host
    # drivers leave it None
    telemetry: object = None


# ---------------------------------------------------------------- registry
@dataclass(frozen=True)
class Neighborhood:
    """A registered candidate-pair generator plus its driver policy
    (``shuffle`` — whether the sequential search visits pairs in random
    order, the guide's behavior for the communication neighborhood).
    ``weight_dependent`` declares that the generator reads edge weights;
    it widens the Mapper's candidate-pair cache key so same-structure,
    different-weight graphs are not served stale pairs.  ``seeded``
    declares that the generator reads its ``seed`` keyword; deterministic
    generators set it False, are called *without* a seed (so a signature
    cannot silently advertise randomness it does not have), and share one
    Mapper pair-cache entry across seeds."""
    name: str
    pairs: Callable          # fn(g, *, dist, max_pairs[, seed]) -> (P, 2) i64
    shuffle: bool = False
    weight_dependent: bool = False
    seeded: bool = False

    def generate(self, g: CommGraph, *, dist: int, seed: int,
                 max_pairs: int) -> np.ndarray:
        """Invoke the generator, forwarding ``seed`` only when it
        declares it uses one."""
        kw = {"dist": dist, "max_pairs": max_pairs}
        if self.seeded:
            kw["seed"] = seed
        return self.pairs(g, **kw)


NEIGHBORHOODS: dict[str, Neighborhood] = {}


def register_neighborhood(name: str, shuffle: bool = False,
                          weight_dependent: bool = False,
                          seeded: bool | None = None) -> Callable:
    """Register ``fn(g, *, dist, max_pairs)`` (or, when seeded,
    ``fn(g, *, dist, seed, max_pairs)``) as a local-search neighborhood.
    Registered names auto-populate CLI ``choices`` and are valid
    ``MappingSpec.neighborhood`` values.  Pass ``weight_dependent=True``
    if the generator reads ``g.adjwgt``.

    ``seeded`` defaults to signature inspection: a generator that names
    an explicit ``seed`` parameter receives the spec's seed (and its
    pair sets are cached per seed); one that does not is treated as
    deterministic — advertising a seed and silently ignoring it is no
    longer possible.  Pass ``seeded`` explicitly to override (e.g. a
    ``**kwargs`` generator that does sample)."""
    def deco(fn: Callable) -> Callable:
        if name in NEIGHBORHOODS:
            raise ValueError(f"neighborhood {name!r} is already registered")
        is_seeded = seeded
        if is_seeded is None:
            import inspect
            is_seeded = "seed" in inspect.signature(fn).parameters
        NEIGHBORHOODS[name] = Neighborhood(name, fn, shuffle,
                                           weight_dependent, is_seeded)
        return fn
    return deco


def resolve_neighborhood(name: str) -> Neighborhood:
    try:
        return NEIGHBORHOODS[name]
    except KeyError:
        raise ValueError(
            f"unknown local search neighborhood {name!r}; registered: "
            f"{sorted(NEIGHBORHOODS)}") from None


def list_neighborhoods() -> list[str]:
    return sorted(NEIGHBORHOODS)


def candidate_pairs(name: str, g: CommGraph, dist: int = 10, seed: int = 0,
                    max_pairs: int = 2_000_000) -> np.ndarray:
    """Candidate pairs of the named registered neighborhood."""
    return resolve_neighborhood(name).generate(
        g, dist=dist, seed=seed, max_pairs=max_pairs)


# ------------------------------------------------------------ neighborhoods
def communication_pairs(g: CommGraph, dist: int = 1,
                        max_pairs: int = 2_000_000) -> np.ndarray:
    """Candidate pairs of N_C^dist: processes with graph distance < dist+1
    ... precisely the guide's N_C for dist=1 (endpoints of an edge) and the
    augmented N_C^d for d=dist (graph distance <= dist, i.e. < d+1 hops;
    the guide's 'distance less than d' with its 1-based convention).

    BFS with depth cutoff from every vertex; deduplicated to u < v and
    returned in (u, v)-lexicographic order.  Fully deterministic — no
    seed parameter, and the registry entry declares ``seeded=False`` so
    sessions share one cached pair set across seeds.  If the candidate
    set would exceed ``max_pairs`` the BFS depth is reduced — N_C^d
    degenerates to N² for dense graphs and large d (guide §2.1:
    N_C ⊆ N_C^2 ⊆ … ⊆ N_C^n = N²), so capping is semantically a fallback
    to a smaller d.
    """
    if dist <= 1:
        u, v, _ = g.edge_list()
        return np.stack([u, v], axis=1)
    d = dist
    while True:
        pairs = _bfs_pairs(g, d, max_pairs)
        if pairs is not None:
            return pairs
        d -= 1


# flat neighbor expansions materialized per slice of a BFS level — bounds
# peak memory near the max_pairs cap instead of one whole dense level
_BFS_CHUNK = 4_000_000


def _bfs_pairs(g: CommGraph, depth: int, max_pairs: int) -> np.ndarray | None:
    """All-sources depth-limited BFS as CSR frontier expansion.

    All n BFS trees advance one level per iteration as flat
    (source, vertex) key arrays: a repeat/offset gather expands the
    frontier vertices' CSR rows, and sorted numpy set ops (``unique`` /
    ``isin`` / ``union1d``) deduplicate within the level and against
    everything already seen — no per-vertex Python loop.  Levels are
    expanded in ``_BFS_CHUNK``-bounded slices so the ``max_pairs`` cap
    can fire (returning ``None``; the caller retries with a smaller
    depth — same cap semantics as before) without first materializing a
    whole dense level.  Returns the u < v pairs sorted
    lexicographically."""
    n = g.n
    f_src = np.arange(n, dtype=np.int64)          # frontier: (source,
    f_v = f_src.copy()                            #            vertex) pairs
    seen = f_src * n + f_src                      # sorted unique keys
    out_u: list[np.ndarray] = []
    out_v: list[np.ndarray] = []
    total = 0
    for _ in range(depth):
        cnt_all = g.xadj[f_v + 1] - g.xadj[f_v]
        cum = np.cumsum(cnt_all)
        flat = int(cum[-1]) if len(cum) else 0
        if flat == 0:
            break
        splits = np.searchsorted(cum, np.arange(_BFS_CHUNK, flat,
                                                _BFS_CHUNK)) + 1
        bounds = [0, *splits.tolist(), len(f_v)]
        nxt_src: list[np.ndarray] = []
        nxt_v: list[np.ndarray] = []
        for lo, hi in zip(bounds, bounds[1:]):
            pos, _, cnt = csr_expand(g.xadj, f_v[lo:hi])
            if len(pos) == 0:
                continue
            key = np.unique(np.repeat(f_src[lo:hi], cnt) * n
                            + g.adjncy[pos])
            key = key[~np.isin(key, seen, assume_unique=True)]
            if len(key) == 0:
                continue
            seen = np.union1d(seen, key)
            s_new, v_new = key // n, key % n
            keep = v_new > s_new
            total += int(keep.sum())
            if total > max_pairs:
                return None
            out_u.append(s_new[keep])
            out_v.append(v_new[keep])
            nxt_src.append(s_new)
            nxt_v.append(v_new)
        if not nxt_src:
            break
        f_src = np.concatenate(nxt_src)           # order is irrelevant:
        f_v = np.concatenate(nxt_v)               # dedupe is via `seen`,
                                                  # output is lexsorted
    if total == 0:
        return np.zeros((0, 2), dtype=np.int64)
    pairs = np.stack([np.concatenate(out_u), np.concatenate(out_v)], axis=1)
    return pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]


def nsquare_pairs(n: int) -> np.ndarray:
    iu, iv = np.triu_indices(n, k=1)
    return np.stack([iu, iv], axis=1).astype(np.int64)


def pruned_pairs(g: CommGraph) -> np.ndarray:
    """Brandfass-style pruning: skip pairs of two isolated processes (their
    swap can never change the objective)."""
    deg = np.diff(g.xadj)
    active = np.nonzero(deg > 0)[0]
    idle = np.nonzero(deg == 0)[0]
    iu, iv = np.triu_indices(len(active), k=1)
    pairs = [np.stack([active[iu], active[iv]], axis=1)]
    if len(idle):
        # active-idle pairs still matter (move an active process elsewhere)
        au = np.repeat(active, len(idle))
        iv2 = np.tile(idle, len(active))
        lo, hi = np.minimum(au, iv2), np.maximum(au, iv2)
        pairs.append(np.stack([lo, hi], axis=1))
    return np.concatenate(pairs, axis=0).astype(np.int64)


# None of the built-in generators is randomized (`seeded=False`): the
# pair sets are pure functions of the graph; the spec's seed drives only
# the sequential driver's shuffle order.
@register_neighborhood("communication", shuffle=True)
def _communication_neighborhood(g: CommGraph, *, dist: int = 10,
                                max_pairs: int = 2_000_000) -> np.ndarray:
    return communication_pairs(g, dist, max_pairs=max_pairs)


@register_neighborhood("nsquare")
def _nsquare_neighborhood(g: CommGraph, **_) -> np.ndarray:
    return nsquare_pairs(g.n)


@register_neighborhood("nsquarepruned")
def _pruned_neighborhood(g: CommGraph, **_) -> np.ndarray:
    return pruned_pairs(g)


# ------------------------------------------------------------------ drivers
def _cyclic_search(g: CommGraph, h, perm: np.ndarray,
                   pairs: np.ndarray, shuffle: bool, seed: int,
                   max_sweeps: int = 50) -> SearchStats:
    """Shared driver: visit candidate pairs cyclically (optionally in random
    order, re-shuffled per cycle), swap on positive gain, terminate after a
    full cycle (|pairs| tries) without success — the guide's termination
    rule ('local search terminates after m unsuccessful swaps')."""
    stats = SearchStats()
    stats.initial_objective = qap_objective(g, h, perm)
    cur = stats.initial_objective
    stats.objective_trace.append(cur)
    if len(pairs) == 0:
        stats.final_objective = cur
        return stats
    rng = np.random.default_rng(seed)
    unsuccessful = 0
    for _sweep in range(max_sweeps):
        order = rng.permutation(len(pairs)) if shuffle else np.arange(len(pairs))
        for idx in order:
            u, v = int(pairs[idx, 0]), int(pairs[idx, 1])
            gain = swap_gain(g, h, perm, u, v)
            stats.evaluated += 1
            if gain > 1e-12:
                perm[u], perm[v] = perm[v], perm[u]
                cur -= gain
                stats.swaps += 1
                stats.objective_trace.append(cur)
                unsuccessful = 0
            else:
                unsuccessful += 1
                if unsuccessful >= len(pairs):
                    stats.final_objective = cur
                    return stats
    stats.final_objective = cur
    return stats


def local_search(g: CommGraph, h, perm: np.ndarray,
                 neighborhood: str = "communication",
                 communication_neighborhood_dist: int = 10,
                 seed: int = 0, max_sweeps: int = 50,
                 max_pairs: int = 2_000_000) -> SearchStats:
    """Improve ``perm`` in place.  Mirrors the guide's §4.1 flags; the
    neighborhood is resolved through the registry."""
    nb = resolve_neighborhood(neighborhood)
    pairs = nb.generate(g, dist=communication_neighborhood_dist, seed=seed,
                        max_pairs=max_pairs)
    return _cyclic_search(g, h, perm, pairs, shuffle=nb.shuffle, seed=seed,
                          max_sweeps=max_sweeps)


# ----------------------------------------------- batched sweep (TPU-shaped)
def parallel_sweep_search(g: CommGraph, h, perm: np.ndarray,
                          pairs: np.ndarray, max_sweeps: int = 64,
                          seed: int = 0) -> SearchStats:
    """TPU-adapted search (DESIGN §3): per sweep, evaluate *all* candidate
    pair gains at once (vectorized sparse gains — or the Pallas swap-gain
    kernel on device for dense n), then greedily apply a maximal set of
    non-conflicting positive-gain swaps (each process in at most one swap).

    Gains of simultaneous swaps interact when the swapped pairs communicate
    or share PE-adjacency, so the batch gains are treated as a *priority
    order*: candidates are applied greedily in descending batched-gain
    order, each verified with an exact O(deg) recomputed gain right before
    application (skip if no longer positive).  The batch does the expensive
    wide evaluation (device-friendly); verification is a cheap sparse pass.
    Objective is monotone by construction.
    """
    stats = SearchStats()
    stats.initial_objective = qap_objective(g, h, perm)
    cur = stats.initial_objective
    stats.objective_trace.append(cur)
    if len(pairs) == 0:
        stats.final_objective = cur
        return stats
    for _sweep in range(max_sweeps):
        gains = batched_swap_gains(g, h, perm, pairs)
        stats.evaluated += len(pairs)
        pos = np.nonzero(gains > 1e-12)[0]
        if len(pos) == 0:
            break
        order = pos[np.argsort(-gains[pos], kind="stable")]
        applied = 0
        for idx in order:
            u, v = int(pairs[idx, 0]), int(pairs[idx, 1])
            exact = swap_gain(g, h, perm, u, v)
            if exact > 1e-12:
                perm[u], perm[v] = perm[v], perm[u]
                cur -= exact
                applied += 1
        if applied == 0:
            break
        stats.swaps += applied
        stats.objective_trace.append(cur)
    stats.final_objective = cur
    return stats
