"""Communication-graph extraction: compiled XLA program → VieM model.

The paper's `generate_model` builds a model of computation and
communication by partitioning an application graph (guide §4.2) — the HPC
way, reproduced in :func:`generate_model`.  The framework way (DESIGN §2)
goes further: an SPMD program's collectives *are* its communication
pattern, so :func:`device_comm_graph` parses the compiled HLO and builds
the per-device-pair traffic graph under ring collective algorithms:

  all-reduce       ring edges, 2(g−1)/g · bytes per link
  all-gather       ring edges, (g−1) · shard bytes per link
  reduce-scatter   ring edges, (g−1)/g · bytes per link
  all-to-all       clique edges, bytes/g per pair
  collective-permute  explicit source→target edges

The result is *sparse* (rings and small cliques — the paper's sparsity
assumption holds by construction for mesh-parallel programs), symmetric,
and ready for ``Mapper.map`` (or a pre-lowered ``MappingPlan``).
"""

from __future__ import annotations

from collections import defaultdict

import numpy as np

from ..analysis.hlo import collective_instances
from .graph import CommGraph, from_edges
from .hierarchy import Hierarchy
from .partition import PartitionConfig, partition
from .construction import quotient


def device_comm_graph(hlo_text: str, n_devices: int) -> CommGraph:
    """Per-device-pair traffic graph (bytes) from optimized SPMD HLO."""
    acc: dict[tuple[int, int], float] = defaultdict(float)

    def add(a: int, b: int, w: float):
        if a == b or w <= 0:
            return
        key = (a, b) if a < b else (b, a)
        acc[key] += w

    for op, groups, nbytes, mult in collective_instances(hlo_text):
        if op == "collective-permute":
            for pair in groups:
                if len(pair) == 2:
                    add(pair[0], pair[1], mult * nbytes)
            continue
        for grp in groups:
            g = len(grp)
            if g <= 1:
                continue
            if op == "all-reduce":
                per_link = 2.0 * (g - 1) / g * nbytes
            elif op == "all-gather":
                per_link = (g - 1) * nbytes
            elif op in ("reduce-scatter",):
                per_link = (g - 1) / g * nbytes
            elif op in ("all-to-all", "ragged-all-to-all"):
                per_pair = nbytes / g
                for i in range(g):
                    for j in range(i + 1, g):
                        add(grp[i], grp[j], mult * per_pair)
                continue
            else:  # collective-broadcast & friends: ring price
                per_link = nbytes
            for i in range(g):
                add(grp[i], grp[(i + 1) % g], mult * per_link)

    if not acc:
        return CommGraph(np.zeros(n_devices + 1, np.int64),
                         np.zeros(0, np.int64), np.zeros(0),
                         np.ones(n_devices))
    keys = np.asarray(list(acc.keys()), dtype=np.int64)
    w = np.asarray(list(acc.values()))
    return from_edges(n_devices, keys[:, 0], keys[:, 1], w)


def generate_model(app_graph: CommGraph, k: int,
                   preconfiguration: str = "eco",
                   imbalance: float = 0.03, seed: int = 0
                   ) -> tuple[CommGraph, np.ndarray]:
    """The guide's `generate_model` (§4.2): partition an application graph
    into k blocks, return the quotient model whose vertices are blocks and
    whose edge weights are the summed inter-block edge weights, plus the
    block labels.  (`imbalance` is accepted for CLI fidelity; the
    partitioner balances perfectly, which satisfies any ε ≥ 0.)"""
    del imbalance
    cfg = PartitionConfig.preconfiguration(preconfiguration)
    labels = partition(app_graph, k, cfg, seed=seed)
    model = quotient(app_graph, labels, k)
    return model, labels


def logical_traffic_summary(g: CommGraph, h: Hierarchy,
                            perm: np.ndarray) -> dict:
    """Traffic volume per hierarchy level under assignment ``perm`` —
    reported next to the QAP objective in benchmarks (bytes that cross a
    tray / superblock / pod boundary)."""
    u, v, w = g.edge_list()
    lvl = h.lca_level(perm[u], perm[v])
    out = {}
    for l in range(1, h.k + 1):
        out[f"level_{l}_bytes"] = float(np.sum(w[lvl == l]))
    return out
