"""Initial mapping construction algorithms (guide §2.2, §4.1).

``--construction_algorithm=`` one of
  identity, random, growing, hierarchybottomup, hierarchytopdown (default).

All return ``perm`` with perm[u] = PE assigned to process u (a bijection on
[0, n)).  n must equal the machine's PE count.

``h`` is the machine model: a legacy ``Hierarchy`` / tree-family topology
(runs the guide's exact factor-driven recursion) or any
:class:`~repro.topology.Topology` — ``hierarchytopdown`` then recurses
through the topology's ``split()`` hook instead of hierarchy factors.

Algorithms live in a registry: decorate a ``fn(g, h, *, seed, cfg)`` with
``@register_construction("name")`` and it becomes addressable from
``MappingSpec``, the ``viem`` CLI (auto-populated ``choices``), and
``Mapper`` — no core edits needed.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from .graph import CommGraph, contract
from .hierarchy import Hierarchy
from .partition import PartitionConfig, partition


def quotient(g: CommGraph, labels: np.ndarray, k: int) -> CommGraph:
    """Cluster quotient graph: vertices = blocks, edge weights = summed
    inter-block communication (the guide's `generate_model` semantics).
    A thin alias of the shared :func:`repro.core.graph.contract`."""
    return contract(g, labels, k)


# ---------------------------------------------------------------- registry
CONSTRUCTIONS: dict[str, Callable] = {}


def register_construction(name: str) -> Callable:
    """Register ``fn(g, h, *, seed, cfg)`` as a construction algorithm.

    Registered names auto-populate CLI ``choices`` and are valid
    ``MappingSpec.construction`` values."""
    def deco(fn: Callable) -> Callable:
        if name in CONSTRUCTIONS:
            raise ValueError(f"construction {name!r} is already registered")
        CONSTRUCTIONS[name] = fn
        return fn
    return deco


def resolve_construction(name: str) -> Callable:
    try:
        return CONSTRUCTIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown construction algorithm {name!r}; registered: "
            f"{sorted(CONSTRUCTIONS)}") from None


def list_constructions() -> list[str]:
    return sorted(CONSTRUCTIONS)


# ------------------------------------------------------------ constructions
@register_construction("identity")
def identity_construction(g: CommGraph, h: Hierarchy, **_) -> np.ndarray:
    return np.arange(g.n, dtype=np.int64)


@register_construction("random")
def random_construction(g: CommGraph, h: Hierarchy, seed: int = 0,
                        **_) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(g.n).astype(np.int64)


@register_construction("growing")
def growing_construction(g: CommGraph, h: Hierarchy, seed: int = 0,
                         **_) -> np.ndarray:
    """Greedy graph growing: repeatedly take the unassigned process with the
    strongest communication to the already-assigned set and give it the next
    PE — consecutive PEs are hierarchy-close, so strongly-communicating
    processes land close."""
    rng = np.random.default_rng(seed)
    n = g.n
    perm = np.full(n, -1, dtype=np.int64)
    attraction = np.full(n, -np.inf)
    start = int(rng.integers(n))
    attraction[start] = 0.0
    for rank in range(n):
        u = int(np.argmax(attraction))
        if attraction[u] == -np.inf:   # disconnected component: restart
            u = int(np.nonzero(perm < 0)[0][0])
        perm[u] = rank
        attraction[u] = -np.inf
        nb, wt = g.neighbors(u), g.weights(u)
        una = perm[nb] < 0
        upd = nb[una]
        a = attraction[upd]
        attraction[upd] = np.where(a == -np.inf, wt[una], a + wt[una])
    return perm


@register_construction("hierarchytopdown")
def hierarchy_top_down(g: CommGraph, h, seed: int = 0,
                       cfg: PartitionConfig | None = None, **_) -> np.ndarray:
    """The guide's most successful strategy: recursively partition G_C into
    perfectly balanced blocks matching the machine's natural decomposition,
    assign each block to one machine sub-group, recurse; the base case
    assigns ranks arbitrarily (all intra-leaf distances are equal).

    Tree-family machines (anything exposing hierarchy ``factors``) run the
    guide's exact factor-driven recursion; every other topology drives the
    recursion through its ``split()`` hook (torus sub-boxes, matrix
    farthest-pair halves, ...)."""
    if g.n != h.n_pe:
        raise ValueError(f"n processes ({g.n}) != n PEs ({h.n_pe})")
    cfg = cfg or PartitionConfig()
    if not hasattr(h, "factors"):
        return _split_top_down(g, h, seed, cfg)
    perm = np.full(g.n, -1, dtype=np.int64)
    factors = h.factors

    def rec(nodes: np.ndarray, lvl: int, base: int, seed_: int):
        if lvl <= 1 or len(nodes) <= factors[0]:
            perm[nodes] = base + np.arange(len(nodes))
            return
        a = factors[lvl - 1]
        sub, back = g.subgraph(nodes)
        labels = partition(sub, a, cfg, seed=seed_)
        stride = len(nodes) // a
        for b in range(a):
            rec(back[labels == b], lvl - 1, base + b * stride, seed_ * a + b + 1)

    rec(np.arange(g.n, dtype=np.int64), h.k, 0, seed)
    return perm


def _fit_block_sizes(labels: np.ndarray, k: int,
                     sizes: np.ndarray) -> np.ndarray:
    """Force block cardinalities to the target ``sizes`` by moving
    vertices from over-full to under-full blocks (the partitioner balances
    to n/k ± 1; split() parts can differ by one for odd sets)."""
    counts = np.bincount(labels, minlength=k)
    if np.array_equal(counts, sizes):
        return labels
    labels = labels.copy()
    for b_u in range(k):
        while counts[b_u] < sizes[b_u]:
            b_o = next(b for b in range(k) if counts[b] > sizes[b])
            v = np.nonzero(labels == b_o)[0][-1]
            labels[v] = b_u
            counts[b_o] -= 1
            counts[b_u] += 1
    return labels


def _split_top_down(g: CommGraph, topo, seed: int,
                    cfg: PartitionConfig) -> np.ndarray:
    """Generic top-down recursion over the topology's ``split()`` hook:
    partition the processes into blocks sized like the machine's natural
    sub-groups, assign block b to sub-group b, recurse."""
    perm = np.full(g.n, -1, dtype=np.int64)

    def rec(nodes: np.ndarray, pes: np.ndarray, seed_: int):
        parts = topo.split(pes) if len(nodes) > 1 else None
        if not parts or len(parts) <= 1:
            perm[nodes] = pes[:len(nodes)]
            return
        a = len(parts)
        sizes = np.array([len(p) for p in parts])
        sub, back = g.subgraph(nodes)
        labels = _fit_block_sizes(partition(sub, a, cfg, seed=seed_),
                                  a, sizes)
        for b, part in enumerate(parts):
            rec(back[labels == b], part, seed_ * a + b + 1)

    rec(np.arange(g.n, dtype=np.int64),
        np.arange(topo.n_pe, dtype=np.int64), seed)
    return perm


@register_construction("hierarchybottomup")
def hierarchy_bottom_up(g: CommGraph, h, seed: int = 0,
                        cfg: PartitionConfig | None = None, **_) -> np.ndarray:
    """Bottom-up: cluster processes into processors (blocks of a_1), build
    the quotient graph, cluster processors into nodes (blocks of a_2), …
    PE index = mixed-radix digits collected along the way."""
    if not hasattr(h, "factors"):
        raise ValueError(
            "hierarchybottomup needs a tree-family machine (hierarchy "
            f"factors); topology kind {getattr(h, 'kind', '?')!r} has "
            "none — use hierarchytopdown (split-driven) or growing")
    if g.n != h.n_pe:
        raise ValueError(f"n processes ({g.n}) != n PEs ({h.n_pe})")
    cfg = cfg or PartitionConfig()
    strides = h.strides
    offset = np.zeros(g.n, dtype=np.int64)      # accumulated PE offset
    cluster = np.arange(g.n, dtype=np.int64)    # current cluster of process
    cur = g
    for lvl, a in enumerate(h.factors):
        n_blocks = cur.n // a
        if n_blocks <= 1:
            labels = np.zeros(cur.n, dtype=np.int64)
        else:
            labels = partition(cur, n_blocks, cfg, seed=seed + lvl)
        # digit = position of each cluster within its block (stable order)
        digit = np.zeros(cur.n, dtype=np.int64)
        for b in range(max(1, n_blocks)):
            members = np.nonzero(labels == b)[0]
            digit[members] = np.arange(len(members))
        offset += digit[cluster] * strides[lvl]
        cluster = labels[cluster]
        cur = quotient(cur, labels, max(1, n_blocks))
        # clusters are equal-sized by construction — balance currency for
        # the next level is cluster cardinality, so weights reset to 1.
        cur.vwgt = np.ones(cur.n)
    return offset


def construct(name: str, g: CommGraph, h: Hierarchy, seed: int = 0,
              preconfiguration: str = "eco") -> np.ndarray:
    fn = resolve_construction(name)
    cfg = PartitionConfig.preconfiguration(preconfiguration)
    return fn(g, h, seed=seed, cfg=cfg)
