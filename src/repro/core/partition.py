"""Multilevel perfectly-balanced graph partitioning (KaHIP stand-in).

Guide §2.2: the top-down construction needs *perfectly balanced* partitions
("each block of the output partition has the specified amount of vertices")
— the Sanders–Schulz highly-balanced partitioning role.  KaHIP is external
C++, so we implement the full multilevel scheme in-framework:

  coarsening   : heavy-edge matching (sorted by rating w(e)/min(deg)) until
                 the graph is small or matching stalls,
  initial      : recursive bisection; each bisection seeds a BFS greedy
                 graph-growing region of exactly the target weight from the
                 best of several random seeds,
  refinement   : boundary pairwise-swap FM — moves are *swaps* of equal-
                 cardinality vertex pairs across the cut, so exact balance
                 is invariant at every step; with per-pass best-prefix
                 rollback (classic FM) and early stop.

`partition(g, k)` returns labels in [0,k) with |block| == n/k exactly when
k | n (the top-down construction's requirement), else ±1.

`preconfiguration` maps the guide's strong/eco/fast knobs onto (number of
initial-seed trials, FM passes, coarsening depth).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .graph import CommGraph, contract


@dataclass(frozen=True)
class PartitionConfig:
    seed_trials: int = 4       # greedy-growing restarts per bisection
    fm_passes: int = 3         # refinement passes per level
    coarsen_min: int = 64      # stop coarsening below this many vertices
    max_levels: int = 20

    @staticmethod
    def preconfiguration(name: str) -> "PartitionConfig":
        """The guide's --preconfiguration={strong,eco,fast} (§4.1/§4.2)."""
        if name == "strong":
            return PartitionConfig(seed_trials=12, fm_passes=8, coarsen_min=48)
        if name == "eco":
            return PartitionConfig()
        if name == "fast":
            return PartitionConfig(seed_trials=1, fm_passes=1, coarsen_min=128)
        raise ValueError(f"unknown preconfiguration {name!r}")


# ------------------------------------------------------------------ metrics
def cut_weight(g: CommGraph, labels: np.ndarray) -> float:
    u, v, w = g.edge_list()
    return float(np.sum(w[labels[u] != labels[v]]))


def block_sizes(labels: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(labels, minlength=k)


# --------------------------------------------------------------- coarsening
def _heavy_edge_matching(g: CommGraph, rng: np.random.Generator) -> np.ndarray:
    """Greedy heavy-edge matching; returns match[u] = partner or u."""
    n = g.n
    match = np.arange(n)
    order = rng.permutation(n)
    matched = np.zeros(n, dtype=bool)
    for u in order:
        if matched[u]:
            continue
        nb = g.neighbors(u)
        wt = g.weights(u)
        if len(nb) == 0:
            continue
        free = ~matched[nb]
        if not free.any():
            continue
        cand_nb, cand_wt = nb[free], wt[free]
        v = int(cand_nb[np.argmax(cand_wt)])
        match[u], match[v] = v, u
        matched[u] = matched[v] = True
    return match


def _contract(g: CommGraph, match: np.ndarray
              ) -> tuple[CommGraph, np.ndarray]:
    """Contract matched pairs; returns (coarse graph, fine->coarse map).
    Edge collapsing is the shared :func:`repro.core.graph.contract`."""
    rep = np.minimum(np.arange(g.n), match)     # pair representative
    uniq, cmap = np.unique(rep, return_inverse=True)
    return contract(g, cmap, len(uniq)), cmap


# ------------------------------------------------------ initial bisection
def _grow_region(g: CommGraph, target_n: float, rng: np.random.Generator,
                 trials: int) -> np.ndarray:
    """Greedy BFS graph-growing: returns bool mask of side-0 with exactly
    ``target_n`` vertices (best cut of `trials` seeds).

    Balance currency is vertex *cardinality*: the mapping use case assigns
    one process per vertex, and the bottom-up construction groups
    equal-sized clusters — in both cases blocks must have equal counts."""
    n = g.n
    target_n = int(round(target_n))
    best_mask, best_cut = None, np.inf
    for _ in range(max(1, trials)):
        seed = int(rng.integers(n))
        in_set = np.zeros(n, dtype=bool)
        gain = np.full(n, -np.inf)          # frontier attraction
        gain[seed] = 0.0
        count = 0
        for _step in range(n):
            if count >= target_n:
                break
            u = int(np.argmax(gain))
            if gain[u] == -np.inf:
                # disconnected: jump to any unused vertex
                rest = np.nonzero(~in_set)[0]
                if len(rest) == 0:
                    break
                u = int(rest[0])
            in_set[u] = True
            count += 1
            gain[u] = -np.inf
            nb, wt = g.neighbors(u), g.weights(u)
            upd = ~in_set[nb]
            gm = gain[nb[upd]]
            gain[nb[upd]] = np.where(gm == -np.inf, wt[upd], gm + wt[upd])
        u_, v_, w_ = g.edge_list()
        cut = float(np.sum(w_[in_set[u_] != in_set[v_]]))
        if cut < best_cut:
            best_cut, best_mask = cut, in_set.copy()
    return best_mask


# --------------------------------------------------- pairwise-swap FM
def _fm_swap_refine(g: CommGraph, side: np.ndarray, passes: int,
                    rng: np.random.Generator) -> np.ndarray:
    """Balance-invariant FM: each move swaps one boundary vertex from each
    side.  Per pass, do greedy best-swap with (vertex) locking and keep the
    best prefix.  O(passes * boundary * deg)."""
    n = g.n
    side = side.copy()

    def move_gains(s):
        # gain of moving u to the other side = ext(u) - int(u)
        gains = np.zeros(n)
        for u in range(n):
            nb, wt = g.neighbors(u), g.weights(u)
            ext = wt[s[nb] != s[u]].sum()
            ing = wt[s[nb] == s[u]].sum()
            gains[u] = ext - ing
        return gains

    for _ in range(max(0, passes)):
        s = side.copy()
        gains = move_gains(s)
        locked = np.zeros(n, dtype=bool)
        seq: list[tuple[int, int]] = []
        cum, best_cum, best_len = 0.0, 0.0, 0
        max_swaps = max(1, n // 2)
        for _step in range(max_swaps):
            g0 = np.where(~locked & ~s, gains, -np.inf)   # side 0 candidates
            g1 = np.where(~locked & s, gains, -np.inf)    # side 1 candidates
            u = int(np.argmax(g0))
            v = int(np.argmax(g1))
            if g0[u] == -np.inf or g1[v] == -np.inf:
                break
            # swap gain = gain(u) + gain(v) - 2*w(u,v) if adjacent
            nb_u, wt_u = g.neighbors(u), g.weights(u)
            wuv = float(wt_u[nb_u == v].sum())
            sg = gains[u] + gains[v] - 2.0 * wuv
            # apply
            s[u], s[v] = ~s[u], ~s[v]
            locked[u] = locked[v] = True
            seq.append((u, v))
            cum += sg
            if cum > best_cum + 1e-12:
                best_cum, best_len = cum, len(seq)
            # update neighbor gains
            for x in (u, v):
                nb, wt = g.neighbors(x), g.weights(x)
                for yy, ww in zip(nb, wt):
                    if locked[yy]:
                        continue
                    # recompute y's gain locally
                    nb2, wt2 = g.neighbors(yy), g.weights(yy)
                    ext = wt2[s[nb2] != s[yy]].sum()
                    ing = wt2[s[nb2] == s[yy]].sum()
                    gains[yy] = ext - ing
            gains[u] = -gains[u] - 0  # locked anyway
            gains[v] = -gains[v]
            if len(seq) - best_len > 16:   # early stop: no improvement window
                break
        # rollback to best prefix
        s2 = side.copy()
        for (u, v) in seq[:best_len]:
            s2[u], s2[v] = ~s2[u], ~s2[v]
        if best_cum <= 1e-12:
            break
        side = s2
    return side


# ------------------------------------------------------------- multilevel
def _bisect_multilevel(g: CommGraph, w_target0: float, cfg: PartitionConfig,
                       rng: np.random.Generator) -> np.ndarray:
    """Multilevel bisection into (side0 ~ w_target0, side1 = rest).
    Returns a bool array (True = side 1)."""
    graphs: list[CommGraph] = [g]
    maps: list[np.ndarray] = []
    cur = g
    for _ in range(cfg.max_levels):
        if cur.n <= cfg.coarsen_min:
            break
        match = _heavy_edge_matching(cur, rng)
        if np.all(match == np.arange(cur.n)):
            break
        coarse, cmap = _contract(cur, match)
        if coarse.n >= cur.n * 0.95:        # matching stalled
            break
        graphs.append(coarse)
        maps.append(cmap)
        cur = coarse

    # initial bisection on the coarsest level (vertex-weighted target)
    mask0 = _grow_region(cur, w_target0, rng, cfg.seed_trials)
    side = ~mask0  # True = side 1

    # uncoarsen + refine.  Swap-FM preserves per-level cardinality; coarse
    # vertices aggregate different numbers of finest vertices, so finest-
    # level balance can drift by a few — the exact rebalance below repairs
    # it before the final refinement pass.
    for lvl in range(len(maps) - 1, -1, -1):
        side = side[maps[lvl]]
        if graphs[lvl].n <= 4 * cfg.coarsen_min:   # refine cheap levels only
            side = _fm_swap_refine(graphs[lvl], side, cfg.fm_passes, rng)

    side = _exact_rebalance(g, side, w_target0)
    side = _fm_swap_refine(g, side, cfg.fm_passes, rng)
    side = _exact_rebalance(g, side, w_target0)   # FM swaps keep balance; belt+braces
    return side


def _exact_rebalance(g: CommGraph, side: np.ndarray,
                     n_target0: float) -> np.ndarray:
    """Move cheapest boundary-ish vertices until |side 0| == target count.
    Each move changes the count by exactly 1, so this terminates in
    |count - target| steps; a hard bound guards regardless."""
    side = side.copy()
    target0 = int(round(n_target0))
    for _ in range(g.n + 1):
        n0 = int(np.sum(~side))
        if n0 == target0:
            break
        move_from0 = n0 > target0
        cand = np.nonzero(~side if move_from0 else side)[0]
        if len(cand) == 0:
            break
        # pick candidate with max (external - internal) wrt its side
        best_u, best_g = -1, -np.inf
        for u in cand:
            nb, wt = g.neighbors(u), g.weights(u)
            ext = wt[side[nb] != side[u]].sum()
            ing = wt[side[nb] == side[u]].sum()
            gn = ext - ing
            if gn > best_g:
                best_g, best_u = gn, int(u)
        side[best_u] = ~side[best_u]
    return side


def partition(g: CommGraph, k: int, cfg: PartitionConfig | None = None,
              seed: int = 0) -> np.ndarray:
    """Perfectly balanced k-way partition by recursive bisection.

    Requires unit vertex weights at the top level (the mapping use case:
    one process per vertex).  When k | n every block has exactly n/k
    vertices; general k splits proportionally (±1).
    """
    cfg = cfg or PartitionConfig()
    rng = np.random.default_rng(seed)
    labels = np.zeros(g.n, dtype=np.int64)

    def rec(nodes: np.ndarray, kk: int, label_base: int):
        if kk == 1:
            labels[nodes] = label_base
            return
        sub, back = g.subgraph(nodes)
        k0 = kk // 2
        n0 = int(round(len(nodes) * k0 / kk))
        side = _bisect_multilevel(sub, float(n0), cfg, rng)
        part0 = back[~side]
        part1 = back[side]
        rec(part0, k0, label_base)
        rec(part1, kk - k0, label_base + k0)

    rec(np.arange(g.n, dtype=np.int64), k, 0)
    return labels
