"""Top-level mapping API — `Mapper` sessions driven by `MappingSpec`,
staged through `MappingPlan` artifacts.

    spec = MappingSpec(neighborhood="communication", neighborhood_dist=10)
    mapper = Mapper(machine, spec)        # machine: Hierarchy or Topology
    plan = mapper.lower(ShapeBucket.of(g))   # stage 1: AOT lower
    result = plan.execute(g)                 # stage 2: zero-recompile run
    result = mapper.map(g)                # thin wrapper: lower-or-fetch
    results = mapper.map_many(gs)         # one plan, one vmapped batch
    service = mapper.serve()              # request-queue serving hook

A `Mapper` owns one machine model — a legacy :class:`Hierarchy` (wrapped
into the ``tree`` topology, bit-for-bit identical) or any registered
:class:`~repro.topology.Topology` — plus ONE LRU cache of lowered
:class:`~repro.core.plan.MappingPlan` artifacts keyed by (seed-free
spec, :class:`ShapeBucket`).  Everything a plan amortizes (distance
oracle, jitted engine executables per level, Pallas kernels, coarse
machines, candidate-pair sets) lives inside the plan; ``map`` and
``map_many`` just fetch-or-lower the right plan and call ``execute``.
``cache_info()`` exposes the plan cache (hits/builds/evictions, plus a
per-bucket breakdown) and aggregated per-plan counters so callers can
assert the amortization actually happened.

Algorithms are resolved through the registries in
:mod:`repro.core.construction`, :mod:`repro.core.local_search`, and
:mod:`repro.topology`; defaults mirror the guide (hierarchytopdown
construction, communication neighborhood with distance 10, eco
preconfiguration, online distances).  ``Mapper.from_spec(spec)`` builds
the machine from the spec's serialized :class:`TopologySpec`.

The high-throughput, shape-bucketed serving front end
(:class:`~repro.launch.serve.MappingService`) batches same-bucket
requests through ``plan.execute_batch``; the in-core
:class:`MapperService` below is the simple one-at-a-time queue hook.
"""

from __future__ import annotations

import itertools
import json
import queue
import threading
from collections import Counter

import numpy as np

from .graph import CommGraph
from .plan import MappingPlan, MappingResult, _LRU
from .spec import MappingSpec, ShapeBucket

__all__ = ["Mapper", "MapperService", "MappingResult", "MappingPlan",
           "ShapeBucket"]

# default caps for the session caches (override via Mapper(cache_caps=...)):
# "plans" bounds the Mapper's one plan LRU; "engines" bounds the shared
# engine pool plans draw from; "pairs"/"pyramids" bound each plan's
# per-request graph-content caches; "engine_graphs"/"engine_pairs" bound
# each pooled engine's device-upload LRUs (see RefinementEngine)
_DEFAULT_CACHE_CAPS = {"plans": 8, "engines": 8, "pairs": 16,
                       "pyramids": 8, "engine_graphs": 16,
                       "engine_pairs": 16}


# ------------------------------------------------------------------ session
class Mapper:
    """A mapping session over one machine model.

    ``machine`` is a legacy :class:`Hierarchy` (wrapped into the ``tree``
    topology — results bit-for-bit identical) or any
    :class:`~repro.topology.Topology`.  The session stages every request
    through the ``lower → MappingPlan → execute`` pipeline: ``lower``
    pays all graph-independent cost once per (spec, bucket) and the plan
    cache hands the compiled artifact back to every subsequent request —
    the point of a session object.
    """

    def __init__(self, machine, spec: MappingSpec | None = None,
                 cache_caps: dict | None = None):
        from ..topology.base import as_topology
        self.topology = as_topology(machine)
        # `h` is the machine handle threaded through constructions, search
        # drivers, and the objective — kept under the legacy name so the
        # duck-typed tree path runs the exact pre-topology code.
        self.h = self.topology
        self.spec = (spec or MappingSpec()).validate()
        self.oracle, self._oracle_builds = self._claim_oracle()
        caps = dict(_DEFAULT_CACHE_CAPS)
        if cache_caps:
            unknown = sorted(set(cache_caps) - set(caps))
            if unknown:
                raise ValueError(f"unknown cache_caps keys {unknown}; "
                                 f"known: {sorted(caps)}")
            caps.update(cache_caps)
        self._plan_caps = {"pairs": caps["pairs"],
                          "pyramids": caps["pyramids"]}
        self._engine_caps = {"graphs": caps["engine_graphs"],
                             "pairs": caps["engine_pairs"]}
        # THE session cache: lowered plans keyed by (seed-free spec,
        # bucket).  Evicted plans retire their counters into _retired so
        # cache_info() stays monotone.
        self._retired: Counter = Counter()
        self._plans = _LRU(caps["plans"], on_evict=self._retire_plan)
        # engines are bucket-agnostic compiled resources (the bucket is
        # a per-call argument), so plans over the same (machine kernel
        # form, sweep budget) share one instance — without this, mixed
        # tight-bucket traffic rotating past the plan cap would rebuild
        # jit wrappers (and re-trace) on every lower.  LRU-bounded like
        # every session cache (live plans keep their engine references
        # even past a pool eviction; the pool only controls sharing).
        self._engine_pool = _LRU(caps["engines"])
        # machine-side coarse pyramid (graph-independent, fixed by the
        # topology): grown lazily, shared by every multilevel plan
        self._ml_machines: list = [self.topology]
        self._requests = 0

    def _shared_engine(self, machine, max_sweeps: int, kernel_config=None):
        """Plan engine factory: one RefinementEngine per (machine kernel
        form — content-fingerprinted for matrices, sweep budget, kernel
        config), shared by every plan this session lowers.  The kernel
        config is part of the pool key because it is baked into the
        compiled sweep (tile geometry, quantized table) — two plans with
        different configs must not alias one engine.  Returns
        (engine, built)."""
        from ..engine import RefinementEngine
        before = self._engine_pool.builds
        cfg_key = None if kernel_config is None else kernel_config.key()
        eng = self._engine_pool.get_or_build(
            (machine.kernel_params(), int(max_sweeps), cfg_key),
            lambda: RefinementEngine(machine, max_sweeps=max_sweeps,
                                     cache_caps=self._engine_caps,
                                     kernel_config=kernel_config))
        return eng, self._engine_pool.builds > before

    def _coarse_machines(self, depth: int) -> list:
        """The machine-side pyramid up to ``depth`` levels — level l
        pairs the PEs (2b, 2b+1) of level l-1.  Coarsening materializes
        O(n²) coarse distance matrices, so the chain is built once per
        session and shared by every plan over this machine."""
        from ..multilevel.coarsen import coarsen_machine
        while len(self._ml_machines) < depth:
            self._ml_machines.append(coarsen_machine(self._ml_machines[-1]))
        return self._ml_machines[:depth]

    @classmethod
    def from_spec(cls, spec: MappingSpec) -> "Mapper":
        """Build the machine from the spec's serialized
        :class:`TopologySpec` and open a session over it."""
        spec = spec.validate()
        if spec.topology is None:
            raise ValueError("MappingSpec.topology is not set; pass the "
                             "machine explicitly: Mapper(machine, spec)")
        return cls(spec.topology.build(), spec)

    def _claim_oracle(self):
        """The machine's distance-oracle state, built at most once per
        machine instance and shared across sessions over it.  Returns
        (oracle, builds_counted_against_this_session)."""
        topo = self.topology
        if hasattr(topo, "hierarchy"):            # tree family: legacy oracle
            already = "oracle" in topo.hierarchy.__dict__
            return topo.hierarchy.oracle, 0 if already else 1
        already = getattr(topo, "_oracle_claimed", False)
        topo._oracle_claimed = True
        return topo, 0 if already else 1

    # ------------------------------------------------------------ stage 1
    def bucket_of(self, g: CommGraph,
                  schedule: str = "tight") -> ShapeBucket:
        """The :class:`ShapeBucket` this graph pads into under
        ``schedule`` (``tight`` reproduces the exact per-graph device
        shapes; ``pow2`` is the coarse serving schedule)."""
        return ShapeBucket.of(g, schedule=schedule)

    def lower(self, bucket: ShapeBucket | None,
              spec: MappingSpec | None = None) -> MappingPlan:
        """Stage 1: fetch-or-build the lowered :class:`MappingPlan` for
        (spec, bucket).  The plan cache key drops the spec's seed — the
        seed is a runtime input of ``plan.execute`` and shares the
        compiled artifact across values."""
        spec = self.spec if spec is None else spec.validate()
        return self._plans.get_or_build(
            self._plan_key(spec, bucket),
            lambda: MappingPlan(self.topology, spec, bucket,
                                cache_caps=self._plan_caps,
                                engine_factory=self._shared_engine,
                                machine_factory=self._coarse_machines))

    def lower_for(self, g: CommGraph, spec: MappingSpec | None = None,
                  schedule: str = "tight") -> MappingPlan:
        """``lower`` with the bucket derived from a concrete graph."""
        self._check_size(g)
        return self.lower(self.bucket_of(g, schedule=schedule), spec)

    @staticmethod
    def _plan_key(spec: MappingSpec, bucket: ShapeBucket | None) -> tuple:
        d = spec.to_dict()
        d.pop("seed")
        return (json.dumps(d, sort_keys=True), bucket)

    def _retire_plan(self, plan: MappingPlan) -> None:
        self._retired.update(plan.cache_info())

    # ------------------------------------------------------------- caching
    def cache_info(self) -> dict:
        """Session amortization counters: the plan cache
        (builds = lowers, hits, evictions, per-bucket breakdown) plus the
        per-plan counters aggregated across live and retired plans —
        engines constructed, kernels compiled, candidate-pair and pyramid
        cache traffic — and requests served."""
        agg = Counter(self._retired)
        per_bucket: dict = {}
        # snapshot first: a MappingService worker may lower/evict plans
        # concurrently with a monitoring thread calling cache_info(),
        # and list() of the dict view is atomic under the GIL while the
        # explicit loop below is not
        for (spec_key, bucket), plan in list(self._plans.items()):
            info = plan.cache_info()
            agg.update(info)
            tag = "dynamic" if bucket is None else bucket.tag()
            while tag in per_bucket:
                tag += "'"               # same bucket, different spec
            per_bucket[tag] = info
        return {
            "oracle_builds": self._oracle_builds,
            "plan_builds": self._plans.builds,
            "plan_hits": self._plans.hits,
            "plan_evictions": self._plans.evictions,
            "plans": per_bucket,
            "engine_pool_evictions": self._engine_pool.evictions,
            "engine_graph_evictions": sum(
                e.cache_info()["graph_evictions"]
                for e in list(self._engine_pool.values())),
            "engine_pair_evictions": sum(
                e.cache_info()["pair_evictions"]
                for e in list(self._engine_pool.values())),
            "engine_builds": agg["engine_builds"],
            "kernel_compiles": agg["kernel_compiles"],
            "pair_cache_builds": agg["pair_builds"],
            "pair_cache_hits": agg["pair_hits"],
            "pair_cache_evictions": agg["pair_evictions"],
            "pyramid_builds": agg["pyramid_builds"],
            "pyramid_hits": agg["pyramid_hits"],
            "pyramid_evictions": agg["pyramid_evictions"],
            "requests": self._requests,
        }

    # ----------------------------------------------------------- objective
    def _eval_plan(self, spec: MappingSpec) -> MappingPlan:
        """A lean evaluation-only plan (no engines, no pyramid, dynamic
        bucket — one entry shared across every graph shape): standalone
        objective/gain evaluations only depend on (machine, backend), so
        they must not lower full pipelines that would churn hot serving
        plans out of the cache."""
        spec = spec.replace(neighborhood=None, engine="host",
                            multilevel=None, portfolio=None,
                            parallel_sweeps=False)
        return self.lower(None, spec)

    def objective(self, g: CommGraph, perm: np.ndarray,
                  spec: MappingSpec | None = None) -> float:
        """J(C, D, Π) via the spec's backend: ``numpy`` host evaluation or
        the plan's compiled Pallas edge-list kernel (``pallas``)."""
        spec = self.spec if spec is None else spec.validate()
        return self._eval_plan(spec).objective(g, perm)

    def gain_matrix(self, g: CommGraph, perm: np.ndarray,
                    spec: MappingSpec | None = None) -> np.ndarray:
        """Full pair-exchange gain matrix via the spec's backend (dense —
        small/medium n)."""
        spec = self.spec if spec is None else spec.validate()
        return self._eval_plan(spec).gain_matrix(g, perm)

    # ----------------------------------------------------------------- map
    def map(self, g: CommGraph, spec: MappingSpec | None = None,
            telemetry: bool = False) -> MappingResult:
        """Compute a process→PE mapping for one graph: lower-or-fetch the
        plan for the graph's tight bucket, then ``execute`` — stage 2 is
        the whole per-request cost.  ``telemetry`` collects the device
        engine's per-sweep counters on ``result.search_stats.telemetry``
        (a runtime toggle — never a recompile)."""
        spec = self.spec if spec is None else spec.validate()
        self._check_size(g)
        self._requests += 1
        plan = self.lower(self.bucket_of(g), spec)
        return plan.execute(g, seed=spec.seed, telemetry=telemetry)

    def map_many(self, graphs, spec: MappingSpec | None = None,
                 telemetry: bool = False) -> list[MappingResult]:
        """Map a batch of graphs through one plan.

        Graphs must agree on process count (and therefore PE count); the
        batch is lowered into the union bucket, so device-engine batches
        run as ONE vmapped executable call.  Results are identical to
        per-graph :meth:`map` calls up to the engine's inert-padding
        invariants.
        """
        graphs = list(graphs)
        if not graphs:
            return []
        ns = {g.n for g in graphs}
        if len(ns) != 1:
            raise ValueError(f"map_many requires same-shape graphs; got "
                             f"process counts {sorted(ns)}")
        spec = self.spec if spec is None else spec.validate()
        for g in graphs:
            self._check_size(g)
        self._requests += len(graphs)
        bucket = self.bucket_of(graphs[0])
        for g in graphs[1:]:
            bucket = bucket.union(self.bucket_of(g))
        return self.lower(bucket, spec).execute_batch(
            graphs, seed=spec.seed, telemetry=telemetry)

    def _check_size(self, g: CommGraph) -> None:
        if g.n != self.h.n_pe:
            raise ValueError(f"graph has {g.n} processes but the machine "
                             f"has {self.h.n_pe} PEs — they must match "
                             f"(guide §4.1)")

    # --------------------------------------------------------------- serve
    def serve(self, requests: "queue.Queue | None" = None,
              results: "queue.Queue | None" = None) -> "MapperService":
        """Start a request-queue serving session over this Mapper."""
        return MapperService(self, requests=requests, results=results)


class MapperService:
    """Request-queue serving hook: a daemon thread drains graphs through
    one :class:`Mapper` session, so plan lowering (oracle, kernels,
    engines) is paid once for the whole queue.  For shape-bucketed
    dynamic batching use :class:`repro.launch.serve.MappingService`.

    ``submit(g)`` returns a ticket; ``(ticket, MappingResult)`` tuples (or
    ``(ticket, Exception)`` on per-request failure) arrive on ``results``.
    ``close()`` — or exiting the context manager — stops the thread after
    draining already-queued requests.
    """

    def __init__(self, mapper: Mapper,
                 requests: "queue.Queue | None" = None,
                 results: "queue.Queue | None" = None):
        self.mapper = mapper
        self.requests = requests if requests is not None else queue.Queue()
        self.results = results if results is not None else queue.Queue()
        self._tickets = itertools.count()
        self._closed = False
        self._lock = threading.Lock()   # makes submit vs close atomic
        self._thread = threading.Thread(target=self._drain,
                                        name="viem-mapper", daemon=True)
        self._thread.start()

    def submit(self, g: CommGraph,
               spec: MappingSpec | None = None) -> int:
        with self._lock:
            if self._closed:
                raise RuntimeError("MapperService is closed; requests "
                                   "submitted now would never be served")
            ticket = next(self._tickets)
            self.requests.put((ticket, g, spec))
        return ticket

    def _drain(self):
        while True:
            item = self.requests.get()
            if item is None:
                break
            ticket, g, spec = item
            try:
                out: object = self.mapper.map(g, spec=spec)
            except Exception as exc:   # per-request isolation
                out = exc
            self.results.put((ticket, out))

    def close(self, timeout: float | None = None):
        with self._lock:
            if not self._closed:
                self._closed = True
                self.requests.put(None)
        self._thread.join(timeout)

    def __enter__(self) -> "MapperService":
        return self

    def __exit__(self, *exc):
        self.close()
