"""Top-level mapping API — `Mapper` sessions driven by `MappingSpec`.

    spec = MappingSpec(neighborhood="communication", neighborhood_dist=10)
    mapper = Mapper(machine, spec)    # machine: Hierarchy or any Topology
    result = mapper.map(g)            # one graph
    results = mapper.map_many(gs)     # same-shape batch, shared setup
    service = mapper.serve()          # request-queue serving hook

A `Mapper` owns one machine model — a legacy :class:`Hierarchy` (wrapped
into the ``tree`` topology, bit-for-bit identical) or any registered
:class:`~repro.topology.Topology` (torus, fattree, dragonfly, explicit
matrix, third-party) — and amortizes everything that does not depend on
the individual graph across requests: the machine's distance oracle
(built once per machine instance), compiled Pallas kernels (swap-gain
matrix, edge-list QAP objective — one entry per topology kernel form ×
shape), and candidate-pair neighborhoods (cached per graph structure).
`cache_info()` exposes hit/build counters so callers can assert the
amortization actually happened.

Algorithms are resolved through the registries in
:mod:`repro.core.construction`, :mod:`repro.core.local_search`, and
:mod:`repro.topology`; defaults mirror the guide (hierarchytopdown
construction, communication neighborhood with distance 10, eco
preconfiguration, online distances).  ``Mapper.from_spec(spec)`` builds
the machine from the spec's serialized :class:`TopologySpec`.

:func:`map_processes` survives as a deprecated shim over
``Mapper(h, MappingSpec(...)).map(g)`` — identical results, one-shot setup.
"""

from __future__ import annotations

import functools
import itertools
import queue
import threading
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from .construction import resolve_construction
from .graph import CommGraph
from .hierarchy import Hierarchy
from .local_search import (SearchStats, _cyclic_search,
                           parallel_sweep_search, resolve_neighborhood)
from .objective import dense_gain_matrix, qap_objective
from .partition import PartitionConfig
from .spec import MappingSpec


@dataclass
class MappingResult:
    perm: np.ndarray
    initial_objective: float
    final_objective: float
    construction_seconds: float
    search_seconds: float
    search_stats: SearchStats | None

    @property
    def improvement(self) -> float:
        if self.initial_objective == 0:
            return 0.0
        return 1.0 - self.final_objective / self.initial_objective


# device-engine sweep budget per preconfiguration when the spec leaves
# max_sweeps=None — the same flag that tunes the partitioner and the
# multilevel pyramid (eco keeps the engine's historical default of 64)
_PRECONF_SWEEPS = {"fast": 32, "eco": 64, "strong": 128}

# default caps for the session caches (override via Mapper(cache_caps=...))
_DEFAULT_CACHE_CAPS = {"pairs": 16, "engines": 8, "kernels": 32,
                       "pyramids": 8}


class _LRU:
    """Bounded LRU mapping with visible accounting: ``builds`` counts
    misses, ``hits`` counts reuses, ``evictions`` counts entries dropped
    at the cap — all surfaced through ``Mapper.cache_info()`` so
    long-lived ``serve()`` sessions can assert their memory stays
    bounded as request shapes vary."""

    def __init__(self, cap: int):
        self.cap = int(cap)
        self.builds = 0
        self.hits = 0
        self.evictions = 0
        self._data: OrderedDict = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def get_or_build(self, key, build):
        val = self._data.get(key)
        if val is not None:
            self._data.move_to_end(key)
            self.hits += 1
            return val
        val = build()
        self.builds += 1
        self._data[key] = val
        while len(self._data) > self.cap:
            self._data.popitem(last=False)
            self.evictions += 1
        return val


# ------------------------------------------------------------- kernel cache
class _KernelCache:
    """Session cache of jitted Pallas entry points, keyed by the static
    arguments that force a recompile (the topology's ``kernel_params()``
    + shapes).  ``compiles`` counts cache misses — the number of distinct
    kernel configurations this session prepared.  Each miss corresponds to
    at most one XLA compile on first call (jax's process-global jit cache
    dedups across sessions), so it upper-bounds real compiles.  LRU-
    bounded: ``evictions`` counts entries dropped at the cap."""

    def __init__(self, cap: int = 32):
        self._fns = _LRU(cap)

    @property
    def compiles(self) -> int:
        return self._fns.builds

    @property
    def evictions(self) -> int:
        return self._fns.evictions

    @staticmethod
    def _interpret() -> bool:
        import jax
        return jax.default_backend() != "tpu"

    def objective_edges(self, topology, n_edges: int):
        """Edge-list objective entry for the topology's device-side
        distance form: closed-form tree/torus oracles computed in-register,
        or the gather path against the materialized matrix."""
        kp = topology.kernel_params()
        key = ("qap_edges", kp, int(n_edges))
        return self._fns.get_or_build(
            key, lambda: self._build_objective_edges(topology, kp))

    def _build_objective_edges(self, topology, kp):
        from ..kernels import qap_objective as qk
        kind = kp[0]
        interpret = self._interpret()
        if kind == "tree":
            _, strides, dists = kp
            return functools.partial(qk.qap_objective_edges,
                                     strides=strides, dists=dists,
                                     interpret=interpret)
        if kind == "torus":
            _, dims, weights = kp
            return functools.partial(qk.qap_objective_edges_torus,
                                     dims=dims, weights=weights,
                                     interpret=interpret)
        if kind == "matrix":
            import jax.numpy as jnp
            D = jnp.asarray(topology.matrix(), jnp.float32)
            return functools.partial(qk.qap_objective_edges_matrix, D=D,
                                     interpret=interpret)
        raise ValueError(f"unknown kernel_params kind {kind!r}")

    def swap_gain_matrix(self, n: int):
        from ..kernels.swap_gain import swap_gain_matrix
        return self._fns.get_or_build(
            ("swap_gain", int(n)),
            lambda: functools.partial(swap_gain_matrix,
                                      interpret=self._interpret()))


def _structure_key(g: CommGraph, with_weights: bool = False) -> tuple:
    """Adjacency-structure fingerprint; weights are included only for
    neighborhoods that declare ``weight_dependent`` (none of the built-ins
    read them, so same-structure batches share one candidate set)."""
    key = (g.n, int(g.xadj[-1]), hash(g.xadj.tobytes()),
           hash(g.adjncy.tobytes()))
    if with_weights:
        key += (hash(np.asarray(g.adjwgt).tobytes()),)
    return key


# ------------------------------------------------------------------ session
class Mapper:
    """A mapping session over one machine model.

    ``machine`` is a legacy :class:`Hierarchy` (wrapped into the ``tree``
    topology — results bit-for-bit identical) or any
    :class:`~repro.topology.Topology`.  Construction cost (oracle build,
    kernel compiles, neighborhood pair generation) is paid once and reused
    by every subsequent ``map`` / ``map_many`` / ``serve`` request — the
    point of a session object over the one-shot :func:`map_processes`.
    """

    def __init__(self, machine, spec: MappingSpec | None = None,
                 cache_caps: dict | None = None):
        from ..topology.base import as_topology
        self.topology = as_topology(machine)
        # `h` is the machine handle threaded through constructions, search
        # drivers, and the objective — kept under the legacy name so the
        # duck-typed tree path runs the exact pre-topology code.
        self.h = self.topology
        self.spec = (spec or MappingSpec()).validate()
        self.oracle, self._oracle_builds = self._claim_oracle()
        # every session cache is LRU-bounded (serve() sessions are
        # long-lived and must not grow without limit as shapes vary);
        # caps are per-cache configurable, evictions visible in
        # cache_info()
        caps = dict(_DEFAULT_CACHE_CAPS)
        if cache_caps:
            unknown = sorted(set(cache_caps) - set(caps))
            if unknown:
                raise ValueError(f"unknown cache_caps keys {unknown}; "
                                 f"known: {sorted(caps)}")
            caps.update(cache_caps)
        self._kernels = _KernelCache(cap=caps["kernels"])
        # device refinement engines, one per (kernel_params, max_sweeps) —
        # the multilevel V-cycle adds one per coarse level
        self._engines = _LRU(caps["engines"])
        # candidate-pair arrays can reach max_pairs entries (~32 MB each)
        self._pair_cache = _LRU(caps["pairs"])
        # multilevel level pyramids, one per (graph structure+weights,
        # V-cycle knobs, neighborhood knobs)
        self._pyramids = _LRU(caps["pyramids"])
        # machine-side coarse models (graph-independent): level l pairs
        # the PEs (2b, 2b+1) of level l-1 — grown lazily, shared by every
        # pyramid over this machine
        self._ml_machines: list = [self.topology]
        self._requests = 0

    @classmethod
    def from_spec(cls, spec: MappingSpec) -> "Mapper":
        """Build the machine from the spec's serialized
        :class:`TopologySpec` and open a session over it."""
        spec = spec.validate()
        if spec.topology is None:
            raise ValueError("MappingSpec.topology is not set; pass the "
                             "machine explicitly: Mapper(machine, spec)")
        return cls(spec.topology.build(), spec)

    def _claim_oracle(self):
        """The machine's distance-oracle state, built at most once per
        machine instance and shared across sessions over it.  Returns
        (oracle, builds_counted_against_this_session)."""
        topo = self.topology
        if hasattr(topo, "hierarchy"):            # tree family: legacy oracle
            already = "oracle" in topo.hierarchy.__dict__
            return topo.hierarchy.oracle, 0 if already else 1
        already = getattr(topo, "_oracle_claimed", False)
        topo._oracle_claimed = True
        return topo, 0 if already else 1

    # ------------------------------------------------------------- caching
    def cache_info(self) -> dict:
        """Counters for the session's amortized state: how many distance
        oracles were built, kernels compiled, engines constructed, and
        pyramids coarsened on this session's behalf, plus cache hits,
        LRU evictions, and requests served."""
        return {
            "oracle_builds": self._oracle_builds,
            "kernel_compiles": self._kernels.compiles,
            "kernel_evictions": self._kernels.evictions,
            "engine_builds": self._engines.builds,
            "engine_evictions": self._engines.evictions,
            "pair_cache_hits": self._pair_cache.hits,
            "pair_cache_evictions": self._pair_cache.evictions,
            "pyramid_builds": self._pyramids.builds,
            "pyramid_hits": self._pyramids.hits,
            "pyramid_evictions": self._pyramids.evictions,
            "requests": self._requests,
        }

    def _sweep_budget(self, spec: MappingSpec) -> int:
        """Device-engine sweep budget: the spec's explicit ``max_sweeps``,
        else the preconfiguration's (fast 32, eco 64, strong 128)."""
        if spec.max_sweeps is not None:
            return spec.max_sweeps
        return _PRECONF_SWEEPS.get(spec.preconfiguration, 64)

    def _engine(self, spec: MappingSpec, machine=None):
        """The session's device refinement engine for this spec — built
        once per (machine kernel form, sweep budget) and reused by every
        subsequent device-engine request (jax re-specializes per shape
        under the hood, so same-shape graphs share one executable).
        ``machine`` defaults to the session topology; the multilevel
        V-cycle passes its coarse machines, whose engines land in the
        same LRU cache."""
        machine = self.topology if machine is None else machine
        max_sweeps = self._sweep_budget(spec)
        key = (machine.kernel_params(), max_sweeps)

        def build():
            from ..engine import RefinementEngine
            return RefinementEngine(machine, max_sweeps=max_sweeps)

        return self._engines.get_or_build(key, build)

    def _pairs(self, g: CommGraph, spec: MappingSpec) -> np.ndarray:
        nb = resolve_neighborhood(spec.neighborhood)
        # unseeded (deterministic) generators share one cache entry
        # across seeds — only genuinely randomized ones key on the seed
        key = (spec.neighborhood, spec.neighborhood_dist,
               spec.seed if nb.seeded else None,
               spec.max_pairs) + _structure_key(g, nb.weight_dependent)
        return self._pair_cache.get_or_build(
            key, lambda: nb.generate(g, dist=spec.neighborhood_dist,
                                     seed=spec.seed,
                                     max_pairs=spec.max_pairs))

    # ----------------------------------------------------------- objective
    def objective(self, g: CommGraph, perm: np.ndarray,
                  spec: MappingSpec | None = None) -> float:
        """J(C, D, Π) via the spec's backend: ``numpy`` host evaluation or
        the cached Pallas edge-list kernel (``pallas``)."""
        spec = spec or self.spec
        if spec.backend == "pallas":
            u, v, w = g.edge_list()
            fn = self._kernels.objective_edges(self.topology, len(u))
            perm = np.asarray(perm, dtype=np.int64)
            return float(fn(perm[u].astype(np.int32),
                            perm[v].astype(np.int32),
                            w.astype(np.float32)))
        return qap_objective(g, self.h, perm)

    def gain_matrix(self, g: CommGraph, perm: np.ndarray,
                    spec: MappingSpec | None = None) -> np.ndarray:
        """Full pair-exchange gain matrix via the spec's backend (dense —
        small/medium n).  The pallas path reuses the session's cached
        distance matrix and compiled swap-gain kernel."""
        spec = spec or self.spec
        perm = np.asarray(perm, dtype=np.int64)
        D = self.oracle.matrix()
        if spec.backend == "pallas":
            C = g.to_dense()
            B = D[np.ix_(perm, perm)]
            fn = self._kernels.swap_gain_matrix(g.n)
            return np.asarray(fn(C, B))
        return dense_gain_matrix(g.to_dense(), D, perm)

    # ----------------------------------------------------------------- map
    def map(self, g: CommGraph, spec: MappingSpec | None = None
            ) -> MappingResult:
        """Compute a process→PE mapping for one graph."""
        spec = self.spec if spec is None else spec.validate()
        return self._map_one(g, spec)

    def map_many(self, graphs, spec: MappingSpec | None = None
                 ) -> list[MappingResult]:
        """Map a batch of same-shape graphs through one session.

        Graphs must agree on process count (and therefore PE count); the
        hierarchy oracle, compiled kernels, and — for structurally
        identical graphs — the candidate-pair neighborhoods are computed
        once and shared across the whole batch.  Results are identical to
        per-graph :meth:`map` calls.
        """
        graphs = list(graphs)
        if not graphs:
            return []
        ns = {g.n for g in graphs}
        if len(ns) != 1:
            raise ValueError(f"map_many requires same-shape graphs; got "
                             f"process counts {sorted(ns)}")
        spec = self.spec if spec is None else spec.validate()
        ml = spec.resolved_multilevel()
        if ml is not None:
            return self._map_many_multilevel(graphs, spec, ml)
        if spec.engine == "device" and spec.neighborhood is not None:
            return self._map_many_device(graphs, spec)
        return [self._map_one(g, spec) for g in graphs]

    def _map_many_device(self, graphs, spec: MappingSpec
                         ) -> list[MappingResult]:
        """Batch path for the device engine: constructions and candidate
        pairs per graph on host (cached as usual), then ONE vmapped
        engine call refines the whole batch — no Python loop over sweeps
        or graphs.  Padding to the batch's common shapes is inert, so
        results match per-graph :meth:`map` calls."""
        prepped = [self._construct(g, spec) for g in graphs]
        perms = [perm for perm, _, _ in prepped]
        # timed window matches _map_one's: pair generation + refinement
        t1 = time.perf_counter()
        pairs_list = [self._pairs(g, spec) for g in graphs]
        stats_list = self._engine(spec).refine_batch(
            graphs, perms, pairs_list, j0s=[j0 for _, _, j0 in prepped])
        t_search = (time.perf_counter() - t1) / len(graphs)
        return [self._finish(g, perm, j0, t_cons, t_search, stats, spec)
                for g, (perm, t_cons, j0), stats
                in zip(graphs, prepped, stats_list)]

    def _construct(self, g: CommGraph, spec: MappingSpec
                   ) -> tuple[np.ndarray, float, float]:
        """Shared per-graph prep for the single and batch paths: size
        check, request accounting, timed construction, and the initial
        objective through the spec's backend."""
        self._check_size(g)
        self._requests += 1
        construct_fn = resolve_construction(spec.construction)
        cfg = PartitionConfig.preconfiguration(spec.preconfiguration)
        t0 = time.perf_counter()
        perm = construct_fn(g, self.h, seed=spec.seed, cfg=cfg)
        return perm, time.perf_counter() - t0, self.objective(g, perm, spec)

    def _finish(self, g: CommGraph, perm: np.ndarray, j0: float,
                t_cons: float, t_search: float, stats: SearchStats | None,
                spec: MappingSpec) -> MappingResult:
        """Shared result assembly: the final objective is the search's
        incremental host float64 value on the ``numpy`` backend
        (legacy-identical) and recomputed through the session backend
        otherwise, so j0 and jf stay comparable."""
        if stats is None:
            jf = j0
        elif spec.backend == "numpy":
            jf = stats.final_objective
        else:
            jf = self.objective(g, perm, spec)
        return MappingResult(perm=perm, initial_objective=j0,
                             final_objective=jf,
                             construction_seconds=t_cons,
                             search_seconds=t_search, search_stats=stats)

    # ------------------------------------------------------------ multilevel
    def _check_size(self, g: CommGraph) -> None:
        if g.n != self.h.n_pe:
            raise ValueError(f"graph has {g.n} processes but the machine "
                             f"has {self.h.n_pe} PEs — they must match "
                             f"(guide §4.1)")

    def _coarse_machines(self, depth: int) -> list:
        """The machine-side pyramid up to ``depth`` levels, grown lazily
        and shared by every graph pyramid over this machine."""
        from ..multilevel.coarsen import coarsen_machine
        while len(self._ml_machines) < depth:
            self._ml_machines.append(coarsen_machine(self._ml_machines[-1]))
        return self._ml_machines[:depth]

    def _pyramid(self, g: CommGraph, spec: MappingSpec,
                 ml: tuple[int, int]) -> list:
        """The graph-side level pyramid, LRU-cached per (graph structure
        *and weights* — the heavy-edge matching reads them, V-cycle
        knobs, neighborhood knobs)."""
        from ..multilevel.coarsen import build_pyramid, pyramid_depth
        levels, cmin = ml
        machines = self._coarse_machines(pyramid_depth(g.n, levels, cmin))
        if spec.neighborhood is None:
            nb = None
            pair_fn = lambda gg: np.zeros((0, 2), np.int64)  # noqa: E731
        else:
            nb = resolve_neighborhood(spec.neighborhood)
            pair_fn = lambda gg: nb.generate(       # noqa: E731
                gg, dist=spec.neighborhood_dist, seed=spec.seed,
                max_pairs=spec.max_pairs)
        key = (("pyramid", levels, cmin, spec.neighborhood,
                spec.neighborhood_dist, spec.max_pairs,
                spec.seed if (nb is not None and nb.seeded) else None)
               + _structure_key(g, with_weights=True))
        return self._pyramids.get_or_build(
            key, lambda: build_pyramid(g, machines, levels, cmin, pair_fn))

    def _map_one_multilevel(self, g: CommGraph, spec: MappingSpec,
                            ml: tuple[int, int]) -> MappingResult:
        """The coarsen → map → uncoarsen V-cycle (:mod:`repro.multilevel`):
        construction runs on the coarsest level, the device engine
        refines every level on the way down.  The reported initial
        objective is the projected (pre-refinement) finest-level
        objective — the multilevel construction's value."""
        from ..multilevel import vcycle_map
        self._check_size(g)
        self._requests += 1
        pyramid = self._pyramid(g, spec, ml)
        cfg = PartitionConfig.preconfiguration(spec.preconfiguration)
        construct_fn = resolve_construction(spec.construction)
        t0 = time.perf_counter()
        res = vcycle_map(
            pyramid, lambda m: self._engine(spec, m), construct_fn, cfg,
            seed=spec.seed,
            objective0=lambda gg, pp: self.objective(gg, pp, spec))
        t_search = time.perf_counter() - t0 - res.construction_seconds
        return self._finish(g, res.perm, res.initial_objective,
                            res.construction_seconds, t_search, res.stats,
                            spec)

    def _map_many_multilevel(self, graphs, spec: MappingSpec,
                             ml: tuple[int, int]) -> list[MappingResult]:
        """Batched V-cycles: the forced perfect pairing gives every
        same-n graph the same level geometry, so each level's refinement
        runs as ONE vmapped engine call across the whole batch."""
        from ..multilevel import vcycle_map_batch
        for g in graphs:
            self._check_size(g)
        self._requests += len(graphs)
        pyramids = [self._pyramid(g, spec, ml) for g in graphs]
        cfg = PartitionConfig.preconfiguration(spec.preconfiguration)
        construct_fn = resolve_construction(spec.construction)
        t0 = time.perf_counter()
        results = vcycle_map_batch(
            pyramids, lambda m: self._engine(spec, m), construct_fn, cfg,
            seed=spec.seed,
            objective0=lambda gg, pp: self.objective(gg, pp, spec))
        elapsed = (time.perf_counter() - t0) / len(graphs)
        return [self._finish(g, r.perm, r.initial_objective,
                             r.construction_seconds,
                             elapsed - r.construction_seconds, r.stats,
                             spec)
                for g, r in zip(graphs, results)]

    # ------------------------------------------------------------- flat map
    def _map_one(self, g: CommGraph, spec: MappingSpec) -> MappingResult:
        ml = spec.resolved_multilevel()
        if ml is not None:
            return self._map_one_multilevel(g, spec, ml)
        perm, t_cons, j0 = self._construct(g, spec)
        stats = None
        t1 = time.perf_counter()
        if spec.neighborhood is not None:
            nb = resolve_neighborhood(spec.neighborhood)
            pairs = self._pairs(g, spec)
            kw = {} if spec.max_sweeps is None else \
                {"max_sweeps": spec.max_sweeps}
            if spec.engine == "device":
                stats = self._engine(spec).refine(g, perm, pairs, j0=j0)
            elif spec.parallel_sweeps:
                stats = parallel_sweep_search(g, self.h, perm, pairs,
                                              seed=spec.seed, **kw)
            else:
                stats = _cyclic_search(g, self.h, perm, pairs,
                                       shuffle=nb.shuffle, seed=spec.seed,
                                       **kw)
        t_search = time.perf_counter() - t1
        return self._finish(g, perm, j0, t_cons, t_search, stats, spec)

    # --------------------------------------------------------------- serve
    def serve(self, requests: "queue.Queue | None" = None,
              results: "queue.Queue | None" = None) -> "MapperService":
        """Start a request-queue serving session over this Mapper."""
        return MapperService(self, requests=requests, results=results)


class MapperService:
    """Request-queue serving hook: a daemon thread drains graphs through
    one :class:`Mapper` session, so hierarchy-oracle and kernel setup are
    paid once for the whole queue (wired into ``repro.launch.serve``).

    ``submit(g)`` returns a ticket; ``(ticket, MappingResult)`` tuples (or
    ``(ticket, Exception)`` on per-request failure) arrive on ``results``.
    ``close()`` — or exiting the context manager — stops the thread after
    draining already-queued requests.
    """

    def __init__(self, mapper: Mapper,
                 requests: "queue.Queue | None" = None,
                 results: "queue.Queue | None" = None):
        self.mapper = mapper
        self.requests = requests if requests is not None else queue.Queue()
        self.results = results if results is not None else queue.Queue()
        self._tickets = itertools.count()
        self._closed = False
        self._lock = threading.Lock()   # makes submit vs close atomic
        self._thread = threading.Thread(target=self._drain,
                                        name="viem-mapper", daemon=True)
        self._thread.start()

    def submit(self, g: CommGraph,
               spec: MappingSpec | None = None) -> int:
        with self._lock:
            if self._closed:
                raise RuntimeError("MapperService is closed; requests "
                                   "submitted now would never be served")
            ticket = next(self._tickets)
            self.requests.put((ticket, g, spec))
        return ticket

    def _drain(self):
        while True:
            item = self.requests.get()
            if item is None:
                break
            ticket, g, spec = item
            try:
                out: object = self.mapper.map(g, spec=spec)
            except Exception as exc:   # per-request isolation
                out = exc
            self.results.put((ticket, out))

    def close(self, timeout: float | None = None):
        with self._lock:
            if not self._closed:
                self._closed = True
                self.requests.put(None)
        self._thread.join(timeout)

    def __enter__(self) -> "MapperService":
        return self

    def __exit__(self, *exc):
        self.close()


# ------------------------------------------------------------- legacy shim
def map_processes(g: CommGraph, h: Hierarchy,
                  construction_algorithm: str = "hierarchytopdown",
                  local_search_neighborhood: str | None = "communication",
                  communication_neighborhood_dist: int = 10,
                  preconfiguration_mapping: str = "eco",
                  parallel_sweeps: bool = False,
                  seed: int = 0) -> MappingResult:
    """Deprecated one-shot API — use ``Mapper(h, MappingSpec(...)).map(g)``.

    Results are identical; the session API additionally amortizes oracle,
    kernel, and neighborhood setup across calls."""
    warnings.warn(
        "map_processes() is deprecated; build a MappingSpec and use "
        "Mapper(h, spec).map(g) — identical results, reusable session "
        "state. map_processes() will be removed in a future release.",
        DeprecationWarning, stacklevel=2)
    spec = MappingSpec(
        construction=construction_algorithm,
        neighborhood=local_search_neighborhood,
        neighborhood_dist=communication_neighborhood_dist,
        preconfiguration=preconfiguration_mapping,
        parallel_sweeps=parallel_sweeps,
        seed=seed)
    return Mapper(h, spec).map(g)
