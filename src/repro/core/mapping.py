"""Top-level mapping API — the `viem` program as a library (guide §4.1).

    result = map_processes(g, hierarchy=..., distance=...)
    result.perm        # process -> PE
    result.stats       # construction + search statistics

Defaults mirror the guide: hierarchytopdown construction, communication
neighborhood with distance 10, eco preconfiguration, hierarchyonline
distances (we never materialize D unless explicitly requested).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from .construction import construct
from .graph import CommGraph
from .hierarchy import Hierarchy
from .local_search import SearchStats, communication_pairs, local_search, \
    parallel_sweep_search
from .objective import qap_objective


@dataclass
class MappingResult:
    perm: np.ndarray
    initial_objective: float
    final_objective: float
    construction_seconds: float
    search_seconds: float
    search_stats: SearchStats | None

    @property
    def improvement(self) -> float:
        if self.initial_objective == 0:
            return 0.0
        return 1.0 - self.final_objective / self.initial_objective


def map_processes(g: CommGraph, h: Hierarchy,
                  construction_algorithm: str = "hierarchytopdown",
                  local_search_neighborhood: str | None = "communication",
                  communication_neighborhood_dist: int = 10,
                  preconfiguration_mapping: str = "eco",
                  parallel_sweeps: bool = False,
                  seed: int = 0) -> MappingResult:
    """Compute a process→PE mapping.  ``local_search_neighborhood=None``
    skips local search (construction only).  ``parallel_sweeps=True`` uses
    the TPU-adapted batched sweep instead of the paper's sequential search
    (same candidate neighborhood)."""
    if g.n != h.n_pe:
        raise ValueError(f"graph has {g.n} processes but hierarchy has "
                         f"{h.n_pe} PEs — they must match (guide §4.1)")
    t0 = time.perf_counter()
    perm = construct(construction_algorithm, g, h, seed=seed,
                     preconfiguration=preconfiguration_mapping)
    t_cons = time.perf_counter() - t0
    j0 = qap_objective(g, h, perm)

    stats = None
    t1 = time.perf_counter()
    if local_search_neighborhood is not None:
        if parallel_sweeps:
            if local_search_neighborhood == "communication":
                pairs = communication_pairs(
                    g, communication_neighborhood_dist, seed=seed)
            elif local_search_neighborhood == "nsquare":
                from .local_search import nsquare_pairs
                pairs = nsquare_pairs(g.n)
            else:
                from .local_search import pruned_pairs
                pairs = pruned_pairs(g)
            stats = parallel_sweep_search(g, h, perm, pairs, seed=seed)
        else:
            stats = local_search(
                g, h, perm,
                neighborhood=local_search_neighborhood,
                communication_neighborhood_dist=communication_neighborhood_dist,
                seed=seed)
    t_search = time.perf_counter() - t1
    jf = stats.final_objective if stats is not None else j0
    return MappingResult(perm=perm, initial_objective=j0, final_objective=jf,
                         construction_seconds=t_cons,
                         search_seconds=t_search, search_stats=stats)
