"""Hierarchical machine topology and the online distance oracle.

Guide §2.2 and §4.1: the machine is described by
  hierarchy string  S = a_1:a_2:...:a_k   (a_1 cores/processor, a_2
                                           processors/node, a_3 nodes/rack, ...)
  distance string   D = d_1:d_2:...:d_k   (distance between PEs sharing a
                                           processor, a node, a rack, ...)

`--distance_construction_algorithm=hierarchy` materializes the full n×n
matrix; `hierarchyonline` computes distances on the fly — mandatory for the
n where a dense matrix would not fit.  Both are implemented; they agree
bit-for-bit (tested).

TPU fleet presets map the paper's supercomputer levels onto a v5e fleet:
chip → tray (ICI hop) → superblock (several ICI hops) → pod (DCN).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Hierarchy:
    """Homogeneous machine hierarchy with per-level distances."""

    factors: tuple[int, ...]     # a_1 .. a_k  (innermost first)
    distances: tuple[float, ...]  # d_1 .. d_k

    def __post_init__(self):
        if len(self.factors) != len(self.distances):
            raise ValueError("hierarchy and distance strings differ in length")
        if any(f <= 0 for f in self.factors):
            raise ValueError("hierarchy factors must be positive")
        if any(self.distances[i] > self.distances[i + 1]
               for i in range(len(self.distances) - 1)):
            raise ValueError("distances must be non-decreasing up the tree")

    # ------------------------------------------------------------ properties
    @property
    def n_pe(self) -> int:
        return int(np.prod(self.factors))

    @property
    def k(self) -> int:
        return len(self.factors)

    # strides[l] = number of PEs in a level-l subtree (strides[0]=1 core)
    # cached: the distance oracle reads strides on every call and this sits
    # in the innermost loop of every search driver.  Do not mutate.
    @functools.cached_property
    def strides(self) -> np.ndarray:
        s = np.concatenate([[1], np.cumprod(self.factors)]).astype(np.int64)
        s.setflags(write=False)
        return s

    # --------------------------------------------------------------- oracle
    def distance(self, p, q):
        """Online distance oracle D(p, q): vectorized, O(k), no n×n matrix.

        The distance is d_l where l is the *lowest* level at which p and q
        fall into the same subtree (i.e. the LCA level).  D(p, p) = 0.
        """
        p = np.asarray(p, dtype=np.int64)
        q = np.asarray(q, dtype=np.int64)
        out = np.zeros(np.broadcast(p, q).shape, dtype=np.float64)
        strides = self.strides
        # level l (1-based): same subtree iff p // strides[l] == q // strides[l]
        for lvl in range(self.k, 0, -1):
            same = (p // strides[lvl]) == (q // strides[lvl])
            out = np.where(same & (p != q), self.distances[lvl - 1], out)
        return out if out.ndim else float(out)

    def distance_matrix(self) -> np.ndarray:
        """Materialized D (the guide's `hierarchy` construction) — small n only."""
        idx = np.arange(self.n_pe)
        return self.distance(idx[:, None], idx[None, :])

    def lca_level(self, p, q):
        """Level (1-based) of the lowest common subtree; 0 for p == q."""
        p = np.asarray(p, dtype=np.int64)
        q = np.asarray(q, dtype=np.int64)
        out = np.full(np.broadcast(p, q).shape, self.k, dtype=np.int64)
        strides = self.strides
        for lvl in range(self.k - 1, 0, -1):
            same = (p // strides[lvl]) == (q // strides[lvl])
            out = np.where(same, lvl, out)
        return np.where(p == q, 0, out)

    # ---------------------------------------------------------------- parse
    @staticmethod
    def from_strings(hierarchy_parameter_string: str,
                     distance_parameter_string: str) -> "Hierarchy":
        """Parse the guide's ``2:2:...`` / ``1:10:...`` flag syntax."""
        f = tuple(int(x) for x in hierarchy_parameter_string.split(":") if x)
        d = tuple(float(x) for x in distance_parameter_string.split(":") if x)
        return Hierarchy(f, d)

    # --------------------------------------------------------- cached oracle
    @functools.cached_property
    def oracle(self) -> "DistanceOracle":
        """The precomputed distance oracle, built once per Hierarchy
        instance and shared by every Mapper session over it."""
        return DistanceOracle(self)


class DistanceOracle:
    """Precomputed distance-oracle state for one :class:`Hierarchy`.

    Holds the stride/distance arrays the online oracle needs (so hot loops
    never rebuild them) and memoizes the materialized n×n matrix (the
    guide's ``hierarchy`` distance construction) on first request.  Built
    at most once per ``Hierarchy`` via the cached ``Hierarchy.oracle``
    property; ``Mapper.cache_info()`` reports whether a session triggered
    that build.
    """

    def __init__(self, h: Hierarchy):
        self.hierarchy = h
        self.n_pe = h.n_pe
        self.strides = h.strides
        self.distances = np.asarray(h.distances, dtype=np.float64)
        self._matrix: np.ndarray | None = None

    def distance(self, p, q):
        """Same semantics as :meth:`Hierarchy.distance` (tested equal)."""
        return self.hierarchy.distance(p, q)

    def matrix(self) -> np.ndarray:
        """Materialized D, computed once and cached — small n only."""
        if self._matrix is None:
            self._matrix = self.hierarchy.distance_matrix()
            self._matrix.setflags(write=False)
        return self._matrix

    # static kernel parameters (hashable) for the Pallas objective kernel
    def kernel_params(self) -> tuple[tuple, tuple]:
        return tuple(int(s) for s in self.strides), tuple(self.hierarchy.distances)


# ----------------------------------------------------------------- presets
def tpu_v5e_fleet(pods: int = 2) -> Hierarchy:
    """A v5e fleet: 16 chips/tray-group, 4 groups/superblock, 4 superblocks/pod.

    Distances calibrated to relative link quality: 1 within a tray group
    (1 ICI hop), 2 within a superblock, 6 across superblocks (multi-hop ICI),
    60 across pods (DCN vs ICI is ~1-2 orders of magnitude).
    """
    if pods == 1:
        return Hierarchy((16, 4, 4), (1.0, 2.0, 6.0))
    return Hierarchy((16, 4, 4, pods), (1.0, 2.0, 6.0, 60.0))


def supermuc_like() -> Hierarchy:
    """The guide's motivating SuperMUC-style hierarchy (island/node/core)."""
    return Hierarchy((16, 32, 18), (1.0, 10.0, 100.0))
