"""`MappingPlan` — the frozen AOT artifact between ``Mapper.lower()`` and
the zero-recompile ``execute()`` hot path.

The guide's workflow is "build the machine model once, map many
communication graphs against it"; the staged API makes that split
explicit:

    plan = mapper.lower(ShapeBucket.of(g))      # AOT: resolve + compile
    result = plan.execute(g)                    # hot path: pad + run
    results = plan.execute_batch(graphs)        # one vmapped device call

``lower`` resolves *everything* that does not depend on the individual
graph — the construction/neighborhood registry handles, the partition
config, the multilevel machine pyramid and its coarse machines, one
:class:`~repro.engine.RefinementEngine` per level (jitted executables),
and the Pallas objective kernel for the ``pallas`` backend — so
``execute`` does no registry resolution, no cache lookups, and no
host-side reconstruction: it pads the graph into the plan's
:class:`~repro.core.spec.ShapeBucket` (inert by the DeviceGraph padding
invariants, so results are bit-identical to exact shapes) and runs the
compiled pipeline.  The seed is a *runtime* input (``execute(g, seed=)``)
— nothing compiled depends on it — which is why a Mapper session keys
its plan cache on the seed-free spec.

A plan is portable: ``to_json()``/``save()`` serialize its
:class:`~repro.core.spec.PlanSpec` (spec + machine model + bucket), and
``from_json()``/``load()``/pickle rebuild the live plan — same machine,
same level geometry, same kernel forms — in a fresh process, reproducing
the original mappings bit-for-bit.  ``describe()`` reports what was
compiled without executing anything (the ``viem --explain`` surface).
"""

from __future__ import annotations

import json
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..obs import EngineTelemetry, get_tracer
from .construction import resolve_construction
from .graph import CommGraph
from .local_search import (SearchStats, _cyclic_search,
                           parallel_sweep_search, resolve_neighborhood)
from .objective import dense_gain_matrix, qap_objective
from .partition import PartitionConfig
from .spec import MappingSpec, PlanSpec, ShapeBucket, TopologySpec

_TR = get_tracer()


@dataclass
class MappingResult:
    perm: np.ndarray
    initial_objective: float
    final_objective: float
    construction_seconds: float
    search_seconds: float
    search_stats: SearchStats | None

    @property
    def improvement(self) -> float:
        if self.initial_objective == 0:
            return 0.0
        return 1.0 - self.final_objective / self.initial_objective


# device-engine sweep budget per preconfiguration when the spec leaves
# max_sweeps=None — the same flag that tunes the partitioner and the
# multilevel pyramid (eco keeps the engine's historical default of 64)
_PRECONF_SWEEPS = {"fast": 32, "eco": 64, "strong": 128}


def sweep_budget(spec: MappingSpec) -> int:
    """Device-engine sweep budget: the spec's explicit ``max_sweeps``,
    else the preconfiguration's (fast 32, eco 64, strong 128)."""
    if spec.max_sweeps is not None:
        return spec.max_sweeps
    return _PRECONF_SWEEPS.get(spec.preconfiguration, 64)


class _LRU:
    """Bounded LRU mapping with visible accounting: ``builds`` counts
    misses, ``hits`` counts reuses, ``evictions`` counts entries dropped
    at the cap — surfaced through ``cache_info()`` so long-lived serving
    sessions can assert their memory stays bounded as requests vary."""

    def __init__(self, cap: int, on_evict=None):
        self.cap = int(cap)
        self.builds = 0
        self.hits = 0
        self.evictions = 0
        self._on_evict = on_evict
        self._data: OrderedDict = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def values(self):
        return self._data.values()

    def items(self):
        return self._data.items()

    def clear(self):
        self._data.clear()

    def get_or_build(self, key, build):
        val = self._data.get(key)
        if val is not None:
            self._data.move_to_end(key)
            self.hits += 1
            return val
        val = build()
        self.builds += 1
        self._data[key] = val
        while len(self._data) > self.cap:
            _, dropped = self._data.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(dropped)
        return val


def _structure_key(g: CommGraph, with_weights: bool = False) -> tuple:
    """Adjacency-structure fingerprint; weights are included only for
    neighborhoods that declare ``weight_dependent`` (none of the built-ins
    read them, so same-structure requests share one candidate set)."""
    key = (g.n, int(g.xadj[-1]), hash(g.xadj.tobytes()),
           hash(g.adjncy.tobytes()))
    if with_weights:
        key += (hash(np.asarray(g.adjwgt).tobytes()),)
    return key


def build_objective_kernel(topology, interpret: bool | None = None,
                           config=None):
    """The edge-list QAP objective entry for the topology's device-side
    distance form: closed-form tree/torus oracles computed in-register,
    or the gather path against the materialized matrix.  ``config`` (a
    :class:`~repro.kernels.config.KernelConfig`) fixes the reduction-tile
    geometry and, for the matrix form, stores the table in its lossless
    int8/int16 packing — bit-identical objectives, narrower gathers."""
    import functools

    from ..kernels import qap_objective as qk
    if interpret is None:
        import jax
        interpret = jax.default_backend() != "tpu"
    geom = {} if config is None else {"lanes": config.lanes,
                                      "block_rows": config.block_rows}
    kp = topology.kernel_params()
    kind = kp[0]
    if kind == "tree":
        _, strides, dists = kp
        return functools.partial(qk.qap_objective_edges, strides=strides,
                                 dists=dists, interpret=interpret, **geom)
    if kind == "torus":
        _, dims, weights = kp
        return functools.partial(qk.qap_objective_edges_torus, dims=dims,
                                 weights=weights, interpret=interpret,
                                 **geom)
    if kind == "matrix":
        import jax.numpy as jnp
        dist_dtype = getattr(config, "dist_dtype", None)
        if dist_dtype is not None:
            from ..kernels.config import quantize_table
            D = jnp.asarray(quantize_table(topology.matrix(),
                                           dist_dtype)[0])
        else:
            D = jnp.asarray(topology.matrix(), jnp.float32)
        return functools.partial(qk.qap_objective_edges_matrix, D=D,
                                 interpret=interpret, **geom)
    raise ValueError(f"unknown kernel_params kind {kind!r}")


_PLAN_CACHE_CAPS = {"pairs": 16, "pyramids": 8}


class MappingPlan:
    """One lowered (machine × spec × bucket) pipeline — see module
    docstring.  Build via ``Mapper.lower(...)`` (session-cached) or
    directly; rebuild a serialized plan with ``from_dict``/``load``."""

    def __init__(self, machine, spec: MappingSpec | None = None,
                 bucket: ShapeBucket | None = None,
                 cache_caps: dict | None = None, engine_factory=None,
                 machine_factory=None):
        with _TR.span("plan.lower") as sp:
            self._lower(machine, spec, bucket, cache_caps,
                        engine_factory, machine_factory)
            sp.attrs["machine"] = self.topology.kind
            sp.attrs["engine"] = self.spec.engine
            sp.attrs["bucket"] = (None if self.bucket is None
                                  else self.bucket.tag())
        # the lower wall-time, kept on the plan so describe() can report
        # the AOT cost even when the tracer is disabled
        self.lower_seconds = sp.dur

    def _lower(self, machine, spec, bucket, cache_caps, engine_factory,
               machine_factory):
        from ..topology.base import as_topology
        self.topology = as_topology(machine)
        self.spec = (spec or MappingSpec()).validate()
        self.bucket = None if bucket is None else bucket.validate()
        caps = dict(_PLAN_CACHE_CAPS)
        caps.update(cache_caps or {})
        # --- stage 1 (lower): resolve every handle the hot path needs
        self._construct = resolve_construction(self.spec.construction)
        self._cfg = PartitionConfig.preconfiguration(
            self.spec.preconfiguration)
        self._nb = (None if self.spec.neighborhood is None else
                    resolve_neighborhood(self.spec.neighborhood))
        self.max_sweeps = sweep_budget(self.spec)
        self._ml = self.spec.resolved_multilevel()
        # machine-side level pyramid: level l pairs the PEs (2b, 2b+1)
        # of level l-1 (graph-independent, fixed by n and the V-cycle
        # knobs — what makes the level geometry part of the AOT
        # artifact).  ``machine_factory(depth)`` lets a Mapper session
        # share the chain across plans (coarsening materializes O(n²)
        # coarse distance matrices); a standalone plan builds its own.
        machines = [self.topology]
        if self._ml is not None:
            from ..multilevel.coarsen import coarsen_machine, pyramid_depth
            depth = pyramid_depth(self.topology.n_pe, *self._ml)
            if machine_factory is not None:
                machines = list(machine_factory(depth))
            else:
                for _ in range(depth - 1):
                    machines.append(coarsen_machine(machines[-1]))
        self.machines = machines
        # kernel geometry: ONE KernelConfig per pyramid level, derived
        # from the plan bucket + backend (overridable via spec.kernel) at
        # lower time — part of the AOT artifact, reported by describe()
        # under "kernels".  Coarse matrix machines whose averaged
        # distances are no longer exact integers simply derive
        # dist_dtype=None (float tables) — quantization is per level.
        import jax

        from ..kernels.config import derive_kernel_config
        self.kernel_backend = jax.default_backend()
        kspec = self.spec.kernel
        kover = {} if kspec is None else {
            "block_rows": kspec.block_rows, "lanes": kspec.lanes,
            "acc_dtype": kspec.acc_dtype, "quantize": kspec.quantize}
        self.kernel_configs = []
        for m in machines:
            kind = m.kernel_params()[0]
            self.kernel_configs.append(derive_kernel_config(
                kind, bucket=self.bucket, backend=self.kernel_backend,
                table=m.matrix() if kind == "matrix" else None, **kover))
        # one jitted engine per level (device engine only); jax compiles
        # lazily on the first execute, then every same-bucket request
        # reuses the executable.  ``engine_factory(machine, max_sweeps,
        # kernel_config) -> (engine, built)`` lets a Mapper session pool
        # engines across plans (they are bucket-agnostic — the bucket is
        # a per-call argument), with ``built`` telling this plan whether
        # to count the construction; a standalone plan builds its own.
        self.engine_builds = 0
        self.engines = None
        if self.spec.engine == "device":
            if engine_factory is None:
                from ..engine import RefinementEngine

                def engine_factory(m, sweeps, config=None):
                    return RefinementEngine(m, max_sweeps=sweeps,
                                            kernel_config=config), True
            self.engines = []
            for m, cfg in zip(machines, self.kernel_configs):
                eng, built = engine_factory(m, self.max_sweeps, cfg)
                self.engine_builds += bool(built)
                self.engines.append(eng)
        # portfolio runner: the vmapped multistart/tabu search layer over
        # the finest-level engine (repro.portfolio) — per-lane
        # constructions resolved here, at lower time, like everything else
        self.portfolio = None
        if self.spec.portfolio is not None:
            from ..portfolio import PortfolioRunner
            names = dict.fromkeys(
                [self.spec.construction]
                + list(self.spec.portfolio.constructions or ()))
            self.portfolio = PortfolioRunner(
                self.engines[0], self.spec.portfolio,
                [(nm, resolve_construction(nm)) for nm in names])
        self.kernel_compiles = 0
        self._objective_fn = None
        if self.spec.backend == "pallas":
            self._objective_fn = build_objective_kernel(
                self.topology, config=self.kernel_configs[0])
            self.kernel_compiles += 1
        self._swap_gain_fn = None
        # --- per-request state (graph-content keyed, LRU-bounded)
        self._pairs_lru = _LRU(caps["pairs"])
        self._pyramids = _LRU(caps["pyramids"])
        self.executes = 0
        self.execute_seconds_total = 0.0

    # -------------------------------------------------------------- describe
    def describe(self) -> dict:
        """Structured report of what was lowered/compiled — per level:
        size, machine kind, device kernel form, sweep budget."""
        n = self.topology.n_pe
        levels = []
        for i, m in enumerate(self.machines):
            levels.append({
                "level": i,
                "n": n >> i,
                "machine_kind": m.kind,
                "kernel_form": m.kernel_params()[0],
                "kernel_config": self.kernel_configs[i].tag(),
                "engine_compiled": self.engines is not None,
                "max_sweeps": (self.max_sweeps if self.engines is not None
                               else self.spec.max_sweeps),
            })
        return {
            "machine": {"kind": self.topology.kind, "n_pe": n},
            "bucket": None if self.bucket is None else self.bucket.to_dict(),
            "construction": self.spec.construction,
            "neighborhood": self.spec.neighborhood,
            "neighborhood_dist": self.spec.neighborhood_dist,
            "preconfiguration": self.spec.preconfiguration,
            "engine": self.spec.engine,
            "backend": self.spec.backend,
            "multilevel": (None if self._ml is None else
                           {"levels": self._ml[0],
                            "coarsen_min": self._ml[1]}),
            "portfolio": (None if self.portfolio is None else
                          self.portfolio.describe()),
            "kernels": {
                "backend": self.kernel_backend,
                "configs": [cfg.to_dict() for cfg in self.kernel_configs],
                "quantized": any(cfg.dist_dtype is not None
                                 for cfg in self.kernel_configs),
            },
            "levels": levels,
            "compiled": {"engines": self.engine_builds,
                         "kernels": self.kernel_compiles},
            "timings": {
                "lower_seconds": self.lower_seconds,
                "executes": self.executes,
                "execute_seconds_total": self.execute_seconds_total,
                # per-level device trace counts: compiles paid so far —
                # growth across same-bucket executes means a retrace
                "engine_traces": [eng.trace_count()
                                  for eng in (self.engines or [])],
            },
        }

    def cache_info(self) -> dict:
        return {
            "engine_builds": self.engine_builds,
            "kernel_compiles": self.kernel_compiles,
            "pair_builds": self._pairs_lru.builds,
            "pair_hits": self._pairs_lru.hits,
            "pair_evictions": self._pairs_lru.evictions,
            "pyramid_builds": self._pyramids.builds,
            "pyramid_hits": self._pyramids.hits,
            "pyramid_evictions": self._pyramids.evictions,
            "executes": self.executes,
        }

    def clear_request_caches(self) -> None:
        """Drop all per-request state (candidate pairs, pyramids, device
        uploads) while keeping the compiled artifacts — benchmarks use
        this to time the full per-graph cost honestly."""
        self._pairs_lru.clear()
        self._pyramids.clear()
        for eng in (self.engines or []):
            eng._dg_cache.clear()
            eng._pair_cache.clear()

    # --------------------------------------------------------- serialization
    def plan_spec(self) -> PlanSpec:
        """The serializable identity (spec + machine + bucket)."""
        mspec = self.spec
        if mspec.topology is None:
            mspec = mspec.replace(topology=TopologySpec.of(self.topology))
        return PlanSpec(mapping=mspec, bucket=self.bucket).validate()

    def to_dict(self) -> dict:
        return self.plan_spec().to_dict()

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_dict(cls, d: dict) -> "MappingPlan":
        ps = PlanSpec.from_dict(d).validate()
        return cls(ps.mapping.topology.build(), ps.mapping, ps.bucket)

    @classmethod
    def from_json(cls, text: str) -> "MappingPlan":
        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())

    @classmethod
    def load(cls, path) -> "MappingPlan":
        with open(path) as fh:
            return cls.from_json(fh.read())

    def __reduce__(self):
        return (_plan_from_dict, (self.to_dict(),))

    # ------------------------------------------------------------- hot path
    def _check(self, g: CommGraph) -> None:
        if g.n != self.topology.n_pe:
            raise ValueError(f"graph has {g.n} processes but the machine "
                             f"has {self.topology.n_pe} PEs — they must "
                             f"match (guide §4.1)")
        if self.bucket is not None and not self.bucket.admits(g):
            raise ValueError(
                f"graph (max_deg="
                f"{int(np.diff(g.xadj).max(initial=0))}, "
                f"E={g.num_edges}) exceeds the plan bucket "
                f"{self.bucket.tag()} — lower a larger plan")

    def _pairs(self, g: CommGraph, seed: int) -> np.ndarray:
        nb = self._nb
        # unseeded (deterministic) generators share one cache entry
        # across seeds — only genuinely randomized ones key on the seed
        key = ((seed if nb.seeded else None,)
               + _structure_key(g, nb.weight_dependent))
        return self._pairs_lru.get_or_build(
            key, lambda: nb.generate(g, dist=self.spec.neighborhood_dist,
                                     seed=seed,
                                     max_pairs=self.spec.max_pairs))

    def objective(self, g: CommGraph, perm: np.ndarray) -> float:
        """J(C, D, Π) via the plan's backend: host numpy float64, or the
        Pallas edge-list kernel compiled at lower time."""
        if self._objective_fn is not None:
            u, v, w = g.edge_list()
            perm = np.asarray(perm, dtype=np.int64)
            return float(self._objective_fn(perm[u].astype(np.int32),
                                            perm[v].astype(np.int32),
                                            w.astype(np.float32)))
        return qap_objective(g, self.topology, perm)

    def gain_matrix(self, g: CommGraph, perm: np.ndarray) -> np.ndarray:
        """Full pair-exchange gain matrix via the plan's backend (dense —
        small/medium n)."""
        perm = np.asarray(perm, dtype=np.int64)
        D = self.topology.matrix()
        if self.spec.backend == "pallas":
            if self._swap_gain_fn is None:
                import functools

                import jax

                from ..kernels.swap_gain import swap_gain_matrix
                self._swap_gain_fn = functools.partial(
                    swap_gain_matrix,
                    interpret=jax.default_backend() != "tpu")
                self.kernel_compiles += 1
            C = g.to_dense()
            B = D[np.ix_(perm, perm)]
            return np.asarray(self._swap_gain_fn(C, B))
        return dense_gain_matrix(g.to_dense(), D, perm)

    def _construct_one(self, g: CommGraph, seed: int
                       ) -> tuple[np.ndarray, float, float]:
        with _TR.span("plan.construct", n=g.n,
                      construction=self.spec.construction) as sp:
            perm = self._construct(g, self.topology, seed=seed,
                                   cfg=self._cfg)
        return perm, sp.dur, self.objective(g, perm)

    def _finish(self, g: CommGraph, perm: np.ndarray, j0: float,
                t_cons: float, t_search: float,
                stats: SearchStats | None) -> MappingResult:
        """Result assembly: the final objective is the search's
        incremental host float64 value on the ``numpy`` backend
        (legacy-identical) and recomputed through the plan backend
        otherwise, so j0 and jf stay comparable."""
        if stats is None:
            jf = j0
        elif self.spec.backend == "numpy":
            jf = stats.final_objective
        else:
            jf = self.objective(g, perm)
        return MappingResult(perm=perm, initial_objective=j0,
                             final_objective=jf,
                             construction_seconds=t_cons,
                             search_seconds=t_search, search_stats=stats)

    def execute(self, g: CommGraph, seed: int | None = None,
                telemetry: bool = False) -> MappingResult:
        """Map one graph through the lowered pipeline.  ``seed`` is the
        runtime seed (defaults to the plan spec's) — it steers the
        construction and any seeded neighborhood, never the compiled
        artifacts.  ``telemetry`` asks the device engine to collect its
        per-sweep counters (``result.search_stats.telemetry``) — a
        runtime toggle, masked on-device, never a retrace."""
        seed = self.spec.seed if seed is None else int(seed)
        self._check(g)
        self.executes += 1
        with _TR.span("plan.execute", n=g.n, engine=self.spec.engine,
                      seed=seed) as sp:
            if self.portfolio is not None:
                res = self._execute_portfolio(g, seed, telemetry)
            elif self._ml is not None:
                res = self._execute_multilevel(g, seed, telemetry)
            else:
                res = self._execute_flat(g, seed, telemetry)
            sp.attrs["final_objective"] = res.final_objective
        self.execute_seconds_total += sp.dur
        return res

    def _execute_flat(self, g: CommGraph, seed: int,
                      telemetry: bool) -> MappingResult:
        perm, t_cons, j0 = self._construct_one(g, seed)
        stats = None
        with _TR.span("plan.refine", n=g.n,
                      engine=self.spec.engine) as rsp:
            if self._nb is not None:
                pairs = self._pairs(g, seed)
                rsp.attrs["pairs"] = len(pairs)
                kw = {} if self.spec.max_sweeps is None else \
                    {"max_sweeps": self.spec.max_sweeps}
                if self.spec.engine == "device":
                    eng = self.engines[0]
                    before = eng.trace_count()
                    stats = eng.refine(g, perm, pairs, j0=j0,
                                       bucket=self.bucket,
                                       telemetry=telemetry)
                    rsp.attrs["retraces"] = eng.trace_count() - before
                    if stats.telemetry is not None:
                        rsp.attrs["telemetry"] = stats.telemetry
                elif self.spec.parallel_sweeps:
                    stats = parallel_sweep_search(g, self.topology, perm,
                                                  pairs, seed=seed, **kw)
                else:
                    stats = _cyclic_search(g, self.topology, perm, pairs,
                                           shuffle=self._nb.shuffle,
                                           seed=seed, **kw)
        return self._finish(g, perm, j0, t_cons, rsp.dur, stats)

    def candidate_pairs(self, g: CommGraph,
                        seed: int | None = None) -> np.ndarray:
        """The plan's candidate exchange pairs for this graph — the same
        (p, 2) array ``execute`` refines over, LRU-cached per structure.
        Exposed so incremental callers (:mod:`repro.monitor`) can build a
        runtime activity mask over a *fixed* pair set and keep the padded
        pair shape — and therefore the compiled executable — unchanged
        across warm re-executions."""
        if self._nb is None:
            return np.zeros((0, 2), np.int64)
        seed = self.spec.seed if seed is None else int(seed)
        return self._pairs(g, seed)

    def execute_warm(self, g: CommGraph, perm: np.ndarray,
                     pairs: np.ndarray | None = None,
                     active: np.ndarray | None = None,
                     seed: int | None = None,
                     telemetry: bool = False) -> MappingResult:
        """Warm-start: refine an incumbent ``perm`` on ``g`` with NO
        construction phase — the incremental-remap hot path.

        ``pairs`` fixes the candidate set (default: the plan's own
        ``candidate_pairs(g)``); ``active`` is an optional boolean mask
        over it.  Inactive pairs are replaced by inert ``(u, u)``
        self-pairs — exactly the engine's padding convention, zero gain
        and never selected — so the array length, the padded pair shape
        P, and the compiled executable are all unchanged: masking, never
        retracing (trace-count tested).  Dirty-region remaps pass the
        mask of pairs touching drifted vertices and leave the rest of
        the mapping frozen in place by construction of the sweep.

        The incumbent is *not* mutated; the result carries the refined
        copy.  ``initial_objective`` is the incumbent's objective on
        ``g``, so ``result.improvement`` reads as recovered drift."""
        seed = self.spec.seed if seed is None else int(seed)
        self._check(g)
        self.executes += 1
        perm = np.array(perm, dtype=np.int64, copy=True)
        with _TR.span("plan.execute_warm", n=g.n, engine=self.spec.engine,
                      seed=seed) as sp:
            j0 = self.objective(g, perm)
            stats = None
            with _TR.span("plan.refine", n=g.n, engine=self.spec.engine,
                          warm=True) as rsp:
                if pairs is None:
                    pairs = self.candidate_pairs(g, seed)
                pairs = np.asarray(pairs, dtype=np.int64)
                if active is not None:
                    active = np.asarray(active, dtype=bool)
                    if active.shape != (len(pairs),):
                        raise ValueError(
                            f"active mask shape {active.shape} does not "
                            f"match {len(pairs)} candidate pairs")
                    masked = np.where(active[:, None], pairs,
                                      pairs[:, [0, 0]])
                else:
                    masked = pairs
                rsp.attrs["pairs"] = len(pairs)
                rsp.attrs["active"] = (len(pairs) if active is None
                                       else int(active.sum()))
                if len(pairs) and self.spec.engine == "device":
                    eng = self.engines[0]
                    before = eng.trace_count()
                    stats = eng.refine(g, perm, masked, j0=j0,
                                       bucket=self.bucket,
                                       telemetry=telemetry)
                    rsp.attrs["retraces"] = eng.trace_count() - before
                    if stats.telemetry is not None:
                        rsp.attrs["telemetry"] = stats.telemetry
                elif len(pairs):
                    live = masked if active is None else pairs[active]
                    kw = {} if self.spec.max_sweeps is None else \
                        {"max_sweeps": self.spec.max_sweeps}
                    stats = parallel_sweep_search(g, self.topology, perm,
                                                  live, seed=seed, **kw)
            res = self._finish(g, perm, j0, 0.0, rsp.dur, stats)
            sp.attrs["final_objective"] = res.final_objective
        self.execute_seconds_total += sp.dur
        return res

    def execute_batch(self, graphs, seed: int | None = None,
                      telemetry: bool = False) -> list[MappingResult]:
        """Map a batch through one vmapped device dispatch per level.

        Every graph must fit the plan bucket (they need not be
        structurally identical — padding into the common bucket is
        inert), so the whole batch shares the compiled executables."""
        graphs = list(graphs)
        if not graphs:
            return []
        seed = self.spec.seed if seed is None else int(seed)
        if self.portfolio is not None:
            # the lane axis already fills the vmap batch dimension — each
            # graph runs its own portfolio (lanes × graphs would multiply
            # the device footprint, not amortize it)
            return [self.execute(g, seed=seed, telemetry=telemetry)
                    for g in graphs]
        if self._ml is not None:
            for g in graphs:
                self._check(g)
            self.executes += len(graphs)
            return self._execute_batch_multilevel(graphs, seed, telemetry)
        if self.spec.engine != "device" or self._nb is None:
            return [self.execute(g, seed=seed, telemetry=telemetry)
                    for g in graphs]
        for g in graphs:
            self._check(g)
        self.executes += len(graphs)
        with _TR.span("plan.execute_batch", batch=len(graphs),
                      n=graphs[0].n) as bsp:
            # duplicate lanes (the service pads batches by cycling its
            # tick's graphs) share one construction; every lane still
            # gets its own perm array because the engine refines in place
            memo: dict = {}
            prepped = []
            for g in graphs:
                hit = memo.get(id(g))
                if hit is None:
                    hit = memo[id(g)] = self._construct_one(g, seed)
                else:
                    hit = (hit[0].copy(), hit[1], hit[2])
                prepped.append(hit)
            perms = [perm for perm, _, _ in prepped]
            # timed window matches execute()'s: pair gen + refinement
            eng = self.engines[0]
            before = eng.trace_count()
            with _TR.span("plan.refine", batch=len(graphs)) as rsp:
                pairs_list = [self._pairs(g, seed) for g in graphs]
                stats_list = eng.refine_batch(
                    graphs, perms, pairs_list,
                    j0s=[j0 for _, _, j0 in prepped],
                    bucket=self.bucket, telemetry=telemetry)
            rsp.attrs["retraces"] = eng.trace_count() - before
            t_search = rsp.dur / len(graphs)
        self.execute_seconds_total += bsp.dur
        return [self._finish(g, perm, j0, t_cons, t_search, stats)
                for g, (perm, t_cons, j0), stats
                in zip(graphs, prepped, stats_list)]

    # ------------------------------------------------------------ multilevel
    def _pyramid(self, g: CommGraph, seed: int) -> list:
        """The graph-side level pyramid, LRU-cached per (graph structure
        *and weights* — the heavy-edge matching reads them, seed for
        seeded neighborhoods)."""
        from ..multilevel.coarsen import build_pyramid
        levels, cmin = self._ml
        if self._nb is None:
            pair_fn = lambda gg: np.zeros((0, 2), np.int64)  # noqa: E731
            skey = None
        else:
            nb = self._nb
            pair_fn = lambda gg: nb.generate(        # noqa: E731
                gg, dist=self.spec.neighborhood_dist, seed=seed,
                max_pairs=self.spec.max_pairs)
            skey = seed if nb.seeded else None
        key = (("pyramid", skey)
               + _structure_key(g, with_weights=True))
        return self._pyramids.get_or_build(
            key, lambda: build_pyramid(g, self.machines, levels, cmin,
                                       pair_fn))

    def _execute_multilevel(self, g: CommGraph, seed: int,
                            telemetry: bool = False) -> MappingResult:
        """The coarsen → map → uncoarsen V-cycle (:mod:`repro.multilevel`)
        over the plan's per-level engines; the reported initial objective
        is the projected (pre-refinement) finest-level objective."""
        from ..multilevel import vcycle_map
        pyramid = self._pyramid(g, seed)
        with _TR.span("plan.vcycle", n=g.n, levels=len(pyramid)) as sp:
            res = vcycle_map(pyramid, self.engines, self._construct,
                             self._cfg, seed=seed,
                             objective0=self.objective,
                             bucket=self.bucket, telemetry=telemetry)
        t_search = sp.dur - res.construction_seconds
        return self._finish(g, res.perm, res.initial_objective,
                            res.construction_seconds, t_search, res.stats)

    def _execute_batch_multilevel(self, graphs, seed: int,
                                  telemetry: bool = False
                                  ) -> list[MappingResult]:
        """Batched V-cycles: the forced perfect pairing gives every
        same-n graph the same level geometry, so each level's refinement
        runs as ONE vmapped engine call across the whole batch."""
        from ..multilevel import vcycle_map_batch
        pyramids = [self._pyramid(g, seed) for g in graphs]
        with _TR.span("plan.vcycle", batch=len(graphs),
                      levels=len(pyramids[0])) as sp:
            results = vcycle_map_batch(
                pyramids, self.engines, self._construct, self._cfg,
                seed=seed, objective0=self.objective, bucket=self.bucket,
                telemetry=telemetry)
        self.execute_seconds_total += sp.dur
        elapsed = sp.dur / len(graphs)
        return [self._finish(g, r.perm, r.initial_objective,
                             r.construction_seconds,
                             elapsed - r.construction_seconds, r.stats)
                for g, r in zip(graphs, results)]

    # ------------------------------------------------------------- portfolio
    def _execute_portfolio(self, g: CommGraph, seed: int,
                           telemetry: bool = False) -> MappingResult:
        """The portfolio pipeline (:mod:`repro.portfolio`): L lanes
        constructed with per-lane seeds, refined per level as ONE vmapped
        lane call (descending the V-cycle when the spec is multilevel),
        then the device round loop — kick → refine → tournament — at the
        finest level.  ``PortfolioSpec(lanes=1, rounds=1, tabu_tenure=0)``
        degenerates to the non-portfolio pipeline bit-for-bit (tested).

        With ``telemetry``, the finest-level lane refinement collects
        per-lane engine counters and the merged
        :class:`~repro.obs.EngineTelemetry` rides the result's stats
        (the round loop itself stays counter-free — one device dispatch,
        sweep/swap totals only)."""
        runner = self.portfolio
        empty = np.zeros((0, 2), np.int64)
        lane_stats = None
        pyramid = self._pyramid(g, seed) if self._ml is not None else None
        with _TR.span("plan.construct", lanes=runner.pspec.lanes) as csp:
            if pyramid is not None:
                coarsest = pyramid[-1]
                perms = runner.construct_lanes(
                    coarsest.graph, coarsest.machine, self._cfg, seed)
            else:
                perms = runner.construct_lanes(g, self.topology,
                                               self._cfg, seed)
        t_cons = csp.dur
        with _TR.span("plan.refine", n=g.n,
                      lanes=runner.pspec.lanes) as rsp:
            if pyramid is not None:
                from ..multilevel.coarsen import project_perm
                j0s = []
                pairs0 = pyramid[0].pairs
                for lvl in range(len(pyramid) - 1, -1, -1):
                    level = pyramid[lvl]
                    if lvl == 0:
                        j0s = [self.objective(level.graph, p)
                               for p in perms]
                    else:
                        j0s = [qap_objective(level.graph, level.machine,
                                             p) for p in perms]
                    lane_stats = runner.refine_lanes(
                        level.graph, perms, level.pairs, j0s=j0s,
                        bucket=self.bucket if lvl == 0 else None,
                        engine=self.engines[lvl],
                        telemetry=telemetry and lvl == 0)
                    if lvl > 0:
                        perms = [project_perm(p, level.fine_u,
                                              level.fine_v)
                                 for p in perms]
            else:
                j0s = [self.objective(g, p) for p in perms]
                pairs0 = self._pairs(g, seed) if self._nb is not None \
                    else empty
                lane_stats = runner.refine_lanes(g, perms, pairs0,
                                                 j0s=j0s,
                                                 bucket=self.bucket,
                                                 telemetry=telemetry)
            res = runner.run_rounds(g, perms, pairs0, j0s,
                                    bucket=self.bucket, seed=seed)
            rsp.attrs["rounds"] = res.rounds
        t_search = rsp.dur
        j0 = min(j0s) if j0s else self.objective(g, res.perm)
        stats = SearchStats()
        stats.initial_objective = j0
        stats.final_objective = qap_objective(g, self.topology, res.perm)
        stats.swaps = res.swaps
        stats.evaluated = res.sweeps * len(pairs0)
        if self._ml is None:
            stats.swaps += sum(s.swaps for s in lane_stats)
            stats.evaluated += sum(s.evaluated for s in lane_stats)
        stats.objective_trace = [j0] + res.round_objectives
        if telemetry and lane_stats:
            tels = [s.telemetry for s in lane_stats
                    if s.telemetry is not None]
            if tels:
                stats.telemetry = EngineTelemetry.merge(tels)
                rsp.attrs["telemetry"] = stats.telemetry
        return self._finish(g, res.perm, j0, t_cons, t_search, stats)


def _plan_from_dict(d: dict) -> MappingPlan:
    """Module-level pickle entry (``MappingPlan.__reduce__``)."""
    return MappingPlan.from_dict(d)
