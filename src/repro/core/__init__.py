"""VieM core: sparse quadratic assignment process mapping (the paper's
contribution), reimplemented as a composable, registry-driven library.

The public API is declarative: describe *what* mapping you want in a
frozen, serializable :class:`MappingSpec`, then run it through a
:class:`Mapper` session that owns the machine :class:`Hierarchy` and
amortizes its distance oracle, compiled Pallas kernels, and candidate
neighborhoods across requests::

    from repro.core import Hierarchy, Mapper, MappingSpec, grid3d

    h = Hierarchy.from_strings("16:8:4", "1:10:100")
    spec = MappingSpec(neighborhood="communication", neighborhood_dist=10)
    mapper = Mapper(h, spec)
    result = mapper.map(grid3d(8, 8, 8))     # one request
    results = mapper.map_many(graphs)        # same-shape batch, shared setup
    service = mapper.serve()                 # request-queue serving hook

Algorithms are pluggable through registries — ``@register_construction``
and ``@register_neighborhood`` make third-party strategies addressable
from specs and the CLI without touching core dispatch.

Modules:
  spec         — MappingSpec: one config language for CLI/launch/benchmarks
  mapping      — Mapper sessions, MapperService queue serving,
                 map_processes() (deprecated one-shot shim)
  graph        — CSR communication graphs, Metis IO, generators
  hierarchy    — hierarchical topologies + cached online distance oracle
  objective    — sparse QAP objective, O(deg) swap gains, dense gain matrix
  partition    — multilevel perfectly-balanced partitioner (KaHIP stand-in)
  construction — registered constructions (identity/random/growing/
                 hierarchybottomup/hierarchytopdown)
  local_search — registered neighborhoods (N², N² pruned, N_C^d)
  comm_model   — communication-graph extraction from compiled XLA programs
"""

from .construction import list_constructions, register_construction
from .graph import CommGraph, DeviceGraph, GraphFormatError, device_pairs, \
    from_dense, from_edges, grid3d, random_geometric, read_metis, validate, \
    write_metis
from .hierarchy import DistanceOracle, Hierarchy, supermuc_like, \
    tpu_v5e_fleet
from .local_search import list_neighborhoods, register_neighborhood
from .mapping import Mapper, MapperService, MappingResult, map_processes
from .objective import dense_gain_matrix, qap_objective, \
    qap_objective_dense, swap_gain
from .spec import MappingSpec, MultilevelSpec, TopologySpec

__all__ = [
    "CommGraph", "DeviceGraph", "GraphFormatError", "device_pairs",
    "from_dense", "from_edges", "grid3d",
    "random_geometric", "read_metis", "validate", "write_metis",
    "DistanceOracle", "Hierarchy", "supermuc_like", "tpu_v5e_fleet",
    "Mapper", "MapperService", "MappingResult", "MappingSpec",
    "MultilevelSpec", "TopologySpec", "map_processes",
    "list_constructions", "register_construction",
    "list_neighborhoods", "register_neighborhood",
    "dense_gain_matrix", "qap_objective", "qap_objective_dense", "swap_gain",
]
