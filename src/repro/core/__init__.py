"""VieM core: sparse quadratic assignment process mapping (the paper's
contribution), reimplemented as a composable, registry-driven library.

The public API is declarative and staged: describe *what* mapping you
want in a frozen, serializable :class:`MappingSpec`, lower it into a
:class:`MappingPlan` (the AOT artifact: machine oracle, level geometry,
compiled kernels and jitted engine executables), then execute graphs
through the plan — or let a :class:`Mapper` session fetch-or-lower plans
for you::

    from repro.core import Hierarchy, Mapper, MappingSpec, ShapeBucket, grid3d

    h = Hierarchy.from_strings("16:8:4", "1:10:100")
    spec = MappingSpec(neighborhood="communication", neighborhood_dist=10)
    mapper = Mapper(h, spec)
    plan = mapper.lower(ShapeBucket.of(g))   # stage 1: AOT lower
    result = plan.execute(g)                 # stage 2: zero-recompile run
    result = mapper.map(grid3d(8, 8, 8))     # thin wrapper over both
    results = mapper.map_many(graphs)        # one plan, one vmapped batch
    service = mapper.serve()                 # request-queue serving hook

Plans serialize (``plan.to_json()`` / ``MappingPlan.load``) and rebuild
in a fresh process, reproducing mappings bit-for-bit.  Algorithms are
pluggable through registries — ``@register_construction`` and
``@register_neighborhood`` make third-party strategies addressable from
specs and the CLI without touching core dispatch.

Modules:
  spec         — MappingSpec/PlanSpec/ShapeBucket: one config language
                 for CLI/launch/benchmarks
  plan         — MappingPlan: the lowered AOT artifact + execute hot path
  mapping      — Mapper sessions (one LRU plan cache), MapperService queue
  graph        — CSR communication graphs, Metis IO, generators
  hierarchy    — hierarchical topologies + cached online distance oracle
  objective    — sparse QAP objective, O(deg) swap gains, dense gain matrix
  partition    — multilevel perfectly-balanced partitioner (KaHIP stand-in)
  construction — registered constructions (identity/random/growing/
                 hierarchybottomup/hierarchytopdown)
  local_search — registered neighborhoods (N², N² pruned, N_C^d)
  comm_model   — communication-graph extraction from compiled XLA programs
"""

from .construction import list_constructions, register_construction
from .graph import CommGraph, DeviceGraph, GraphFormatError, device_pairs, \
    from_dense, from_edges, grid3d, random_geometric, read_metis, validate, \
    write_metis
from .hierarchy import DistanceOracle, Hierarchy, supermuc_like, \
    tpu_v5e_fleet
from .local_search import list_neighborhoods, register_neighborhood
from .mapping import Mapper, MapperService
from .objective import dense_gain_matrix, qap_objective, \
    qap_objective_dense, swap_gain
from .plan import MappingPlan, MappingResult
from .spec import MappingSpec, MultilevelSpec, PlanSpec, ShapeBucket, \
    TopologySpec

__all__ = [
    "CommGraph", "DeviceGraph", "GraphFormatError", "device_pairs",
    "from_dense", "from_edges", "grid3d",
    "random_geometric", "read_metis", "validate", "write_metis",
    "DistanceOracle", "Hierarchy", "supermuc_like", "tpu_v5e_fleet",
    "Mapper", "MapperService", "MappingPlan", "MappingResult",
    "MappingSpec", "MultilevelSpec", "PlanSpec", "ShapeBucket",
    "TopologySpec",
    "list_constructions", "register_construction",
    "list_neighborhoods", "register_neighborhood",
    "dense_gain_matrix", "qap_objective", "qap_objective_dense", "swap_gain",
]
