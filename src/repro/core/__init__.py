"""VieM core: sparse quadratic assignment process mapping (the paper's
contribution), reimplemented as a composable library.

Public surface:
  graph        — CSR communication graphs, Metis IO, generators
  hierarchy    — hierarchical topologies + online distance oracle
  objective    — sparse QAP objective, O(deg) swap gains, dense gain matrix
  partition    — multilevel perfectly-balanced partitioner (KaHIP stand-in)
  construction — identity/random/growing/hierarchybottomup/hierarchytopdown
  local_search — N², N² pruned, N_C^d neighborhoods
  mapping      — map_processes() top-level API
  comm_model   — communication-graph extraction from compiled XLA programs
"""

from .graph import CommGraph, GraphFormatError, from_dense, from_edges, \
    grid3d, random_geometric, read_metis, validate, write_metis
from .hierarchy import Hierarchy, supermuc_like, tpu_v5e_fleet
from .mapping import MappingResult, map_processes
from .objective import dense_gain_matrix, qap_objective, \
    qap_objective_dense, swap_gain

__all__ = [
    "CommGraph", "GraphFormatError", "from_dense", "from_edges", "grid3d",
    "random_geometric", "read_metis", "validate", "write_metis",
    "Hierarchy", "supermuc_like", "tpu_v5e_fleet",
    "MappingResult", "map_processes",
    "dense_gain_matrix", "qap_objective", "qap_objective_dense", "swap_gain",
]
