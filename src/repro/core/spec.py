"""`MappingSpec` — the one declarative config language for mappings.

Every way of asking for a mapping (library calls, the `viem` CLI, launch
specs, benchmarks, the serving queue) builds the same frozen, serializable
spec:

    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication", neighborhood_dist=10)
    spec.to_dict() / MappingSpec.from_dict(d)     # JSON-safe round trip
    MappingSpec.from_flags(args)                  # the guide's §4.1 flags

Algorithm names are resolved against the registries in
:mod:`repro.core.construction`, :mod:`repro.core.local_search`, and
:mod:`repro.topology`, so a third-party ``@register_construction`` /
``@register_topology`` plug-in is immediately addressable from a spec (and
from the CLI) without touching this file.

A spec may carry the machine model itself as a :class:`TopologySpec`
(kind + JSON-safe constructor params); ``Mapper.from_spec(spec)`` then
builds both the topology and the session from the one serialized object.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field

_NONE_ALIASES = (None, "none", "None", "")


@dataclass(frozen=True)
class TopologySpec:
    """Declarative machine model: a registered topology ``kind`` plus the
    JSON-safe constructor parameters its factory takes, e.g.::

        TopologySpec("tree",  {"factors": [4, 4], "distances": [1, 10]})
        TopologySpec("torus", {"dims": [16, 16]})
        TopologySpec("matrix", {"file": "D.metis"})

    ``build()`` resolves the kind against the ``@register_topology``
    registry and returns the live :class:`~repro.topology.Topology`.
    """

    kind: str = "tree"
    params: dict = field(default_factory=dict)

    def validate(self) -> "TopologySpec":
        from ..topology.base import resolve_topology
        resolve_topology(self.kind)
        return self

    def build(self):
        from ..topology.base import make_topology
        return make_topology(self.kind, **self.params)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: dict) -> "TopologySpec":
        unknown = sorted(set(d) - {"kind", "params"})
        if unknown:
            raise ValueError(f"unknown TopologySpec keys {unknown}; "
                             f"known keys: ['kind', 'params']")
        return cls(kind=d.get("kind", "tree"),
                   params=dict(d.get("params", {})))

    @classmethod
    def of(cls, topology) -> "TopologySpec":
        """Spec of a live topology (via its ``spec_params``)."""
        return cls(kind=topology.kind, params=topology.spec_params())


@dataclass(frozen=True)
class MappingSpec:
    """Declarative description of one mapping computation (guide §4.1).

    ``neighborhood=None`` skips local search (construction only).
    ``parallel_sweeps`` selects the TPU-adapted batched sweep over the
    paper's sequential search.  ``engine`` selects where the refinement
    loop runs: ``"host"`` (the reference numpy drivers) or ``"device"``
    (the jitted :mod:`repro.engine` sweep loop — graph, perm, pairs, and
    objective stay in device arrays until convergence; implies the
    batched-sweep semantics, so ``parallel_sweeps`` is moot with it).
    ``backend`` selects how standalone objective evaluations are computed:
    ``"numpy"`` (host, float64 — bit-identical to the legacy
    ``map_processes`` path) or ``"pallas"`` (the Pallas edge-list kernel,
    compiled once per session and cached by the :class:`Mapper`).
    ``max_sweeps=None`` keeps each search driver's own default budget.
    """

    construction: str = "hierarchytopdown"
    neighborhood: str | None = "communication"
    neighborhood_dist: int = 10
    preconfiguration: str = "eco"
    parallel_sweeps: bool = False
    engine: str = "host"
    backend: str = "numpy"
    seed: int = 0
    max_sweeps: int | None = None
    max_pairs: int = 2_000_000
    topology: TopologySpec | None = None

    def __post_init__(self):
        if self.neighborhood in _NONE_ALIASES:
            object.__setattr__(self, "neighborhood", None)
        if isinstance(self.topology, dict):
            object.__setattr__(self, "topology",
                               TopologySpec.from_dict(self.topology))

    # ------------------------------------------------------------ validation
    def validate(self) -> "MappingSpec":
        """Resolve every algorithm name against its registry; raise
        ``ValueError`` naming the offender (and what *is* registered)."""
        from .construction import resolve_construction
        from .local_search import resolve_neighborhood
        from .partition import PartitionConfig

        resolve_construction(self.construction)
        if self.neighborhood is not None:
            resolve_neighborhood(self.neighborhood)
        PartitionConfig.preconfiguration(self.preconfiguration)
        if self.backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"choose from ['numpy', 'pallas']")
        if self.engine not in ("host", "device"):
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"choose from ['host', 'device']")
        if self.neighborhood_dist < 1:
            raise ValueError("neighborhood_dist must be >= 1")
        if self.max_pairs < 1:
            raise ValueError("max_pairs must be >= 1")
        if self.max_sweeps is not None and self.max_sweeps < 0:
            raise ValueError("max_sweeps must be None or >= 0")
        if self.topology is not None:
            self.topology.validate()
        return self

    # ------------------------------------------------------- dict/json forms
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.topology is not None:
            d["topology"] = self.topology.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "MappingSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown MappingSpec keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "MappingSpec":
        return cls.from_dict(json.loads(text))

    # -------------------------------------------------------------- flags
    #  legacy guide flag            -> spec field
    _FLAG_FIELDS = (
        ("construction_algorithm", "construction"),
        ("local_search_neighborhood", "neighborhood"),
        ("communication_neighborhood_dist", "neighborhood_dist"),
        ("preconfiguration_mapping", "preconfiguration"),
        ("parallel_sweeps", "parallel_sweeps"),
        ("engine", "engine"),
        ("backend", "backend"),
        ("seed", "seed"),
    )

    @classmethod
    def from_flags(cls, args, base: "MappingSpec | None" = None
                   ) -> "MappingSpec":
        """Build a spec from an ``argparse`` namespace using the guide's
        §4.1 flag names.  Flags left at ``None`` fall back to ``base``
        (e.g. a spec loaded from ``--config``), so explicit flags override
        a config file."""
        spec = base or cls()
        overrides = {}
        for flag, field in cls._FLAG_FIELDS:
            val = getattr(args, flag, None)
            if val is not None:
                overrides[field] = val
        return spec.replace(**overrides) if overrides else spec

    def replace(self, **changes) -> "MappingSpec":
        return dataclasses.replace(self, **changes)
