"""`MappingSpec` — the one declarative config language for mappings.

Every way of asking for a mapping (library calls, the `viem` CLI, launch
specs, benchmarks, the serving queue) builds the same frozen, serializable
spec:

    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication", neighborhood_dist=10)
    spec.to_dict() / MappingSpec.from_dict(d)     # JSON-safe round trip
    MappingSpec.from_flags(args)                  # the guide's §4.1 flags

Algorithm names are resolved against the registries in
:mod:`repro.core.construction`, :mod:`repro.core.local_search`, and
:mod:`repro.topology`, so a third-party ``@register_construction`` /
``@register_topology`` plug-in is immediately addressable from a spec (and
from the CLI) without touching this file.

A spec may carry the machine model itself as a :class:`TopologySpec`
(kind + JSON-safe constructor params); ``Mapper.from_spec(spec)`` then
builds both the topology and the session from the one serialized object.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field

_NONE_ALIASES = (None, "none", "None", "")


@dataclass(frozen=True)
class TopologySpec:
    """Declarative machine model: a registered topology ``kind`` plus the
    JSON-safe constructor parameters its factory takes, e.g.::

        TopologySpec("tree",  {"factors": [4, 4], "distances": [1, 10]})
        TopologySpec("torus", {"dims": [16, 16]})
        TopologySpec("matrix", {"file": "D.metis"})

    ``build()`` resolves the kind against the ``@register_topology``
    registry and returns the live :class:`~repro.topology.Topology`.
    """

    kind: str = "tree"
    params: dict = field(default_factory=dict)

    def validate(self) -> "TopologySpec":
        from ..topology.base import resolve_topology
        resolve_topology(self.kind)
        return self

    def build(self):
        from ..topology.base import make_topology
        return make_topology(self.kind, **self.params)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: dict) -> "TopologySpec":
        unknown = sorted(set(d) - {"kind", "params"})
        if unknown:
            raise ValueError(f"unknown TopologySpec keys {unknown}; "
                             f"known keys: ['kind', 'params']")
        return cls(kind=d.get("kind", "tree"),
                   params=dict(d.get("params", {})))

    @classmethod
    def of(cls, topology) -> "TopologySpec":
        """Spec of a live topology (via its ``spec_params``)."""
        return cls(kind=topology.kind, params=topology.spec_params())


# ----------------------------------------------------------- shape buckets
# base quanta for the padded device shapes — the DeviceGraph/device_pairs
# padding defaults, so a "tight" bucket reproduces the pre-plan shapes
# bit-for-bit
_DEG_BASE = 8
_EDGE_BASE = 128
_PAIR_BASE = 128


def bucket_round(x: int, schedule: str, base: int) -> int:
    """Round a raw size up by a bucket schedule.

    ``"tight"`` → the next multiple of ``base`` (the device-array padding
    quantum — exactly the shapes the engine would pick per graph);
    ``"pow2"`` → the next power of two, at least ``base`` (few, coarse
    buckets — the serving default, so mixed traffic collapses onto a
    handful of compiled executables); ``"mult:<k>"`` → the next multiple
    of ``k`` (a custom linear schedule).
    """
    x = max(int(x), 1)
    if schedule == "tight":
        return max(base, -(-x // base) * base)
    if schedule == "pow2":
        return max(base, 1 << (x - 1).bit_length())
    if schedule.startswith("mult:"):
        k = int(schedule.split(":", 1)[1])
        if k < 1:
            raise ValueError(f"mult bucket schedule needs k >= 1, got {k}")
        # never below the tight rounding: device arrays are padded to
        # ``base`` quanta regardless, and a bucket smaller than that
        # padding could not hold the graph it was derived from
        return max(-(-x // k) * k, max(base, -(-x // base) * base))
    raise ValueError(f"unknown bucket schedule {schedule!r}; choose "
                     f"'tight', 'pow2', or 'mult:<k>'")


@dataclass(frozen=True)
class ShapeBucket:
    """Padded device-shape geometry of a :class:`~repro.core.plan.MappingPlan`.

    ``max_deg`` (K) and ``num_edges`` (E) fix the ELL neighbor width and
    padded edge-list length every graph is padded into; ``num_pairs`` (P)
    fixes the candidate-pair length, or ``None`` to round each request's
    pair count by ``schedule`` (pairs are generated per request, so their
    count is not known at lower time).  Padding into a bucket is inert —
    the DeviceGraph/pair padding invariants guarantee results identical
    to exact shapes — so the only effect of a coarser schedule is fewer
    distinct compiled executables.
    """

    max_deg: int
    num_edges: int
    num_pairs: int | None = None
    schedule: str = "tight"

    def validate(self) -> "ShapeBucket":
        if self.max_deg < 1 or self.num_edges < 1:
            raise ValueError("ShapeBucket sizes must be >= 1")
        if self.num_pairs is not None and self.num_pairs < 1:
            raise ValueError("ShapeBucket num_pairs must be None or >= 1")
        bucket_round(1, self.schedule, 1)    # schedule name check
        return self

    @classmethod
    def of(cls, g, schedule: str = "tight",
           num_pairs: int | None = None) -> "ShapeBucket":
        """The bucket a graph pads into under ``schedule``."""
        import numpy as np
        deg = int(np.diff(g.xadj).max(initial=0))
        return cls(
            max_deg=bucket_round(deg, schedule, _DEG_BASE),
            num_edges=bucket_round(g.num_edges, schedule, _EDGE_BASE),
            num_pairs=(None if num_pairs is None else
                       bucket_round(num_pairs, schedule, _PAIR_BASE)),
            schedule=schedule)

    def admits(self, g) -> bool:
        """Whether the graph fits this bucket's padded shapes."""
        import numpy as np
        return (int(np.diff(g.xadj).max(initial=0)) <= self.max_deg
                and g.num_edges <= self.num_edges)

    def union(self, other: "ShapeBucket") -> "ShapeBucket":
        """Elementwise-max bucket admitting everything both admit."""
        pairs = (None if self.num_pairs is None or other.num_pairs is None
                 else max(self.num_pairs, other.num_pairs))
        return ShapeBucket(max(self.max_deg, other.max_deg),
                           max(self.num_edges, other.num_edges),
                           pairs, self.schedule)

    def pair_pad(self, n_pairs: int) -> int:
        """Padded pair-array length for a request with ``n_pairs``
        candidates: the fixed P when set, else the schedule's rounding."""
        if self.num_pairs is not None:
            if n_pairs > self.num_pairs:
                raise ValueError(f"{n_pairs} candidate pairs exceed the "
                                 f"plan bucket's num_pairs="
                                 f"{self.num_pairs}")
            return self.num_pairs
        return bucket_round(n_pairs, self.schedule, _PAIR_BASE)

    def tag(self) -> str:
        p = "dyn" if self.num_pairs is None else str(self.num_pairs)
        return f"K{self.max_deg}:E{self.num_edges}:P{p}"

    def to_dict(self) -> dict:
        return {"max_deg": self.max_deg, "num_edges": self.num_edges,
                "num_pairs": self.num_pairs, "schedule": self.schedule}

    @classmethod
    def from_dict(cls, d: dict) -> "ShapeBucket":
        known = {"max_deg", "num_edges", "num_pairs", "schedule"}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown ShapeBucket keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(max_deg=d["max_deg"], num_edges=d["num_edges"],
                   num_pairs=d.get("num_pairs"),
                   schedule=d.get("schedule", "tight"))


# --preconfiguration → V-cycle knobs: (levels, coarsen_min).  The same
# flag that tunes the internal partitioner (seed trials, FM passes) and
# the device engine's sweep budget also scales the multilevel pyramid —
# one flag, coherent partition/engine/multilevel settings.
_ML_PRECONF = {
    "fast": (2, 128),
    "eco": (4, 64),
    "strong": (6, 32),
}


@dataclass(frozen=True)
class MultilevelSpec:
    """V-cycle knobs for the multilevel mapping subsystem
    (:mod:`repro.multilevel`).

    ``levels`` is the maximum number of graph scales including the finest
    (1 = no coarsening: the parity escape hatch — bit-for-bit the flat
    device engine); ``coarsen_min`` stops contraction once the coarse
    level would drop below that many vertices.  Fields left ``None``
    resolve from the spec's ``preconfiguration``
    (fast → (2, 128), eco → (4, 64), strong → (6, 32)).
    """

    levels: int | None = None
    coarsen_min: int | None = None

    def validate(self) -> "MultilevelSpec":
        if self.levels is not None and self.levels < 1:
            raise ValueError("multilevel levels must be None or >= 1")
        if self.coarsen_min is not None and self.coarsen_min < 2:
            raise ValueError("multilevel coarsen_min must be None or >= 2")
        return self

    def resolve(self, preconfiguration: str) -> tuple[int, int]:
        """Concrete ``(levels, coarsen_min)`` for a preconfiguration."""
        d_levels, d_cmin = _ML_PRECONF.get(preconfiguration,
                                           _ML_PRECONF["eco"])
        return (self.levels if self.levels is not None else d_levels,
                self.coarsen_min if self.coarsen_min is not None
                else d_cmin)

    def to_dict(self) -> dict:
        return {"levels": self.levels, "coarsen_min": self.coarsen_min}

    @classmethod
    def from_dict(cls, d: dict) -> "MultilevelSpec":
        known = {"levels", "coarsen_min"}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown MultilevelSpec keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(levels=d.get("levels"), coarsen_min=d.get("coarsen_min"))


@dataclass(frozen=True)
class PortfolioSpec:
    """Knobs for the device-side portfolio search
    (:mod:`repro.portfolio`): ``lanes`` restart trajectories run as ONE
    vmapped engine call per level, then ``rounds - 1`` perturb→refine
    rounds at the finest level with device-side tournament selection of
    the incumbent.

    ``tabu_tenure`` sweeps of tabu memory per applied exchange (0 turns
    the tabu masking off — bit-for-bit the monotone sweep);
    ``dont_look`` enables the don't-look bits (only active alongside a
    nonzero tenure); ``kick_strength`` is the fraction of vertices each
    between-round perturbation kick touches; ``stagnation`` stops the
    round loop after that many rounds without improving the incumbent.
    ``constructions`` optionally names a per-lane construction portfolio
    (cycled across lanes); ``None`` seeds every lane from the spec's one
    ``construction`` with per-lane seeds.

    ``lanes=1`` with ``rounds=1`` and ``tabu_tenure=0`` is the
    degeneracy escape hatch: bit-for-bit the non-portfolio pipeline.
    """

    lanes: int = 8
    rounds: int = 4
    tabu_tenure: int = 8
    kick_strength: float = 0.15
    stagnation: int = 3
    dont_look: bool = True
    constructions: tuple | None = None

    def __post_init__(self):
        if isinstance(self.constructions, list):
            object.__setattr__(self, "constructions",
                               tuple(self.constructions))

    def validate(self) -> "PortfolioSpec":
        from .construction import resolve_construction
        if self.lanes < 1:
            raise ValueError("portfolio lanes must be >= 1")
        if self.rounds < 1:
            raise ValueError("portfolio rounds must be >= 1")
        if self.tabu_tenure < 0:
            raise ValueError("portfolio tabu_tenure must be >= 0")
        if not 0.0 <= self.kick_strength <= 1.0:
            raise ValueError("portfolio kick_strength must be in [0, 1]")
        if self.stagnation < 1:
            raise ValueError("portfolio stagnation must be >= 1")
        if self.constructions is not None:
            if not self.constructions:
                raise ValueError("portfolio constructions must be None "
                                 "or a non-empty sequence of names")
            for name in self.constructions:
                resolve_construction(name)
        return self

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.constructions is not None:
            d["constructions"] = list(self.constructions)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PortfolioSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown PortfolioSpec keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(**d)

    def replace(self, **changes) -> "PortfolioSpec":
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class KernelSpec:
    """User-facing kernel-geometry overrides (all optional — the plan
    derives concrete :class:`~repro.kernels.config.KernelConfig` values
    from its :class:`ShapeBucket` and the jax backend at ``lower`` time;
    anything set here wins over derivation).

    ``block_rows``/``lanes`` pin the reduction-tile geometry (lanes must
    be a multiple of 128); ``acc_dtype`` pins the tiled-reduction
    accumulator; ``quantize`` controls the matrix-topology distance-table
    packing: ``"auto"`` (the default) packs to int8/int16 when lossless,
    ``"off"`` keeps float32 tables, and an explicit ``"int8"``/``"int16"``
    forces that width (raising at lower time if the table does not fit —
    a forced packing must never silently change results).
    """

    block_rows: int | None = None
    lanes: int | None = None
    acc_dtype: str | None = None
    quantize: str = "auto"

    def validate(self) -> "KernelSpec":
        if self.block_rows is not None and self.block_rows < 1:
            raise ValueError("kernel block_rows must be None or >= 1")
        if self.lanes is not None and (self.lanes < 128 or self.lanes % 128):
            raise ValueError("kernel lanes must be None or a positive "
                             "multiple of 128")
        if self.acc_dtype not in (None, "float32", "float64"):
            raise ValueError(f"unknown kernel acc_dtype "
                             f"{self.acc_dtype!r}; choose None, "
                             f"'float32', or 'float64'")
        if self.quantize not in ("auto", "off", "int8", "int16"):
            raise ValueError(f"unknown kernel quantize mode "
                             f"{self.quantize!r}; choose from "
                             f"['auto', 'off', 'int8', 'int16']")
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown KernelSpec keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(**d)

    def replace(self, **changes) -> "KernelSpec":
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class MappingSpec:
    """Declarative description of one mapping computation (guide §4.1).

    ``neighborhood=None`` skips local search (construction only).
    ``parallel_sweeps`` selects the TPU-adapted batched sweep over the
    paper's sequential search.  ``engine`` selects where the refinement
    loop runs: ``"host"`` (the reference numpy drivers) or ``"device"``
    (the jitted :mod:`repro.engine` sweep loop — graph, perm, pairs, and
    objective stay in device arrays until convergence; implies the
    batched-sweep semantics, so ``parallel_sweeps`` is moot with it).
    ``backend`` selects how standalone objective evaluations are computed:
    ``"numpy"`` (host, float64 — bit-identical to the legacy pre-session
    path) or ``"pallas"`` (the Pallas edge-list kernel, compiled at
    ``lower`` time and carried by the :class:`MappingPlan`).
    ``max_sweeps=None`` keeps each search driver's own default budget
    (for the device engine the budget then follows ``preconfiguration``:
    fast 32, eco 64, strong 128 sweeps).  ``multilevel`` enables the
    coarsen → map → uncoarsen V-cycle over the device engine
    (:mod:`repro.multilevel`); ``None`` (the default) keeps the flat
    single-level pipeline, and ``MultilevelSpec(levels=1)`` is
    bit-for-bit identical to it.  ``portfolio`` enables the vmapped
    multistart search with tabu memory (:mod:`repro.portfolio`); ``None``
    keeps the single-trajectory pipeline, and
    ``PortfolioSpec(lanes=1, rounds=1, tabu_tenure=0)`` is bit-for-bit
    identical to it.
    """

    construction: str = "hierarchytopdown"
    neighborhood: str | None = "communication"
    neighborhood_dist: int = 10
    preconfiguration: str = "eco"
    parallel_sweeps: bool = False
    engine: str = "host"
    backend: str = "numpy"
    seed: int = 0
    max_sweeps: int | None = None
    max_pairs: int = 2_000_000
    topology: TopologySpec | None = None
    multilevel: MultilevelSpec | None = None
    portfolio: PortfolioSpec | None = None
    kernel: KernelSpec | None = None

    def __post_init__(self):
        if self.neighborhood in _NONE_ALIASES:
            object.__setattr__(self, "neighborhood", None)
        if isinstance(self.topology, dict):
            object.__setattr__(self, "topology",
                               TopologySpec.from_dict(self.topology))
        if isinstance(self.multilevel, dict):
            object.__setattr__(self, "multilevel",
                               MultilevelSpec.from_dict(self.multilevel))
        if isinstance(self.portfolio, dict):
            object.__setattr__(self, "portfolio",
                               PortfolioSpec.from_dict(self.portfolio))
        if isinstance(self.kernel, dict):
            object.__setattr__(self, "kernel",
                               KernelSpec.from_dict(self.kernel))

    # ------------------------------------------------------------ validation
    def validate(self) -> "MappingSpec":
        """Resolve every algorithm name against its registry; raise
        ``ValueError`` naming the offender (and what *is* registered)."""
        from .construction import resolve_construction
        from .local_search import resolve_neighborhood
        from .partition import PartitionConfig

        resolve_construction(self.construction)
        if self.neighborhood is not None:
            resolve_neighborhood(self.neighborhood)
        PartitionConfig.preconfiguration(self.preconfiguration)
        if self.backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"choose from ['numpy', 'pallas']")
        if self.engine not in ("host", "device"):
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"choose from ['host', 'device']")
        if self.neighborhood_dist < 1:
            raise ValueError("neighborhood_dist must be >= 1")
        if self.max_pairs < 1:
            raise ValueError("max_pairs must be >= 1")
        if self.max_sweeps is not None and self.max_sweeps < 0:
            raise ValueError("max_sweeps must be None or >= 0")
        if self.topology is not None:
            self.topology.validate()
        if self.multilevel is not None:
            self.multilevel.validate()
            if self.engine != "device" and \
                    self.multilevel.resolve(self.preconfiguration)[0] > 1:
                raise ValueError(
                    "multilevel mapping runs the device refinement "
                    "engine at every level; set engine='device' "
                    "(or pass --engine=device)")
        if self.portfolio is not None:
            self.portfolio.validate()
            if self.engine != "device":
                raise ValueError(
                    "portfolio search runs the vmapped device refinement "
                    "engine; set engine='device' (or pass --engine=device)")
        if self.kernel is not None:
            self.kernel.validate()
        return self

    # ------------------------------------------------------- dict/json forms
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.topology is not None:
            d["topology"] = self.topology.to_dict()
        if self.multilevel is not None:
            d["multilevel"] = self.multilevel.to_dict()
        if self.portfolio is not None:
            d["portfolio"] = self.portfolio.to_dict()
        if self.kernel is not None:
            d["kernel"] = self.kernel.to_dict()
        return d

    # -------------------------------------------------------- resolution
    def resolved_multilevel(self) -> "tuple[int, int] | None":
        """Concrete V-cycle knobs ``(levels, coarsen_min)``, or ``None``
        when the spec maps flat (no multilevel block, or an escape-hatch
        ``levels=1``)."""
        if self.multilevel is None:
            return None
        levels, cmin = self.multilevel.resolve(self.preconfiguration)
        return None if levels <= 1 else (levels, cmin)

    @classmethod
    def from_dict(cls, d: dict) -> "MappingSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown MappingSpec keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "MappingSpec":
        return cls.from_dict(json.loads(text))

    # -------------------------------------------------------------- flags
    #  legacy guide flag            -> spec field
    _FLAG_FIELDS = (
        ("construction_algorithm", "construction"),
        ("local_search_neighborhood", "neighborhood"),
        ("communication_neighborhood_dist", "neighborhood_dist"),
        ("preconfiguration_mapping", "preconfiguration"),
        ("parallel_sweeps", "parallel_sweeps"),
        ("engine", "engine"),
        ("backend", "backend"),
        ("seed", "seed"),
    )

    @classmethod
    def from_flags(cls, args, base: "MappingSpec | None" = None
                   ) -> "MappingSpec":
        """Build a spec from an ``argparse`` namespace using the guide's
        §4.1 flag names.  Flags left at ``None`` fall back to ``base``
        (e.g. a spec loaded from ``--config``), so explicit flags override
        a config file."""
        spec = base or cls()
        overrides = {}
        for flag, field in cls._FLAG_FIELDS:
            val = getattr(args, flag, None)
            if val is not None:
                overrides[field] = val
        ml_on = getattr(args, "multilevel", None)
        ml_levels = getattr(args, "multilevel_levels", None)
        ml_cmin = getattr(args, "multilevel_coarsen_min", None)
        if ml_on is False:
            overrides["multilevel"] = None           # --no-multilevel
        elif ml_on or ml_levels is not None or ml_cmin is not None:
            ml = spec.multilevel or MultilevelSpec()
            if ml_levels is not None or ml_cmin is not None:
                ml = dataclasses.replace(
                    ml,
                    levels=ml_levels if ml_levels is not None else ml.levels,
                    coarsen_min=(ml_cmin if ml_cmin is not None
                                 else ml.coarsen_min))
            overrides["multilevel"] = ml
            # the V-cycle runs over the device engine; an explicit
            # --engine still wins (validate() rejects host + multilevel)
            if getattr(args, "engine", None) is None and \
                    spec.engine == "host":
                overrides["engine"] = "device"
        pf_on = getattr(args, "portfolio", None)
        pf_flags = {
            "lanes": getattr(args, "portfolio_lanes", None),
            "rounds": getattr(args, "portfolio_rounds", None),
            "tabu_tenure": getattr(args, "portfolio_tabu_tenure", None),
            "kick_strength": getattr(args, "portfolio_kick", None),
            "stagnation": getattr(args, "portfolio_stagnation", None),
        }
        pf_set = {k: v for k, v in pf_flags.items() if v is not None}
        if pf_on is False:
            overrides["portfolio"] = None            # --no-portfolio
        elif pf_on or pf_set:
            pf = spec.portfolio or PortfolioSpec()
            if pf_set:
                pf = pf.replace(**pf_set)
            overrides["portfolio"] = pf
            # the portfolio runs over the device engine; an explicit
            # --engine still wins (validate() rejects host + portfolio)
            if getattr(args, "engine", None) is None and \
                    overrides.get("engine", spec.engine) == "host":
                overrides["engine"] = "device"
        kn_flags = {
            "block_rows": getattr(args, "kernel_block_rows", None),
            "lanes": getattr(args, "kernel_lanes", None),
            "quantize": getattr(args, "kernel_quantize", None),
        }
        kn_set = {k: v for k, v in kn_flags.items() if v is not None}
        if kn_set:
            kn = spec.kernel or KernelSpec()
            overrides["kernel"] = kn.replace(**kn_set)
        return spec.replace(**overrides) if overrides else spec

    def replace(self, **changes) -> "MappingSpec":
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class PlanSpec:
    """The serializable identity of a :class:`~repro.core.plan.MappingPlan`:
    the full :class:`MappingSpec` (machine model included as its
    :class:`TopologySpec`) plus the :class:`ShapeBucket` the plan was
    lowered for.  ``MappingPlan.from_dict`` / ``.load`` rebuild the live
    plan — topology, level pyramid machines, kernels, jitted engine
    executables — from this spec alone, which is what makes plans
    pickle/JSON-portable across processes.
    """

    mapping: MappingSpec
    bucket: ShapeBucket | None = None

    def __post_init__(self):
        if isinstance(self.mapping, dict):
            object.__setattr__(self, "mapping",
                               MappingSpec.from_dict(self.mapping))
        if isinstance(self.bucket, dict):
            object.__setattr__(self, "bucket",
                               ShapeBucket.from_dict(self.bucket))

    def validate(self) -> "PlanSpec":
        self.mapping.validate()
        if self.mapping.topology is None:
            raise ValueError(
                "PlanSpec needs the machine model inside the MappingSpec "
                "(spec.topology) so the plan can be rebuilt on load")
        if self.bucket is not None:
            self.bucket.validate()
        return self

    def to_dict(self) -> dict:
        return {"mapping": self.mapping.to_dict(),
                "bucket": None if self.bucket is None
                else self.bucket.to_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanSpec":
        known = {"mapping", "bucket"}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown PlanSpec keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(mapping=d["mapping"], bucket=d.get("bucket"))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "PlanSpec":
        return cls.from_dict(json.loads(text))
