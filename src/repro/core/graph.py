"""Sparse communication graphs in CSR form + Metis/Chaco/DIMACS file format.

This is the substrate of the paper: the communication matrix C is *always*
handled as a graph G_C = ({1..n}, E[C]) with E[C] = {(u,v) | C_uv != 0}
(guide §2.2).  We keep forward and backward edges explicitly (symmetric CSR),
exactly like the Metis format the guide mandates (§3.1).
"""

from __future__ import annotations

import io
from dataclasses import dataclass

import numpy as np


class GraphFormatError(ValueError):
    """Raised when an input file violates the guide's format rules (§3.3)."""


@dataclass
class CommGraph:
    """Undirected weighted graph in CSR form.

    Attributes:
      xadj:    (n+1,) int64 — CSR row pointers.
      adjncy:  (2m,)  int64 — neighbor ids, both directions stored.
      adjwgt:  (2m,)  float64 — edge weights, mirrored on both directions.
      vwgt:    (n,)   float64 — vertex weights (ignored for one-to-one
               mappings per guide §3.1, but kept for the partitioner).
    """

    xadj: np.ndarray
    adjncy: np.ndarray
    adjwgt: np.ndarray
    vwgt: np.ndarray

    # ------------------------------------------------------------------ basic
    @property
    def n(self) -> int:
        return len(self.xadj) - 1

    @property
    def num_edges(self) -> int:
        """Number of undirected edges m (each stored twice in CSR)."""
        return len(self.adjncy) // 2

    def degree(self, u: int) -> int:
        return int(self.xadj[u + 1] - self.xadj[u])

    def neighbors(self, u: int) -> np.ndarray:
        return self.adjncy[self.xadj[u]:self.xadj[u + 1]]

    def weights(self, u: int) -> np.ndarray:
        return self.adjwgt[self.xadj[u]:self.xadj[u + 1]]

    def edge_list(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(u, v, w) arrays with u < v — each undirected edge once."""
        src = np.repeat(np.arange(self.n, dtype=np.int64),
                        np.diff(self.xadj))
        mask = src < self.adjncy
        return src[mask], self.adjncy[mask], self.adjwgt[mask]

    def total_edge_weight(self) -> float:
        return float(self.adjwgt.sum()) / 2.0

    def to_dense(self) -> np.ndarray:
        """Dense symmetric communication matrix C (test/small-n use only)."""
        C = np.zeros((self.n, self.n))
        src = np.repeat(np.arange(self.n), np.diff(self.xadj))
        C[src, self.adjncy] = self.adjwgt
        return C

    def subgraph(self, nodes: np.ndarray) -> tuple["CommGraph", np.ndarray]:
        """Induced subgraph; returns (graph, mapping local->global)."""
        nodes = np.asarray(nodes, dtype=np.int64)
        glob2loc = -np.ones(self.n, dtype=np.int64)
        glob2loc[nodes] = np.arange(len(nodes))
        xadj = [0]
        adjncy: list[np.ndarray] = []
        adjwgt: list[np.ndarray] = []
        for u in nodes:
            nb = self.neighbors(u)
            wt = self.weights(u)
            loc = glob2loc[nb]
            keep = loc >= 0
            adjncy.append(loc[keep])
            adjwgt.append(wt[keep])
            xadj.append(xadj[-1] + int(keep.sum()))
        return (
            CommGraph(
                xadj=np.asarray(xadj, dtype=np.int64),
                adjncy=(np.concatenate(adjncy) if adjncy else
                        np.zeros(0, np.int64)).astype(np.int64),
                adjwgt=(np.concatenate(adjwgt) if adjwgt else
                        np.zeros(0)).astype(np.float64),
                vwgt=self.vwgt[nodes].copy(),
            ),
            nodes,
        )


def contract(g: CommGraph, labels: np.ndarray, k: int) -> CommGraph:
    """Collapse ``g`` along a cluster labeling: vertices = clusters
    ``0..k-1``, edge weight = summed inter-cluster communication,
    intra-cluster edges dropped (no self-loops — the Metis invariant),
    vertex weights summed per cluster.

    The one edge-collapsing primitive behind the ``generate_model``
    quotient (:func:`repro.core.construction.quotient`), the
    partitioner's host coarsening, and the multilevel mapping V-cycle's
    host-side graph assembly.
    """
    labels = np.asarray(labels, dtype=np.int64)
    u, v, w = g.edge_list()
    cu, cv = labels[u], labels[v]
    keep = cu != cv
    cu, cv, w = cu[keep], cv[keep], w[keep]
    lo, hi = np.minimum(cu, cv), np.maximum(cu, cv)
    vw = np.bincount(labels, weights=g.vwgt, minlength=k)
    if len(lo) == 0:
        return CommGraph(np.zeros(k + 1, np.int64), np.zeros(0, np.int64),
                         np.zeros(0), vw)
    return from_edges(k, lo, hi, w, vwgt=vw)


def csr_expand(xadj: np.ndarray, rows: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Loop-free flat expansion of CSR rows: for each r in ``rows`` (in
    order, repeats allowed) the positions [xadj[r], xadj[r+1])
    concatenated.  Returns ``(pos, off, cnt)`` — flat CSR positions,
    within-row offsets, and per-row counts — the shared repeat/offset
    idiom behind batched gains, frontier BFS, and the ELL conversion.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cnt = xadj[rows + 1] - xadj[rows]
    total = int(cnt.sum())
    if not total:
        z = np.zeros(0, np.int64)
        return z, z.copy(), cnt
    ends = np.cumsum(cnt)
    off = np.arange(total, dtype=np.int64) - np.repeat(ends - cnt, cnt)
    return np.repeat(xadj[rows], cnt) + off, off, cnt


# ------------------------------------------------------------- device arrays
@dataclass
class DeviceGraph:
    """Device-resident view of a :class:`CommGraph` for the refinement
    engine: fixed-width (ELL) neighbor rows plus a padded edge list, all
    jnp arrays, so gains, objectives, and sweeps run without ragged
    indexing or host round-trips.

    Attributes:
      nbr:  (n, K) int32 — neighbor ids; rows right-padded with the row's
            own vertex id (safe for any D gather; the weight masks it out).
      wgt:  (n, K) float32 — edge weights, 0.0 on padding.
      eu/ev/ew: (E,) int32/int32/float32 — each undirected edge once
            (u < v), padded with (0, 0, 0.0) entries (inert: w = 0).
      n, num_edges: true (unpadded) sizes.

    Padding invariants (relied on by the engine and tested):
      * a padded neighbor slot contributes 0 to every pair gain (w = 0),
      * a padded edge contributes 0 to the objective (w = 0),
      * both are invariant under *further* padding, so batching graphs to
        common (K, E) maxima leaves per-graph results unchanged.
    """

    nbr: object
    wgt: object
    eu: object
    ev: object
    ew: object
    n: int
    num_edges: int

    @property
    def max_deg(self) -> int:
        return self.nbr.shape[1]

    @classmethod
    def from_comm(cls, g: "CommGraph", pad_deg_to: int = 8,
                  pad_edges_to: int = 128) -> "DeviceGraph":
        """Build the padded device arrays from a CSR graph.  ``pad_deg_to``
        / ``pad_edges_to`` round K and E up so jit shapes bucket instead of
        recompiling per graph."""
        import jax.numpy as jnp
        n = g.n
        pos, cols, deg = csr_expand(g.xadj, np.arange(n))
        k = int(deg.max(initial=0))
        k = max(pad_deg_to, -(-max(k, 1) // pad_deg_to) * pad_deg_to)
        nbr = np.repeat(np.arange(n, dtype=np.int32)[:, None], k, axis=1)
        wgt = np.zeros((n, k), dtype=np.float32)
        rows = np.repeat(np.arange(n), deg)
        nbr[rows, cols] = g.adjncy[pos]
        wgt[rows, cols] = g.adjwgt[pos]
        from ..kernels.pad import pad_edge_arrays
        u, v, w = g.edge_list()
        eu, ev, ew = pad_edge_arrays(u, v, w, base=pad_edges_to)
        return cls(
            nbr=jnp.asarray(nbr), wgt=jnp.asarray(wgt),
            eu=eu, ev=ev, ew=ew,
            n=n, num_edges=len(u))

    def pad_to(self, max_deg: int, num_edges: int) -> "DeviceGraph":
        """Re-pad to a batch's common (K, E) — results are unchanged by
        the extra inert padding (see class docstring)."""
        import jax.numpy as jnp
        if max_deg < self.max_deg or num_edges < self.eu.shape[0]:
            raise ValueError("pad_to cannot shrink device arrays")
        dk = max_deg - self.max_deg
        de = num_edges - self.eu.shape[0]
        row_ids = jnp.broadcast_to(
            jnp.arange(self.n, dtype=jnp.int32)[:, None], (self.n, dk))
        return DeviceGraph(
            nbr=jnp.concatenate([self.nbr, row_ids], axis=1),
            wgt=jnp.pad(self.wgt, ((0, 0), (0, dk))),
            eu=jnp.pad(self.eu, (0, de)), ev=jnp.pad(self.ev, (0, de)),
            ew=jnp.pad(self.ew, (0, de)),
            n=self.n, num_edges=self.num_edges)


def device_pairs(pairs: np.ndarray, pad_to: int = 128) -> tuple:
    """Candidate pairs as device arrays: (us, vs) int32, right-padded with
    (0, 0) entries to a ``pad_to`` multiple.  A u == v pair has exactly
    zero gain and is never selected by the engine, so the padding is
    inert (and invariant under further padding — batching-safe)."""
    import jax.numpy as jnp
    pairs = np.asarray(pairs, dtype=np.int64)
    p = max(pad_to, -(-max(len(pairs), 1) // pad_to) * pad_to)
    pad = p - len(pairs)
    us = np.pad(pairs[:, 0] if len(pairs) else np.zeros(0, np.int64),
                (0, pad)).astype(np.int32)
    vs = np.pad(pairs[:, 1] if len(pairs) else np.zeros(0, np.int64),
                (0, pad)).astype(np.int32)
    return jnp.asarray(us), jnp.asarray(vs)


# --------------------------------------------------------------------- build
def from_edges(n: int, u: np.ndarray, v: np.ndarray, w: np.ndarray,
               vwgt: np.ndarray | None = None) -> CommGraph:
    """Build a symmetric CSR graph from one-directional edge lists.

    Parallel edges are merged by summing weights; self loops are rejected
    (the guide's format forbids both, §3.3 — `from_edges` is the programmatic
    entry so we merge rather than crash, but loops are a caller bug).
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    if np.any(u == v):
        raise GraphFormatError("self-loops are not allowed")
    # mirror
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    wt = np.concatenate([w, w])
    # merge parallel edges: sort by (src, dst) and sum runs
    key = src * n + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, wt = key[order], src[order], dst[order], wt[order]
    uniq, start = np.unique(key, return_index=True)
    wsum = np.add.reduceat(wt, start) if len(wt) else wt
    src = src[start]
    dst = dst[start]
    xadj = np.zeros(n + 1, dtype=np.int64)
    np.add.at(xadj, src + 1, 1)
    xadj = np.cumsum(xadj)
    return CommGraph(
        xadj=xadj,
        adjncy=dst.astype(np.int64),
        adjwgt=wsum.astype(np.float64),
        vwgt=(np.ones(n) if vwgt is None else
              np.asarray(vwgt, dtype=np.float64)),
    )


def from_dense(C: np.ndarray) -> CommGraph:
    """Graph view of a dense symmetric communication matrix."""
    C = np.asarray(C, dtype=np.float64)
    if C.shape[0] != C.shape[1]:
        raise GraphFormatError("C must be square")
    if not np.allclose(C, C.T):
        raise GraphFormatError("C must be symmetric (guide §1)")
    iu, iv = np.nonzero(np.triu(C, k=1))
    return from_edges(C.shape[0], iu, iv, C[iu, iv])


# ----------------------------------------------------------------- Metis IO
def read_metis(path_or_file) -> CommGraph:
    """Read the Metis/Chaco/DIMACS format described in guide §3.1.

    First line: ``n m [f]`` with f in {<absent>, 1, 10, 11}.  Comment lines
    start with %.  Vertices are 1-indexed in the file, 0-indexed in memory.
    Violations raise GraphFormatError with the same checks the guide's
    `graphchecker` performs (§3.3, §4.3).
    """
    if hasattr(path_or_file, "read"):
        lines = path_or_file.read().splitlines()
    else:
        with open(path_or_file, "r") as fh:
            lines = fh.read().splitlines()
    # blank lines are significant — an isolated vertex has an empty
    # adjacency line — so only comments and leading blanks are dropped
    body = [ln for ln in lines if not ln.lstrip().startswith("%")]
    while body and not body[0].strip():
        body.pop(0)
    if not body:
        raise GraphFormatError("empty graph file")
    header = body[0].split()
    if len(header) not in (2, 3):
        raise GraphFormatError(f"header must be 'n m [f]', got {body[0]!r}")
    n, m = int(header[0]), int(header[1])
    fmt = header[2] if len(header) == 3 else "0"
    if fmt not in ("0", "1", "10", "11", "00", "01"):
        raise GraphFormatError(f"unknown format flag {fmt!r}")
    has_ew = fmt.endswith("1")
    has_vw = len(fmt) == 2 and fmt[0] == "1"
    # tolerate editor-added blank lines after the last vertex line
    while len(body) - 1 > n and not body[-1].strip():
        body.pop()
    if len(body) - 1 != n:
        raise GraphFormatError(
            f"file declares n={n} vertices but has {len(body)-1} vertex lines")
    xadj = [0]
    adjncy: list[int] = []
    adjwgt: list[float] = []
    vwgt = np.ones(n)
    for i, ln in enumerate(body[1:]):
        tok = ln.split()
        pos = 0
        if has_vw:
            if not tok:
                raise GraphFormatError(f"vertex {i+1}: missing vertex weight")
            cw = float(tok[0])
            if cw < 0:
                raise GraphFormatError(f"vertex {i+1}: vertex weight < 0")
            vwgt[i] = cw
            pos = 1
        step = 2 if has_ew else 1
        rest = tok[pos:]
        if len(rest) % step:
            raise GraphFormatError(
                f"vertex {i+1}: dangling token (edge weight missing?)")
        for j in range(0, len(rest), step):
            v = int(rest[j]) - 1
            if v == i:
                raise GraphFormatError(f"vertex {i+1}: self-loop")
            if not (0 <= v < n):
                raise GraphFormatError(f"vertex {i+1}: neighbor {v+1} out of range")
            w = float(rest[j + 1]) if has_ew else 1.0
            if w <= 0:
                raise GraphFormatError(f"vertex {i+1}: edge weight <= 0")
            adjncy.append(v)
            adjwgt.append(w)
        xadj.append(len(adjncy))
    g = CommGraph(np.asarray(xadj, np.int64), np.asarray(adjncy, np.int64),
                  np.asarray(adjwgt, np.float64), vwgt)
    validate(g, declared_m=m)
    return g


def validate(g: CommGraph, declared_m: int | None = None) -> None:
    """The `graphchecker` checks (guide §3.3): edge count, symmetry,
    matching forward/backward weights, no parallel edges."""
    if declared_m is not None and len(g.adjncy) != 2 * declared_m:
        raise GraphFormatError(
            f"header says m={declared_m} but file stores "
            f"{len(g.adjncy)} directed edges (expected {2*declared_m})")
    for u in range(g.n):
        nb = g.neighbors(u)
        if len(np.unique(nb)) != len(nb):
            raise GraphFormatError(f"vertex {u+1}: parallel edges")
    # symmetry + weight match via sorted key comparison
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.xadj))
    fwd = np.lexsort((g.adjncy, src))
    bwd = np.lexsort((src, g.adjncy))
    if not (np.array_equal(src[fwd], g.adjncy[bwd])
            and np.array_equal(g.adjncy[fwd], src[bwd])):
        raise GraphFormatError("missing backward edge")
    if not np.allclose(g.adjwgt[fwd], g.adjwgt[bwd]):
        raise GraphFormatError("forward/backward edge weights differ")


def write_metis(g: CommGraph, path_or_file, edge_weights: bool = True) -> None:
    out = io.StringIO()
    fmt = " 1" if edge_weights else ""
    out.write(f"{g.n} {g.num_edges}{fmt}\n")
    for u in range(g.n):
        toks: list[str] = []
        for v, w in zip(g.neighbors(u), g.weights(u)):
            toks.append(str(int(v) + 1))
            if edge_weights:
                toks.append(f"{int(w) if float(w).is_integer() else w}")
        out.write(" ".join(toks) + "\n")
    if hasattr(path_or_file, "write"):
        path_or_file.write(out.getvalue())
    else:
        with open(path_or_file, "w") as fh:
            fh.write(out.getvalue())


# ------------------------------------------------------------- generators
def grid3d(nx: int, ny: int, nz: int, torus: bool = False,
           weight: float = 1.0) -> CommGraph:
    """3D stencil communication graph — the canonical sparse HPC pattern."""
    def vid(x, y, z):
        return (x * ny + y) * nz + z
    us, vs = [], []
    for x in range(nx):
        for y in range(ny):
            for z in range(nz):
                for dx, dy, dz in ((1, 0, 0), (0, 1, 0), (0, 0, 1)):
                    X, Y, Z = x + dx, y + dy, z + dz
                    if torus:
                        if (dx and nx > 2 or dy and ny > 2 or dz and nz > 2):
                            X, Y, Z = X % nx, Y % ny, Z % nz
                        elif X >= nx or Y >= ny or Z >= nz:
                            continue
                    elif X >= nx or Y >= ny or Z >= nz:
                        continue
                    a, b = vid(x, y, z), vid(X, Y, Z)
                    if a != b:
                        us.append(a)
                        vs.append(b)
    us, vs = np.asarray(us), np.asarray(vs)
    return from_edges(nx * ny * nz, us, vs, np.full(len(us), weight))


def random_geometric(n: int, radius: float, seed: int = 0) -> CommGraph:
    """Random geometric graph in the unit square (sparse, community-like)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    iu, iv = np.nonzero(np.triu(d2 < radius * radius, k=1))
    w = rng.integers(1, 10, size=len(iu)).astype(np.float64)
    if len(iu) == 0:  # guarantee connectivity fallback: a path
        iu = np.arange(n - 1)
        iv = iu + 1
        w = np.ones(n - 1)
    return from_edges(n, iu, iv, w)
