"""Masked cross-entropy LM loss over padded-vocab logits."""

from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -1


def cross_entropy(logits, labels):
    """logits: (B, T, Vp); labels: (B, T) int32 with IGNORE for masked
    positions (modality-frontend slots, padding).  Mean over valid."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    valid = labels != IGNORE
    safe = jnp.where(valid, labels, 0)
    picked = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * valid
    count = jnp.maximum(valid.sum(), 1)
    return nll.sum() / count, count


def lm_loss(params, batch, cfg, forward_fn, aux_weight: float = 0.01):
    frontend = batch.get("frontend")
    logits, aux = forward_fn(params, batch["tokens"], cfg,
                             frontend=frontend)
    labels = batch["labels"]
    if frontend is not None:
        # frontend slots carry no labels
        b, f = labels.shape[0], frontend.shape[1]
        pad = jnp.full((b, f), IGNORE, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce, count = cross_entropy(logits, labels)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux,
                                   "tokens": count}
