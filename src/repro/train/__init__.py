"""Training/serving substrate: optimizer, loss, step builders, compression."""

from .loss import IGNORE, cross_entropy, lm_loss
from .optimizer import OptConfig, adamw_update, init_opt_state, schedule
from .steps import (build_prefill_step, build_serve_step, build_train_step,
                    init_train_state, prefill_step, serve_step, train_step,
                    train_state_specs)

__all__ = ["IGNORE", "cross_entropy", "lm_loss", "OptConfig",
           "adamw_update", "init_opt_state", "schedule",
           "build_prefill_step", "build_serve_step", "build_train_step",
           "init_train_state", "prefill_step", "serve_step", "train_step",
           "train_state_specs"]
