"""AdamW with cosine schedule and global-norm clipping.

Memory layout: params in the model dtype (bf16), first/second moments in
f32 (the memory-lean production choice — DESIGN §5).  The update math runs
in f32 and casts back.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(opt: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(opt.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - opt.warmup_steps)
                    / jnp.maximum(opt.total_steps - opt.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = opt.min_lr_frac + (1 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, opt_state, opt: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))

    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + opt.eps)
                           + opt.weight_decay * pf)
        return new_p.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
