"""Int8 error-feedback gradient compression for cross-pod (DCN) sync.

DCN is the scarcest bandwidth in a multi-pod fleet (DESIGN §7).  The
cross-pod gradient exchange is compressed 4× by quantizing each gradient
leaf to int8 with a per-leaf scale and *error feedback* (the quantization
residual is added to the next step's gradient — provably preserves SGD
convergence, Karimireddy et al. 2019).

Wire format per leaf: int8 tensor + f32 scale.  The exchange is an
``all_gather`` of the int8 payload over the ``pod`` axis (true int8 on the
wire) followed by a local dequantized mean — for small pod counts this
moves (P−1)/P · ¼ the bytes of an f32 ring all-reduce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g, err):
    """(int8 payload, f32 scale, new error) with error feedback."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def cross_pod_mean(grads, err_state, axis_name: str = "pod"):
    """Compressed mean over the pod axis (inside shard_map over `pod`).

    grads/err_state: pytrees of per-pod gradients and error buffers.
    Returns (mean grads f32, new error state)."""

    def leaf(g, err):
        q, scale, new_err = quantize(g, err)
        qs = jax.lax.all_gather(q, axis_name)            # (P, ...) int8 wire
        ss = jax.lax.all_gather(scale, axis_name)        # (P,) f32
        deq = qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * (qs.ndim - 1))
        return deq.mean(0).astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_error_state(grads_shape_tree):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape_tree)
