"""Step functions: train, prefill, decode — the jit/lower targets.

``make_*`` builders return (fn, in_shardings, out_shardings, donate) so
the launcher and the dry-run lower identical artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import sharding as shd
from ..models.transformer import decode_step, forward, init_params
from .loss import lm_loss
from .optimizer import OptConfig, adamw_update, init_opt_state


def train_state_specs(cfg, mesh):
    pspec = shd.param_specs(cfg, mesh)
    return {
        "params": pspec,
        "m": pspec,
        "v": pspec,
        "step": P(),
    }


def init_train_state(key, cfg):
    params = init_params(key, cfg)
    opt = init_opt_state(params)
    return {"params": params, "m": opt["m"], "v": opt["v"],
            "step": opt["step"]}


def train_step(state, batch, cfg, opt: OptConfig, constrain=None,
               moe_c=None, grad_constrain=None, microbatches: int = 1,
               grad_sync_dtype=None):
    """Forward + backward + AdamW, with gradient accumulation.

    ``microbatches`` > 1 scans over batch slices accumulating f32 grads —
    the production memory lever: live activations shrink by the microbatch
    factor while the optimizer still sees the full global batch.

    ``grad_constrain`` pins per-microbatch grads to the parameter sharding:
    without it GSPMD all-reduces *unsharded* per-layer grads over every
    batch axis each microbatch (the 450 GB-per-device cross-pod AR the
    jamba dry-run exposed); with it the sync is a reduce-scatter to the
    FSDP layout + a small sharded cross-pod all-reduce."""
    fwd = functools.partial(forward, constrain=constrain, moe_c=moe_c)
    gc = grad_constrain or (lambda g: g)

    def loss_fn(params, mb):
        return lm_loss(params, mb, cfg, fwd)

    params = state["params"]
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads = gc(jax.tree.map(lambda g: g.astype(jnp.float32), grads))
    else:
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = gc(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

        def acc_body(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            if grad_sync_dtype is not None:
                # sync-precision lever (§Perf B2): the per-microbatch
                # reduce-scatter moves bf16; accumulation stays f32
                g = jax.tree.map(
                    lambda x: x.astype(grad_sync_dtype), g)
            g_acc = gc(jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), g_acc, gc(g)))
            return (g_acc, loss_acc + loss), metrics

        (grads, loss_sum), metrics = jax.lax.scan(
            acc_body, (zero, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss = loss_sum / microbatches
        metrics = jax.tree.map(lambda m: m[-1], metrics)

    new_params, new_opt, opt_metrics = adamw_update(
        params, grads, {"m": state["m"], "v": state["v"],
                        "step": state["step"]}, opt)
    new_state = {"params": new_params, "m": new_opt["m"],
                 "v": new_opt["v"], "step": new_opt["step"]}
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return new_state, metrics


def prefill_step(params, batch, cfg, constrain=None, moe_c=None):
    """Prefill forward: last-position logits (serving semantics).  The
    lm_head projection runs on the last position only (§Perf A1) — the
    full (B, 32768, V) logits tensor never exists."""
    logits, _ = forward(params, batch["tokens"], cfg,
                        frontend=batch.get("frontend"),
                        constrain=constrain, moe_c=moe_c,
                        logits_last_only=True)
    return logits


def serve_step(params, token, caches, step_idx, cfg, constrain=None,
               moe_c=None):
    """One-token decode against the cache (decode dry-run cells)."""
    logits, new_caches = decode_step(params, token, caches, step_idx, cfg,
                                     constrain=constrain, moe_c=moe_c)
    next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return next_token[:, None], new_caches


# ------------------------------------------------------------- jit builders
def default_microbatches(cfg, mesh, global_batch: int) -> int:
    """Largest accumulation factor keeping ≥1 example per data shard."""
    n_b = 1
    for a in shd.batch_axes(mesh):
        n_b *= mesh.shape[a]
    target = cfg.train_microbatches or 8
    mb = 1
    while (global_batch % (mb * 2) == 0
           and (global_batch // (mb * 2)) % n_b == 0 and mb < target):
        mb *= 2
    return mb


def build_train_step(cfg, mesh, opt: OptConfig | None = None,
                     donate: bool = True, global_batch: int | None = None,
                     microbatches: int | None = None,
                     grad_sync_dtype=None):
    opt = opt or OptConfig()
    shd.set_flash_mesh(mesh)
    sspec = train_state_specs(cfg, mesh)
    bspec = shd.train_batch_specs(mesh,
                                  has_frontend=cfg.frontend_tokens > 0)
    n_b = 1
    for a in shd.batch_axes(mesh):
        n_b *= mesh.shape[a]
    gb = global_batch or n_b
    if microbatches is None:
        microbatches = default_microbatches(cfg, mesh, gb)
    mb_batch = gb // microbatches if gb % microbatches == 0 else gb
    constrain = shd.activation_constrainer(mesh, mb_batch)
    moe_c = shd.moe_constrainers(cfg, mesh, mb_batch)
    pspec_named = shd.named(mesh, sspec["params"])

    def grad_constrain(g):
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            pspec_named)

    fn = functools.partial(train_step, cfg=cfg, opt=opt,
                           constrain=constrain, moe_c=moe_c,
                           grad_constrain=grad_constrain,
                           microbatches=microbatches,
                           grad_sync_dtype=grad_sync_dtype)
    metrics_spec = {k: P() for k in
                    ("ce", "aux", "tokens", "loss", "grad_norm", "lr")}
    jit_fn = jax.jit(
        fn,
        in_shardings=(shd.named(mesh, sspec), shd.named(mesh, bspec)),
        out_shardings=(shd.named(mesh, sspec),
                       shd.named(mesh, metrics_spec)),
        donate_argnums=(0,) if donate else (),
    )
    return jit_fn, sspec, bspec


def build_prefill_step(cfg, mesh, global_batch: int | None = None):
    shd.set_flash_mesh(mesh)
    pspec = shd.param_specs(cfg, mesh)
    bspec = shd.train_batch_specs(mesh,
                                  has_frontend=cfg.frontend_tokens > 0)
    bspec = {k: v for k, v in bspec.items() if k != "labels"}
    ba = shd.batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    gb = global_batch or n_b
    constrain = shd.activation_constrainer(mesh, gb)
    moe_c = shd.moe_constrainers(cfg, mesh, gb)
    out_spec = P(ba, None, None)
    fn = functools.partial(prefill_step, cfg=cfg, constrain=constrain,
                           moe_c=moe_c)
    jit_fn = jax.jit(fn,
                     in_shardings=(shd.named(mesh, pspec),
                                   shd.named(mesh, bspec)),
                     out_shardings=NamedSharding(mesh, out_spec))
    return jit_fn, pspec, bspec


def build_serve_step(cfg, mesh, batch: int, max_len: int,
                     donate: bool = True):
    seq_shard = batch == 1          # long-context: shard the cache seq dim
    shd.set_flash_mesh(mesh)
    pspec = shd.param_specs(cfg, mesh)
    cspec = shd.cache_specs(cfg, mesh, batch, seq_shard=seq_shard)
    ba = shd.batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    tok_spec = P(ba, None) if batch % n_b == 0 and batch >= n_b else P(None,
                                                                       None)
    constrain = shd.activation_constrainer(mesh, batch)
    moe_c = shd.moe_constrainers(cfg, mesh, batch)
    fn = functools.partial(serve_step, cfg=cfg, constrain=constrain,
                           moe_c=moe_c)
    jit_fn = jax.jit(
        fn,
        in_shardings=(shd.named(mesh, pspec),
                      NamedSharding(mesh, tok_spec),
                      shd.named(mesh, cspec), NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, tok_spec),
                       shd.named(mesh, cspec)),
        donate_argnums=(2,) if donate else (),
    )
    return jit_fn, pspec, cspec, tok_spec
