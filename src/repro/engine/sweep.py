"""The jitted sweep loop and its session wrapper (see package docstring).

``_make_refine`` builds the pure device function — one ``lax.while_loop``
from initial permutation to converged permutation — for one distance form;
:class:`RefinementEngine` wraps it with host glue: DeviceGraph/pair
conversion (cached per graph structure), jit/vmap executables (cached per
shape by jax), eps selection, and :class:`SearchStats` reporting against
host float64 objectives.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from ..core.graph import CommGraph, DeviceGraph, device_pairs
from ..core.local_search import SearchStats
from ..core.objective import qap_objective
from ..runtime.boundary import host_boundary

# Gain/acceptance threshold relative to |J0|: must sit above the f32
# noise of the device objective (~1e-7 · J0 for the edge-sum) while not
# swallowing genuine gains — 1e-6 converges to the same optima as exact
# thresholds on every benchmarked workload (see BENCH_engine.json).
_EPS_REL = 1e-6


def _make_refine(kind: str, params: tuple, max_sweeps: int,
                 use_pallas: bool = False, interpret: bool = False,
                 config=None):
    """The device sweep fn for one distance form.

    Signature: ``(nbr, wgt, eu, ev, ew, us, vs, perm0, D, eps, tenure,
    dlb, collect) -> (perm, trace, sweeps, swaps, tel)`` — all jnp, no
    host syncs inside; the trace is the carried objective after each
    sweep (NaN past convergence).  Monotone in its *result* by
    construction: every sweep
    either applies a greedy maximal matching verified (against the
    recomputed device objective) to beat the best single swap, or falls
    back to the best single pair with its exact incremental gain, and the
    returned permutation is the best one seen.

    ``tenure``/``dlb`` are RUNTIME scalars (int32 / bool) — tabu memory
    and don't-look bits compile into the same executable as the plain
    monotone sweep and are enabled by masking, never by retracing:

      * ``tenure > 0`` — a swapped candidate pair becomes tabu for that
        many sweeps (rejecting the immediate reversal), tabu pairs are
        masked out of selection unless they would beat the best-seen
        objective (aspiration), and when no positive-gain move remains
        the sweep takes the best *non-tabu* move even downhill — the
        robust-tabu-search escape from the local optima the monotone
        matching converges to (Paul, arXiv:1009.4880).  The sweep then
        runs to its budget; the best-seen permutation is returned.
      * ``dlb`` — vertices whose incident candidate pairs all had
        non-positive gain go *cold*; pairs with both endpoints cold are
        skipped until a nearby move (the vertex itself or an ELL
        neighbor) wakes them.  Selection-level only: gains are still
        computed (fixed shapes), cold regions just stop attracting moves.

    With ``tenure == 0`` and ``dlb == False`` every mask is identity and
    the loop is bit-for-bit the pre-tabu monotone sweep (tested).

    ``collect`` is a RUNTIME bool enabling the engine telemetry carries
    (``tel`` — see :mod:`repro.obs.telemetry`): fixed-shape, pass-indexed
    counter arrays (exchanges applied, tabu-masked pairs, aspiration
    fires, matching rounds) plus downhill-escape and pass totals, all
    updated under a ``jnp.where(collect, ...)`` mask.  Same no-retrace
    discipline as the tabu knobs — toggling it shares the one compiled
    executable — and the counters never feed back into the search, so
    the ``(perm, trace, sweeps, swaps)`` outputs are bit-identical with
    collection on, off, or absent (tested).  Off, every counter is zero.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels import pair_gain as pg

    def gains_of(nbr, wgt, perm, us, vs, D):
        if use_pallas:
            return pg.pair_gains_pallas(kind, params, nbr, wgt, perm,
                                        us, vs, D, interpret=interpret,
                                        config=config)
        return pg.pair_gains(kind, params, nbr, wgt, perm, us, vs, D,
                             config=config)

    def refine_fn(nbr, wgt, eu, ev, ew, us, vs, perm0, D, eps,
                  tenure, dlb, collect):
        refine_fn.traces += 1           # host-side: counts (re)traces only
        n = perm0.shape[0]
        p = us.shape[0]
        idx = jnp.arange(p, dtype=jnp.int32)
        oob = jnp.int32(n)                      # scatter-drop index
        tabu_on = tenure > 0
        neg_inf = jnp.float32(-jnp.inf)

        def objective(perm):
            return pg.edge_objective(kind, params, eu, ev, ew, perm, D,
                                     config=config)

        j0 = objective(perm0)
        trace0 = jnp.full((max_sweeps + 1,), jnp.nan,
                          jnp.float32).at[0].set(j0)

        def cond(state):
            return (~state["done"]) & (state["sweeps"] < max_sweeps)

        def body(state):
            perm, j, sweeps = state["perm"], state["j"], state["sweeps"]
            swaps, best_j = state["swaps"], state["best_j"]
            g = gains_of(nbr, wgt, perm, us, vs, D)
            # ---- tabu / don't-look masking (identity when both are off:
            # every `blocked` bit is False and g_m is g, bit-for-bit)
            aspire = (j - g) < best_j - eps     # would beat the best seen
            tabu_active = tabu_on & (state["tabu_until"] > sweeps)
            blocked = tabu_active & ~aspire
            blocked |= dlb & state["cold"][us] & state["cold"][vs]
            # under tabu the fallback may move downhill, so inert padding
            # pairs (u == v, gain 0) must never be "best" — mask them too
            blocked |= tabu_on & (us == vs)
            g_m = jnp.where(blocked, neg_inf, g)
            best = jnp.argmax(g_m)              # first max → lowest index
            gbest = g_m[best]
            any_pos = gbest > eps

            # ---- greedy maximal matching by gain priority: rounds of
            # locally-dominant positive pairs (highest gain at both
            # endpoints, ties → lowest index) until no eligible pair is
            # left — the parallel equivalent of popping a gain-ordered
            # priority queue while skipping used vertices
            pos = g_m > eps

            def match_round(mstate):
                sel, used, rounds = mstate
                elig = pos & ~used[us] & ~used[vs]
                ge = jnp.where(elig, g_m, -jnp.inf)
                vmax = jnp.full((n,), -jnp.inf, jnp.float32)
                vmax = vmax.at[us].max(ge).at[vs].max(ge)
                cand = elig & (ge >= vmax[us]) & (ge >= vmax[vs])
                vmin = jnp.full((n,), p, jnp.int32)
                masked_idx = jnp.where(cand, idx, p)
                vmin = vmin.at[us].min(masked_idx).at[vs].min(masked_idx)
                new = cand & (vmin[us] == idx) & (vmin[vs] == idx)
                used = used.at[jnp.where(new, us, oob)].set(
                    True, mode="drop")
                used = used.at[jnp.where(new, vs, oob)].set(
                    True, mode="drop")
                return sel | new, used, rounds + 1

            def match_cond(mstate):
                sel, used, _ = mstate
                return jnp.any(pos & ~used[us] & ~used[vs] & ~sel)

            sel, _, m_rounds = jax.lax.while_loop(
                match_cond, match_round,
                (jnp.zeros((p,), jnp.bool_), jnp.zeros((n,), jnp.bool_),
                 jnp.int32(0)))

            # ---- apply the matching (each vertex in ≤ 1 selected pair)
            pu, pv = perm[us], perm[vs]
            perm_m = perm.at[jnp.where(sel, us, oob)].set(pv, mode="drop")
            perm_m = perm_m.at[jnp.where(sel, vs, oob)].set(pu, mode="drop")
            j_m = objective(perm_m)             # device O(m) — swaps of a
            take = any_pos & (j_m < j - gbest)  # matching interact, verify

            # ---- fallback: the single best pair, exact incremental gain;
            # under tabu, with no positive gain left, the best *eligible*
            # pair is taken even downhill (the escape move) — padding and
            # fully-blocked states leave gbest at -inf, which ends the loop
            ub, vb = us[best], vs[best]
            perm_f = perm.at[ub].set(perm[vb]).at[vb].set(perm[ub])
            fall_down = tabu_on & ~any_pos & (gbest > neg_inf)
            fall = (any_pos & ~take) | fall_down
            moved = any_pos | fall_down

            perm_n = jnp.where(take, perm_m, jnp.where(fall, perm_f, perm))
            j_n = jnp.where(take, j_m, jnp.where(fall, j - gbest, j))
            swaps_n = swaps + jnp.where(
                take, jnp.sum(sel, dtype=jnp.int32),
                jnp.where(fall, jnp.int32(1), jnp.int32(0)))
            sweeps_n = jnp.where(moved, sweeps + 1, sweeps)
            trace_n = state["trace"].at[sweeps_n].set(j_n)

            # ---- tabu memory: pairs applied this sweep reject their
            # reversal for `tenure` sweeps
            applied = jnp.where(take, sel, (idx == best) & fall)
            tabu_until = jnp.where(applied & tabu_on, sweeps_n + tenure,
                                   state["tabu_until"])

            # ---- don't-look bits: a vertex with no positive incident
            # gain goes cold; a move wakes the endpoints and their ELL
            # neighbors (selection-level masking only — see docstring)
            warm = jnp.zeros((n,), jnp.int32)
            pos_raw = (g > eps).astype(jnp.int32)
            warm = warm.at[us].max(pos_raw).at[vs].max(pos_raw) > 0
            moved_v = jnp.zeros((n,), jnp.bool_)
            moved_v = moved_v.at[jnp.where(applied, us, oob)].set(
                True, mode="drop")
            moved_v = moved_v.at[jnp.where(applied, vs, oob)].set(
                True, mode="drop")
            wake = moved_v | jnp.any(moved_v[nbr] & (wgt > 0), axis=1)
            cold = jnp.where(wake, False, state["cold"] | ~warm)

            # ---- telemetry carries (repro.obs): pass-indexed counters,
            # masked by the runtime `collect` toggle — never read by the
            # search, so the outputs above are bit-identical either way
            pass_idx = sweeps                   # unique per body iteration
            exch = jnp.where(
                take, jnp.sum(sel, dtype=jnp.int32),
                jnp.where(fall, jnp.int32(1), jnp.int32(0)))

            def rec(key, val):
                return jnp.where(collect,
                                 state[key].at[pass_idx].set(val),
                                 state[key])

            tel_on = collect
            # ---- best-seen tracking (with tabu off, j is monotone and
            # best == current, bit-for-bit)
            improved = j_n < state["best_j"]
            return {
                "perm": perm_n, "j": j_n, "trace": trace_n,
                "sweeps": sweeps_n, "swaps": swaps_n, "done": ~moved,
                "best_perm": jnp.where(improved, perm_n,
                                       state["best_perm"]),
                "best_j": jnp.where(improved, j_n, state["best_j"]),
                "tabu_until": tabu_until, "cold": cold,
                "tel_exchanges": rec("tel_exchanges", exch),
                "tel_tabu_masked": rec(
                    "tel_tabu_masked",
                    jnp.sum(tabu_active & ~aspire, dtype=jnp.int32)),
                "tel_aspirations": rec(
                    "tel_aspirations",
                    jnp.sum(tabu_active & aspire, dtype=jnp.int32)),
                "tel_match_rounds": rec("tel_match_rounds", m_rounds),
                "tel_downhill": state["tel_downhill"] + jnp.where(
                    tel_on & fall_down, jnp.int32(1), jnp.int32(0)),
                "tel_passes": state["tel_passes"] + jnp.where(
                    tel_on, jnp.int32(1), jnp.int32(0)),
            }

        tel0 = jnp.zeros((max_sweeps + 1,), jnp.int32)
        state = {
            "perm": perm0, "j": j0, "trace": trace0,
            "sweeps": jnp.int32(0), "swaps": jnp.int32(0),
            "done": jnp.bool_(False), "best_perm": perm0, "best_j": j0,
            "tabu_until": jnp.zeros((p,), jnp.int32),
            "cold": jnp.zeros((n,), jnp.bool_),
            "tel_exchanges": tel0, "tel_tabu_masked": tel0,
            "tel_aspirations": tel0, "tel_match_rounds": tel0,
            "tel_downhill": jnp.int32(0), "tel_passes": jnp.int32(0),
        }
        out = jax.lax.while_loop(cond, body, state)
        tel = {
            "exchanges": out["tel_exchanges"],
            "tabu_masked": out["tel_tabu_masked"],
            "aspirations": out["tel_aspirations"],
            "match_rounds": out["tel_match_rounds"],
            "downhill_escapes": out["tel_downhill"],
            "passes": out["tel_passes"],
            "sweeps": out["sweeps"],
        }
        return (out["best_perm"], out["trace"], out["sweeps"],
                out["swaps"], tel)

    refine_fn.traces = 0
    return refine_fn


@dataclass
class EngineResult:
    """One device refinement: the final permutation plus host-facing
    stats (objectives in host float64; the trace is the device f32
    carry, one entry per applied sweep)."""
    perm: np.ndarray
    stats: SearchStats
    sweeps: int


class RefinementEngine:
    """Compiled sweep-loop executables for one machine topology.

    One instance per (``kernel_params()``, ``max_sweeps``,
    ``kernel_config``) — the Mapper keys its engine cache exactly so.
    jax re-specializes the jitted fn per array shape;
    :class:`DeviceGraph`/pair padding buckets shapes so same-shape graphs
    share one executable.  ``use_pallas`` routes the gain reduction
    through the hand-tiled Pallas kernel (default: only on real TPU
    backends; the fused-jnp path is best everywhere else).

    ``kernel_config`` (a :class:`~repro.kernels.config.KernelConfig`,
    normally derived at ``Mapper.lower`` time) fixes the tile geometry
    baked into the compiled sweep and, for matrix-form topologies with a
    ``dist_dtype``, stores the distance table in its lossless int8/int16
    packing — results bit-identical, gather bandwidth 4–8× lower.
    """

    def __init__(self, topology, max_sweeps: int = 64,
                 eps_rel: float = _EPS_REL, use_pallas: bool | None = None,
                 interpret: bool | None = None,
                 cache_caps: dict | None = None,
                 kernel_config=None):
        import jax
        import jax.numpy as jnp
        kp = topology.kernel_params()
        self.topology = topology
        self.kind = kp[0]
        self.max_sweeps = int(max_sweeps)
        self.eps_rel = float(eps_rel)
        self.kernel_config = kernel_config
        on_tpu = jax.default_backend() == "tpu"
        self.use_pallas = on_tpu if use_pallas is None else bool(use_pallas)
        self.interpret = (not on_tpu) if interpret is None \
            else bool(interpret)
        interpret = self.interpret
        if self.kind == "matrix":
            params = ()
            dist_dtype = getattr(kernel_config, "dist_dtype", None)
            if dist_dtype is not None:
                from ..kernels.config import quantize_table
                packed, _ = quantize_table(topology.matrix(), dist_dtype)
                self._D = jnp.asarray(packed)
            else:
                self._D = jnp.asarray(topology.matrix(), jnp.float32)
        else:
            params = kp[1:]
            self._D = jnp.zeros((1, 1), jnp.float32)    # ignored dummy
        self.params = params
        fn = _make_refine(self.kind, params, self.max_sweeps,
                          use_pallas=self.use_pallas, interpret=interpret,
                          config=kernel_config)
        self._refine_fn = fn            # raw sweep fn (fn.traces counts
        self._refine = jax.jit(fn)      # retraces — the tabu-masking
        # regression check asserts toggling tenure/dlb adds none)
        self._vrefine = jax.jit(jax.vmap(
            fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None, 0, None, None,
                         None)))
        # lane axis: ONE graph shared across a portfolio's restart lanes
        # (in_axes=None for every graph/pair array — no per-lane copies)
        self._lrefine = jax.jit(jax.vmap(
            fn, in_axes=(None, None, None, None, None, None, None, 0,
                         None, 0, None, None, None)))
        # internal LRU caps: session-level `cache_caps` plumbing (Mapper
        # passes {"graphs": ..., "pairs": ...}); evictions surface in
        # cache_info()
        self._caps = {"graphs": 16, "pairs": 16}
        if cache_caps:
            unknown = sorted(set(cache_caps) - set(self._caps))
            if unknown:
                raise ValueError(f"unknown engine cache_caps keys "
                                 f"{unknown}; known: "
                                 f"{sorted(self._caps)}")
            self._caps.update({k: int(v) for k, v in cache_caps.items()})
        self._evictions = {"graphs": 0, "pairs": 0}
        # device uploads keyed by full array content (LRU): graph ELL/edge
        # arrays and candidate-pair arrays — long-lived serve() sessions
        # re-map the same structures, and the pair arrays alone can reach
        # ~32 MB (max_pairs entries), so neither re-transfers per request
        self._dg_cache: "OrderedDict[tuple, DeviceGraph]" = OrderedDict()
        self._pair_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        # bucketed pair-length high-water marks, one per bucket shape:
        # under a ShapeBucket with dynamic P, never shrink the padded
        # pair shape below one already compiled for that (K, E) — mixed
        # candidate sets then reuse the existing executable instead of
        # recompiling.  Scoped per bucket because executables are
        # (K, E, P)-specialized anyway: engines are shared across a
        # session's plans, and one bucket's huge pair set must not
        # inflate every other bucket's padding (inert but not free).
        self._p_hwm: dict = {}

    # ------------------------------------------------------------- host glue
    def _lru_get(self, cache: OrderedDict, key: tuple, build, cap: str):
        """Bounded fetch-or-build against ``self._caps[cap]`` (the
        session-level ``cache_caps`` plumbing); drops surface as
        ``cache_info()[f"{cap[:-1]}_evictions"]``."""
        val = cache.get(key)
        if val is None:
            val = build()
            cache[key] = val
            if len(cache) > self._caps[cap]:
                cache.popitem(last=False)
                self._evictions[cap] += 1
        else:
            cache.move_to_end(key)
        return val

    def cache_info(self) -> dict:
        """Device-upload cache accounting: live entry counts plus the
        evictions forced by the ``cache_caps`` bounds."""
        return {
            "graph_entries": len(self._dg_cache),
            "graph_evictions": self._evictions["graphs"],
            "pair_entries": len(self._pair_cache),
            "pair_evictions": self._evictions["pairs"],
        }

    def trace_count(self) -> int:
        """How many times the sweep fn has been (re)traced — the
        tabu-masking regression check asserts this stays flat when
        ``tabu_tenure``/``dlb`` toggle at runtime."""
        return self._refine_fn.traces

    def _device_graph(self, g: CommGraph, k: int | None = None,
                      e: int | None = None) -> DeviceGraph:
        """Cached device upload of a graph, optionally re-padded into a
        plan bucket's (K, E) — padding is inert, so only the executable
        shape changes, never the result."""
        key = (g.n, hash(g.xadj.tobytes()), hash(g.adjncy.tobytes()),
               hash(np.asarray(g.adjwgt).tobytes()), k, e)

        def build():
            dg = DeviceGraph.from_comm(g)
            if k is not None or e is not None:
                dg = dg.pad_to(k if k is not None else dg.max_deg,
                               e if e is not None else dg.eu.shape[0])
            return dg

        return self._lru_get(self._dg_cache, key, build, "graphs")

    def _device_pairs(self, pairs: np.ndarray, pad_to: int = 128) -> tuple:
        pairs = np.asarray(pairs)
        key = (pad_to, pairs.shape[0], hash(pairs.tobytes()))
        return self._lru_get(self._pair_cache, key,
                             lambda: device_pairs(pairs, pad_to=pad_to),
                             "pairs")

    def _bucket_p(self, bucket, n_pairs: int) -> int:
        key = (bucket.max_deg, bucket.num_edges, bucket.num_pairs,
               bucket.schedule)
        p = max(bucket.pair_pad(n_pairs), self._p_hwm.get(key, 0))
        self._p_hwm[key] = p
        return p

    def _eps(self, j0: float) -> float:
        return self.eps_rel * max(1.0, abs(j0))

    def _stats(self, g: CommGraph, perm: np.ndarray, j0: float,
               trace: np.ndarray, sweeps: int, swaps: int,
               n_pairs: int, telemetry=None) -> SearchStats:
        stats = SearchStats()
        stats.initial_objective = j0
        stats.final_objective = qap_objective(g, self.topology, perm)
        stats.swaps = int(swaps)
        # gain passes actually run: one per applied sweep, plus the final
        # pass that found no positive gain when the loop converged before
        # the budget — same accounting as parallel_sweep_search
        passes = int(sweeps) + (1 if int(sweeps) < self.max_sweeps else 0)
        stats.evaluated = passes * n_pairs
        stats.objective_trace = [float(x) for x in trace[:int(sweeps) + 1]]
        if telemetry is not None:
            from ..obs.telemetry import EngineTelemetry
            stats.telemetry = EngineTelemetry.from_device(telemetry, trace)
        return stats

    @staticmethod
    def _tel_slice(tel, i=None) -> dict:
        """Host numpy view of one device telemetry pytree (lane/batch
        index ``i`` under vmap) — rides the transfer the perm/trace
        outputs already paid."""
        return {k: np.asarray(v if i is None else v[i])
                for k, v in tel.items()}

    @staticmethod
    def _toggles(tabu_tenure: int, dlb: bool, telemetry: bool = False
                 ) -> tuple:
        """Runtime tabu/don't-look/telemetry scalars as jnp arrays —
        value changes never retrace the compiled executables (masking,
        not retracing)."""
        import jax.numpy as jnp
        with host_boundary("engine.toggles"):
            return jnp.int32(tabu_tenure), jnp.bool_(dlb), \
                jnp.bool_(telemetry)

    # ------------------------------------------------------------------ API
    def refine(self, g: CommGraph, perm: np.ndarray, pairs: np.ndarray,
               j0: float | None = None, bucket=None,
               tabu_tenure: int = 0, dlb: bool = False,
               telemetry: bool = False) -> SearchStats:
        """Refine ``perm`` in place over the candidate ``pairs`` — the
        device counterpart of ``parallel_sweep_search`` (one device
        dispatch, no host syncs until convergence).  ``j0`` is the
        caller's already-computed objective of ``perm`` (used for eps
        scaling and the reported initial objective); omitted, it is
        recomputed on host.  ``bucket`` (a
        :class:`~repro.core.spec.ShapeBucket`) pads the device arrays to
        the plan's fixed shapes so every same-bucket request reuses one
        compiled executable — inert, results unchanged.
        ``tabu_tenure``/``dlb`` enable the tabu memory and don't-look
        bits (see :func:`_make_refine`) — runtime toggles sharing the one
        executable; the defaults are bit-for-bit the pre-tabu sweep.
        ``telemetry`` enables the engine counter carries (same runtime
        discipline) and attaches an
        :class:`~repro.obs.telemetry.EngineTelemetry` to the stats."""
        import jax.numpy as jnp
        if j0 is None:
            j0 = qap_objective(g, self.topology, perm)
        if len(pairs) == 0:
            stats = SearchStats()
            stats.initial_objective = stats.final_objective = j0
            stats.objective_trace = [j0]
            if telemetry:
                from ..obs.telemetry import EngineTelemetry
                stats.telemetry = EngineTelemetry(
                    objective_trace=np.asarray([j0]))
            return stats
        if bucket is not None:
            dg = self._device_graph(g, k=bucket.max_deg,
                                    e=bucket.num_edges)
            us, vs = self._device_pairs(pairs,
                                        pad_to=self._bucket_p(
                                            bucket, len(pairs)))
        else:
            dg = self._device_graph(g)
            us, vs = self._device_pairs(pairs)
        tenure, dlb_, tel_ = self._toggles(tabu_tenure, dlb, telemetry)
        with host_boundary("engine.dispatch"):
            out_perm, trace, sweeps, swaps, tel = self._refine(
                dg.nbr, dg.wgt, dg.eu, dg.ev, dg.ew, us, vs,
                jnp.asarray(perm, jnp.int32), self._D,
                jnp.float32(self._eps(j0)), tenure, dlb_, tel_)
        with host_boundary("engine.readback"):
            perm[:] = np.asarray(out_perm, dtype=perm.dtype)
            return self._stats(g, perm, j0, np.asarray(trace),
                               int(sweeps), int(swaps), len(pairs),
                               telemetry=self._tel_slice(tel)
                               if telemetry else None)

    def refine_batch(self, graphs, perms, pairs_list,
                     j0s=None, bucket=None, tabu_tenure: int = 0,
                     dlb: bool = False,
                     telemetry: bool = False) -> list[SearchStats]:
        """One vmapped device call over a batch of same-shape graphs.

        Per-graph arrays are padded to the batch's common (K, E, P)
        maxima — or, given a ``bucket``, to the plan's fixed shapes —
        inert by the DeviceGraph/pair padding invariants, so each result
        matches the corresponding single :meth:`refine`.  ``j0s`` are the
        callers' already-computed initial objectives (recomputed on host
        when omitted).
        """
        import jax.numpy as jnp
        graphs = list(graphs)
        if not graphs:
            return []
        if j0s is None:
            j0s = [qap_objective(g, self.topology, p)
                   for g, p in zip(graphs, perms)]
        p_raw = max(max((len(p) for p in pairs_list), default=1), 1)
        if bucket is not None:
            k_max, e_max = bucket.max_deg, bucket.num_edges
            p_max = self._bucket_p(bucket, p_raw)
            dgs = [self._device_graph(g, k=k_max, e=e_max) for g in graphs]
        else:
            dgs = [self._device_graph(g) for g in graphs]
            k_max = max(dg.max_deg for dg in dgs)
            e_max = max(dg.eu.shape[0] for dg in dgs)
            p_max = -(-p_raw // 128) * 128      # same bucketing as refine()
            dgs = [dg.pad_to(k_max, e_max) for dg in dgs]
        dev_pairs = [self._device_pairs(p, pad_to=p_max)
                     for p in pairs_list]
        tenure, dlb_, tel_ = self._toggles(tabu_tenure, dlb, telemetry)
        stack = lambda xs: jnp.stack(xs)                      # noqa: E731
        with host_boundary("engine.dispatch"):
            out_perm, trace, sweeps, swaps, tel = self._vrefine(
                stack([dg.nbr for dg in dgs]),
                stack([dg.wgt for dg in dgs]),
                stack([dg.eu for dg in dgs]),
                stack([dg.ev for dg in dgs]),
                stack([dg.ew for dg in dgs]),
                stack([u for u, _ in dev_pairs]),
                stack([v for _, v in dev_pairs]),
                stack([jnp.asarray(p, jnp.int32) for p in perms]),
                self._D,
                jnp.asarray([self._eps(j) for j in j0s], jnp.float32),
                tenure, dlb_, tel_)
        out = []
        with host_boundary("engine.readback"):
            for i, (g, perm) in enumerate(zip(graphs, perms)):
                perm[:] = np.asarray(out_perm[i], dtype=perm.dtype)
                out.append(self._stats(
                    g, perm, j0s[i], np.asarray(trace[i]),
                    int(sweeps[i]), int(swaps[i]), len(pairs_list[i]),
                    telemetry=self._tel_slice(tel, i)
                    if telemetry else None))
        return out

    def refine_lanes(self, g: CommGraph, perms, pairs: np.ndarray,
                     j0s=None, bucket=None, tabu_tenure: int = 0,
                     dlb: bool = False,
                     telemetry: bool = False) -> list[SearchStats]:
        """One vmapped device call over L restart *lanes* of ONE graph —
        the portfolio counterpart of :meth:`refine_batch`: the graph and
        candidate-pair arrays are shared across lanes (``in_axes=None``,
        no per-lane copies), only the permutations and eps thresholds
        carry a lane axis.  Each lane's result equals a single
        :meth:`refine` of that lane's permutation (tested)."""
        import jax.numpy as jnp
        perms = list(perms)
        if not perms:
            return []
        if j0s is None:
            j0s = [qap_objective(g, self.topology, p) for p in perms]
        if len(pairs) == 0:
            out = []
            for perm, j0 in zip(perms, j0s):
                stats = SearchStats()
                stats.initial_objective = stats.final_objective = j0
                stats.objective_trace = [j0]
                out.append(stats)
            return out
        if bucket is not None:
            dg = self._device_graph(g, k=bucket.max_deg,
                                    e=bucket.num_edges)
            us, vs = self._device_pairs(pairs,
                                        pad_to=self._bucket_p(
                                            bucket, len(pairs)))
        else:
            dg = self._device_graph(g)
            us, vs = self._device_pairs(pairs)
        tenure, dlb_, tel_ = self._toggles(tabu_tenure, dlb, telemetry)
        with host_boundary("engine.dispatch"):
            out_perm, trace, sweeps, swaps, tel = self._lrefine(
                dg.nbr, dg.wgt, dg.eu, dg.ev, dg.ew, us, vs,
                jnp.stack([jnp.asarray(p, jnp.int32) for p in perms]),
                self._D,
                jnp.asarray([self._eps(j) for j in j0s], jnp.float32),
                tenure, dlb_, tel_)
        out = []
        with host_boundary("engine.readback"):
            for i, perm in enumerate(perms):
                perm[:] = np.asarray(out_perm[i], dtype=perm.dtype)
                out.append(self._stats(
                    g, perm, j0s[i], np.asarray(trace[i]),
                    int(sweeps[i]), int(swaps[i]), len(pairs),
                    telemetry=self._tel_slice(tel, i)
                    if telemetry else None))
        return out


def refine(machine, g: CommGraph, perm: np.ndarray, pairs: np.ndarray,
           max_sweeps: int = 64, **kw) -> EngineResult:
    """One-shot convenience: build a :class:`RefinementEngine` over
    ``machine`` (Hierarchy or any Topology) and refine ``perm`` in place.
    Sessions should hold a ``Mapper`` (which caches engines) instead."""
    from ..topology.base import as_topology
    eng = RefinementEngine(as_topology(machine), max_sweeps=max_sweeps, **kw)
    stats = eng.refine(g, perm, pairs)
    return EngineResult(perm=perm, stats=stats,
                        sweeps=max(len(stats.objective_trace) - 1, 0))
