"""Device-resident refinement engine: one jitted sweep loop.

The guide's central speedup is the cheap incremental gain during
pair-exchange local search (§2.1).  The host drivers in
:mod:`repro.core.local_search` realize it as Python loops — every
candidate gain, every verification, every swap syncs through the host.
This package moves the *whole sweep loop* onto the device: graph,
permutation, candidate pairs, gains, conflict resolution, and the
objective all live in device arrays inside a single ``lax.while_loop``,
and nothing returns to the host until the search has converged (or hit
its sweep budget).  Following the sparse-gain formulation of Schulz &
Träff (arXiv:1702.04164) and the delta-table style of Paul's robust tabu
search for sparse QAP (arXiv:1009.4880), one sweep is:

  1. **Gains** — the sparse O(deg) gain of every candidate pair at once,
     via :mod:`repro.kernels.pair_gain` over the :class:`DeviceGraph`'s
     padded ELL neighbor rows, using the machine topology's
     ``kernel_params()`` distance form (in-register tree/torus closed
     forms, or gathers against an explicit D).
  2. **Conflict resolution** — simultaneous swaps may share endpoints, so
     a greedy *maximal* matching selects, by gain priority, a set of
     positive-gain pairs in which each process appears at most once:
     rounds of locally-dominant pairs (highest gain among all eligible
     candidates touching either endpoint, ties broken toward the lowest
     pair index) with the matched vertices masked out between rounds,
     until no eligible pair remains — the parallel equivalent of popping
     a gain-ordered priority queue while skipping used vertices, realized
     as scatter-max/scatter-min over the endpoint arrays inside a nested
     ``while_loop``.  The globally best pair is always matched, so
     progress is guaranteed.
  3. **Apply + objective update** — the matching's swaps are applied with
     one dual scatter, and the objective of the tentative permutation is
     recomputed on device from the edge arrays (O(m), the same order as
     the gain pass).  Disjoint swaps still *interact* (their processes
     may communicate or share PE-adjacency), so the batch is accepted
     only if the recomputed objective beats the best *single* swap;
     otherwise the sweep falls back to applying just that best pair,
     whose gain is exact in isolation, and updates the objective
     incrementally (J ← J − gain).  Every sweep therefore drops the
     carried objective by more than max(eps, best-gain − eps) — the
     engine is monotone *by construction*, never does worse than
     steepest descent per sweep, and terminates (objective bounded
     below).  On the mesh-collective benchmark this lands 12–22% *below*
     the host greedy driver's final objectives (BENCH_engine.json).

The host drivers remain the semantic reference: the engine reaches a
local optimum of exactly the same candidate neighborhood (no pair with
gain > eps remains), which the parity tests check against
``parallel_sweep_search`` on every topology backend.

Batching: the whole sweep fn is shape-polymorphic and ``vmap``-able.
``Mapper.map_many`` pads same-shape graphs to common (K, E, P) maxima —
all three paddings are inert by construction (zero-weight neighbor slots,
zero-weight edges, u == v pairs) — and runs the batch through one
vmapped engine call instead of a Python loop.

Select it per request with ``MappingSpec(engine="device")`` or
``viem --engine=device``; ``engine="host"`` (the default) keeps the
reference numpy drivers.
"""

from .sweep import EngineResult, RefinementEngine, refine

__all__ = ["EngineResult", "RefinementEngine", "refine"]
