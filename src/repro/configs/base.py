"""Model/config schema shared by all assigned architectures.

Every architecture file in this package defines ``CONFIG`` (the exact
published configuration) and ``smoke_config()`` (a reduced same-family
variant for CPU tests).  Shapes (``SHAPES``) are global; ``input_specs``
builds ShapeDtypeStruct stand-ins per (config, shape) for the dry-run.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // n_heads
    mlp_type: str = "swiglu"       # swiglu | gelu
    use_rope: bool = True
    rope_theta: float = 1e4
    sliding_window: int = 0        # 0 → full attention
    # --- MoE
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_every: int = 1             # every k-th layer uses MoE FFN
    capacity_factor: float = 1.25
    # --- layer pattern
    mixer: str = "attn"            # attn | mamba | rwkv
    attn_every: int = 0            # hybrid: every k-th layer is attention
    # --- mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0         # 0 → ceil(d_model/16)
    # --- rwkv
    rwkv_head_size: int = 64
    # --- compute policy
    dtype: str = "bfloat16"
    remat: str = "full"            # none | dots | full
    train_microbatches: int = 0    # 0 = auto; capped to batch divisibility
    time_chunk: int = 64           # ssm/rwkv chunked-scan length
    q_block: int = 512             # flash-attention query block
    kv_block: int = 1024           # flash-attention kv block
    # --- modality stub (audio/vlm): leading frames come in as embeddings
    frontend_tokens: int = 0       # e.g. image patches / audio frames

    pad_heads_to: int = 0          # pad Q heads to a multiple (TP fix for
                                   # head counts that don't divide the mesh;
                                   # padded heads have zero output rows —
                                   # mathematically inert, §Perf A2)
    use_flash_kernel: bool = False  # Pallas fused attention (§Perf A3)

    # ------------------------------------------------------------- derived
    @property
    def n_heads_eff(self) -> int:
        """Padded head count.  Padding happens *within* each KV group (the
        head→KV mapping of real heads is unchanged; padded heads share a
        real KV head and have zero wo rows → exactly inert)."""
        if not self.pad_heads_to or self.n_heads % self.pad_heads_to == 0:
            return self.n_heads
        kv = self.n_kv_heads
        g = self.n_heads // kv
        for g_eff in range(g, g + self.pad_heads_to + 1):
            if (kv * g_eff) % self.pad_heads_to == 0:
                return kv * g_eff
        return self.n_heads

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank_(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (TP divisibility + MXU tiles)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def moe_ep_split(self) -> int:
        """Virtual-expert split so E·s equals the production data axis (16):
        mixtral (E=8) → 2, jamba (E=16) → 1.  Exact math — SwiGLU splits
        elementwise over F (models/moe.py)."""
        if not self.moe_experts:
            return 1
        e, axis = self.moe_experts, 16
        if e < axis and axis % e == 0 and self.d_ff % (axis // e) == 0:
            return axis // e
        return 1

    @property
    def period(self) -> int:
        """Scan period: smallest layer group that repeats verbatim."""
        p = 1
        if self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.moe_experts and self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={self.period}")
        return self.n_layers // self.period

    def layer_kind(self, layer_idx: int) -> tuple[str, str]:
        """(mixer, ffn) for a global layer index."""
        if self.mixer == "attn":
            mixer = "attn"
        elif self.attn_every:
            # hybrid (Jamba): attention at position attn_every//2 of each
            # period, SSM elsewhere (1:7 interleave for attn_every=8)
            mixer = "attn" if (layer_idx % self.attn_every
                               == self.attn_every // 2) else self.mixer
        else:
            mixer = self.mixer
        if self.mixer == "rwkv":
            ffn = "channelmix"
        elif self.moe_experts and (layer_idx % self.moe_every
                                   == self.moe_every - 1):
            ffn = "moe"
        else:
            ffn = "mlp"
        return mixer, ffn

    def period_kinds(self) -> list[tuple[str, str]]:
        return [self.layer_kind(i) for i in range(self.period)]

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    # ----------------------------------------------------------- counting
    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim_
        total = 2 * self.padded_vocab * d          # embed + lm_head
        for i in range(self.n_layers):
            mixer, ffn = self.layer_kind(i)
            if mixer == "attn":
                total += d * self.n_heads * hd        # q
                total += 2 * d * self.n_kv_heads * hd  # k, v
                total += self.n_heads * hd * d        # o
            elif mixer == "mamba":
                di, ds, dr = self.d_inner, self.mamba_d_state, self.dt_rank_
                total += d * 2 * di + di * self.mamba_d_conv
                total += di * (dr + 2 * ds) + dr * di + di * ds + di
                total += di * d
            elif mixer == "rwkv":
                total += 5 * d * d + d * d            # r,k,v,g,o + decay/first misc
            if ffn == "mlp":
                mult = 3 if self.mlp_type == "swiglu" else 2
                total += mult * d * self.d_ff
            elif ffn == "moe":
                total += d * self.moe_experts
                total += self.moe_experts * 3 * d * self.d_ff
            elif ffn == "channelmix":
                total += 2 * d * self.d_ff + d * d
            total += 2 * d                            # norms
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of E experts)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        moe_layers = sum(1 for i in range(self.n_layers)
                         if self.layer_kind(i)[1] == "moe")
        inactive = (self.moe_experts - self.moe_top_k) * 3 * d * self.d_ff
        return self.param_count() - moe_layers * inactive

    def model_flops_per_token(self, kind: str = "train") -> float:
        """Analytic MODEL_FLOPS: 6·N_active per token for training,
        2·N_active for inference forward."""
        mult = 6.0 if kind == "train" else 2.0
        return mult * self.active_param_count()


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving smoke-test reduction."""
    smoke_experts = 4 if cfg.moe_experts else 0
    base = dict(
        n_layers=cfg.period * 2 if cfg.period > 1 else 2,
        d_model=128,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=256, vocab_size=512, head_dim=32,
        moe_experts=smoke_experts,
        # Smoke configs are drop-free: with the production capacity factor
        # (1.25) the MoE capacity cutoff makes every token's kept/dropped
        # status depend on how *earlier* tokens routed, which breaks the
        # locality the receptive-field tests assert.  capacity_factor == E
        # gives cap == top_k·T — no drops, routing stays token-local.
        capacity_factor=float(smoke_experts) if smoke_experts
        else cfg.capacity_factor,
        time_chunk=16, q_block=64, kv_block=64,
        sliding_window=64 if cfg.sliding_window else 0,
        frontend_tokens=4 if cfg.frontend_tokens else 0,
        mamba_d_state=8, rwkv_head_size=32,
    )
    base.update(overrides)
    return replace(cfg, **base)
