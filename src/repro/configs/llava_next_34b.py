"""llava-next-34b [vlm] — Yi-34B-class backbone, anyres patch tiling.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision tower
is a STUB: ``input_specs`` provides 2880 precomputed anyres patch
embeddings (5 tiles × 576) prepended to the token stream (DESIGN §6).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, head_dim=128,
    mlp_type="swiglu", use_rope=True, rope_theta=5e6,
    frontend_tokens=2880,
)


def smoke_config():
    return reduced(CONFIG)
