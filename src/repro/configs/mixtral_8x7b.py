"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
[arXiv:2401.04088; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128,
    mlp_type="swiglu", use_rope=True, rope_theta=1e6,
    sliding_window=4096,
    moe_experts=8, moe_top_k=2, moe_every=1,
)


def smoke_config():
    return reduced(CONFIG)
