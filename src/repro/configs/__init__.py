"""Assigned architecture registry: ``get_config(arch)`` / ``ARCHS``."""

from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeCell, reduced

ARCHS = [
    "jamba-v0.1-52b",
    "mixtral-8x22b",
    "mixtral-8x7b",
    "musicgen-medium",
    "starcoder2-7b",
    "granite-3-2b",
    "stablelm-1.6b",
    "granite-3-8b",
    "rwkv6-3b",
    "llava-next-34b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.smoke_config()


def supports_shape(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN §6)."""
    if shape_name != "long_500k":
        return True, ""
    sub_quadratic = (cfg.mixer in ("mamba", "rwkv") or cfg.attn_every > 0
                     or cfg.sliding_window > 0)
    if not sub_quadratic:
        return False, ("skipped: pure full attention — 524288-token KV "
                       "cache/prefill is O(S²) without windowing")
    return True, ""


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeCell", "get_config",
           "get_smoke_config", "reduced", "supports_shape"]
