"""starcoder2-7b [dense] — GQA, RoPE, sliding-window attention (4096).

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
[arXiv:2402.19173; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab_size=49152, head_dim=128,
    mlp_type="gelu", use_rope=True, rope_theta=1e5,
    sliding_window=4096,
)


def smoke_config():
    return reduced(CONFIG, n_kv_heads=2)
