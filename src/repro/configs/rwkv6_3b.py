"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536, head size 64 (40 heads).
[arXiv:2404.05892; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab_size=65536, head_dim=64,
    mixer="rwkv", rwkv_head_size=64, use_rope=False,
    time_chunk=32,
)


def smoke_config():
    return reduced(CONFIG, d_model=128, rwkv_head_size=32)
