"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.  The EnCodec
frontend is a STUB: ``input_specs`` provides 256 precomputed frame
embeddings prepended to the token sequence (DESIGN §6).
[arXiv:2306.05284; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, head_dim=64,
    mlp_type="gelu", use_rope=False,   # sinusoidal in paper; stub w/o pos
    frontend_tokens=256,
)


def smoke_config():
    return reduced(CONFIG, n_kv_heads=4)
