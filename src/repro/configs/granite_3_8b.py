"""granite-3-8b [dense] — GQA.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 (padded →49408).
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
    vocab_size=49155, head_dim=128,
    mlp_type="swiglu", use_rope=True, rope_theta=1e4,
)


def smoke_config():
    return reduced(CONFIG)
