"""stablelm-1.6b [dense] — MHA (kv=32).

24L d_model=2048 32H d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab_size=100352, head_dim=64,
    mlp_type="swiglu", use_rope=True, rope_theta=1e4,
)


def smoke_config():
    return reduced(CONFIG, n_kv_heads=4)
