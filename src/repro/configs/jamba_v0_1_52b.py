"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts
top-2 every other layer; attention every 8th layer, no RoPE.
[arXiv:2403.19887; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    mlp_type="swiglu", use_rope=False,
    mixer="mamba", attn_every=8,
    moe_experts=16, moe_top_k=2, moe_every=2,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)


def smoke_config():
    return reduced(CONFIG, n_layers=8, moe_experts=4)
