"""granite-3-2b [dense] — GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155 (padded →49408).
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from .base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=49155, head_dim=64,
    mlp_type="swiglu", use_rope=True, rope_theta=1e4,
)


def smoke_config():
    return reduced(CONFIG)
