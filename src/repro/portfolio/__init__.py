"""Device-side portfolio search: vmapped multistart trajectories with
tabu memory, perturbation kicks, and tournament selection.

VieM's quality comes from restarting construction + refinement and
keeping the best result; this package spends idle accelerator lanes on
exactly that.  A :class:`PortfolioRunner` runs L restart *lanes* of the
refinement pipeline as ONE vmapped engine call per level (the graph and
candidate-pair arrays are shared across lanes — only the permutations
carry a lane axis), then iterates perturb → refine rounds entirely on
device: a ``lax.while_loop`` that kicks every lane
(:mod:`.kicks` — random segment reversal or swap storms), re-refines,
and tournament-selects the incumbent, stopping on stagnation or the
round budget.  Tabu tenure and don't-look bits
(:mod:`repro.engine.sweep`) let lanes walk downhill out of the local
optima the monotone matching converges to (Paul, arXiv:1009.4880);
Schulz & Träff (arXiv:1702.04164) report the multistart-portfolio
effect on mapping quality that motivates the lane axis.

Configured by :class:`repro.core.spec.PortfolioSpec` inside a
``MappingSpec``; lowered into :class:`repro.core.plan.MappingPlan` and
exposed per request through ``MappingService`` quality classes.
"""

from .kicks import make_kick
from .search import PortfolioRunner, RoundsResult

__all__ = ["make_kick", "PortfolioRunner", "RoundsResult"]
