"""The portfolio round loop and its host-side runner.

``_make_rounds`` builds the pure device function: starting from L
already-refined lane permutations, a ``lax.while_loop`` over rounds
where the worse half of the population adopts the incumbent, every lane
is perturbed (:mod:`.kicks`), every lane re-refines (the engine's sweep
fn vmapped over the lane axis — graph and pair arrays shared, no
per-lane copies), and the incumbent is tournament-selected as the
device-side argmin of the lane objectives.  The loop stops on the round
budget or after ``stagnation`` rounds without improving the incumbent —
no host syncs between rounds.

:class:`PortfolioRunner` is the host glue a
:class:`~repro.core.plan.MappingPlan` lowers once per spec: per-lane
registered constructions (cycled across lanes, per-lane seeds), the
engine's cached device uploads, and the jitted rounds executable (one
per shape bucket, compiled lazily by jax like every other engine
executable).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.graph import CommGraph
from ..core.local_search import SearchStats
from ..engine.sweep import RefinementEngine, _make_refine
from ..runtime.boundary import host_boundary


def _make_rounds(kind: str, params: tuple, max_sweeps: int, lanes: int,
                 rounds: int, kick_frac: float, stagnation: int,
                 use_pallas: bool = False, interpret: bool = False):
    """The device round loop for one distance form and lane geometry.

    Signature: ``(nbr, wgt, eu, ev, ew, us, vs, perms, D, epss, tenure,
    dlb, key) -> (inc_perm, inc_j, round_js, rounds_done, sweeps,
    swaps)`` where ``perms`` is the (L, n) stack of *round-0 refined*
    lane permutations.  ``lanes``/``rounds``/``kick_frac``/``stagnation``
    are compile-time (they fix shapes and trip counts); ``tenure``/
    ``dlb`` stay runtime scalars exactly as in the refine fn.
    ``round_js`` is the incumbent objective after each round (NaN past
    the stop), ``rounds_done`` counts executed rounds including round 0.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels import pair_gain as pg
    from .kicks import make_kick

    refine = _make_refine(kind, params, max_sweeps,
                          use_pallas=use_pallas, interpret=interpret)
    vrefine = jax.vmap(refine, in_axes=(None, None, None, None, None,
                                        None, None, 0, None, 0, None,
                                        None, None))
    half = (lanes + 1) // 2                 # lanes=1 → nobody adopts

    def rounds_fn(nbr, wgt, eu, ev, ew, us, vs, perms, D, epss,
                  tenure, dlb, key):
        n = perms.shape[1]
        kick = make_kick(n, kick_frac)
        vkick = jax.vmap(kick)

        def vobj(ps):
            return jax.vmap(
                lambda p: pg.edge_objective(kind, params, eu, ev, ew,
                                            p, D))(ps)

        js0 = vobj(perms)
        b0 = jnp.argmin(js0)
        trace0 = jnp.full((rounds,), jnp.nan,
                          jnp.float32).at[0].set(js0[b0])
        state = {
            "perms": perms, "js": js0,
            "inc_perm": perms[b0], "inc_j": js0[b0],
            "round": jnp.int32(1), "stall": jnp.int32(0),
            "key": key, "round_js": trace0,
            "sweeps": jnp.int32(0), "swaps": jnp.int32(0),
        }

        def cond(st):
            return (st["round"] < rounds) & (st["stall"] < stagnation)

        def body(st):
            key, kk = jax.random.split(st["key"])
            # tournament seeding: the worse half of the population
            # restarts from the incumbent (rank 0 = best lane)
            rank = jnp.argsort(jnp.argsort(st["js"]))
            adopt = rank >= half
            ps = jnp.where(adopt[:, None], st["inc_perm"][None, :],
                           st["perms"])
            ps = vkick(ps, jax.random.split(kk, lanes))
            # telemetry stays off inside the round loop: per-sweep
            # counters are collected at round 0 (refine_lanes); the
            # rounds' sweep/swap totals are already carried below
            ps, _, sw, sp, _ = vrefine(nbr, wgt, eu, ev, ew, us, vs, ps,
                                       D, epss, tenure, dlb,
                                       jnp.bool_(False))
            js = vobj(ps)
            b = jnp.argmin(js)
            improved = js[b] < st["inc_j"]
            inc_perm = jnp.where(improved, ps[b], st["inc_perm"])
            inc_j = jnp.where(improved, js[b], st["inc_j"])
            return {
                "perms": ps, "js": js,
                "inc_perm": inc_perm, "inc_j": inc_j,
                "round": st["round"] + 1,
                "stall": jnp.where(improved, jnp.int32(0),
                                   st["stall"] + 1),
                "key": key,
                "round_js": st["round_js"].at[st["round"]].set(inc_j),
                "sweeps": st["sweeps"] + jnp.sum(sw),
                "swaps": st["swaps"] + jnp.sum(sp),
            }

        out = jax.lax.while_loop(cond, body, state)
        return (out["inc_perm"], out["inc_j"], out["round_js"],
                out["round"], out["sweeps"], out["swaps"])

    return rounds_fn


@dataclass
class RoundsResult:
    """One portfolio run's host-facing accounting: the incumbent
    permutation, the per-round incumbent objectives (round 0 = the
    multistart best, host-truncated at the stop), executed rounds, and
    the device sweep/swap totals across lanes and rounds."""
    perm: np.ndarray
    round_objectives: list[float] = field(default_factory=list)
    rounds: int = 1
    sweeps: int = 0
    swaps: int = 0


class PortfolioRunner:
    """Host glue between a plan and the portfolio device loop.

    Lowered once per (spec × engine): resolves the per-lane construction
    cycle against the registry, fixes the lane geometry, and jits the
    rounds executable over the finest-level engine's sweep fn.  Runtime
    inputs are the graph, the candidate pairs, and the seed — like every
    other engine executable, shapes specialize per bucket and nothing
    compiled depends on the seed.
    """

    def __init__(self, engine: RefinementEngine, pspec, constructions):
        self.engine = engine
        self.pspec = pspec
        # (name, fn) per lane — the construction portfolio cycled across
        # the lane axis
        names = list(pspec.constructions or ()) or [constructions[0][0]]
        by_name = dict(constructions)
        self.lane_constructions = [
            (names[i % len(names)], by_name[names[i % len(names)]])
            for i in range(pspec.lanes)]
        # tabu/dlb runtime toggles: don't-look bits only matter alongside
        # a nonzero tenure (without it the sweep is monotone and stops at
        # the first coldworthy state anyway)
        self.tabu_tenure = int(pspec.tabu_tenure)
        self.dlb = bool(pspec.dont_look) and self.tabu_tenure > 0
        self._rounds_jit = None

    # ------------------------------------------------------------ describe
    def describe(self) -> dict:
        """Lane geometry for ``plan.describe()``."""
        return {
            "lanes": self.pspec.lanes,
            "rounds": self.pspec.rounds,
            "tabu_tenure": self.tabu_tenure,
            "dont_look": self.dlb,
            "kick_strength": self.pspec.kick_strength,
            "stagnation": self.pspec.stagnation,
            "lane_constructions": [name for name, _
                                   in self.lane_constructions],
        }

    # ------------------------------------------------------------- stages
    def construct_lanes(self, g: CommGraph, machine, cfg,
                        seed: int) -> list[np.ndarray]:
        """Per-lane initial permutations: lane i runs its registered
        construction with seed ``seed + i``."""
        return [fn(g, machine, seed=seed + i, cfg=cfg)
                for i, (_, fn) in enumerate(self.lane_constructions)]

    def refine_lanes(self, g: CommGraph, perms, pairs, j0s=None,
                     bucket=None, engine: RefinementEngine | None = None,
                     telemetry: bool = False) -> list[SearchStats]:
        """One vmapped refine of all lanes (round 0, and every coarse
        V-cycle level) — the engine's lane path with this portfolio's
        tabu toggles applied."""
        return (engine or self.engine).refine_lanes(
            g, perms, pairs, j0s=j0s, bucket=bucket,
            tabu_tenure=self.tabu_tenure, dlb=self.dlb,
            telemetry=telemetry)

    def _rounds(self):
        if self._rounds_jit is None:
            import jax
            eng = self.engine
            self._rounds_jit = jax.jit(_make_rounds(
                eng.kind, eng.params, eng.max_sweeps,
                lanes=self.pspec.lanes, rounds=self.pspec.rounds,
                kick_frac=self.pspec.kick_strength,
                stagnation=self.pspec.stagnation,
                use_pallas=eng.use_pallas, interpret=eng.interpret))
        return self._rounds_jit

    def run_rounds(self, g: CommGraph, perms, pairs, j0s,
                   bucket=None, seed: int = 0) -> RoundsResult:
        """The perturb → refine → tournament round loop from the round-0
        refined lane ``perms`` — ONE device dispatch for all remaining
        rounds.  With ``rounds=1`` (or no candidate pairs) there is
        nothing to perturb: the incumbent is the host argmin over the
        lanes, keeping the pure-multistart path free of kick noise."""
        import jax
        import jax.numpy as jnp
        eng = self.engine
        js = [float(qap_objective_of(eng, g, p)) for p in perms]
        if self.pspec.rounds <= 1 or len(pairs) == 0:
            b = int(np.argmin(js))
            return RoundsResult(perm=np.asarray(perms[b]).copy(),
                                round_objectives=[js[b]], rounds=1)
        if bucket is not None:
            dg = eng._device_graph(g, k=bucket.max_deg,
                                   e=bucket.num_edges)
            us, vs = eng._device_pairs(
                pairs, pad_to=eng._bucket_p(bucket, len(pairs)))
        else:
            dg = eng._device_graph(g)
            us, vs = eng._device_pairs(pairs)
        tenure, dlb_, _ = eng._toggles(self.tabu_tenure, self.dlb)
        with host_boundary("portfolio.dispatch"):
            inc_perm, _, round_js, rounds_done, sweeps, swaps = \
                self._rounds()(
                    dg.nbr, dg.wgt, dg.eu, dg.ev, dg.ew, us, vs,
                    jnp.stack([jnp.asarray(p, jnp.int32)
                               for p in perms]),
                    eng._D,
                    jnp.asarray([eng._eps(j) for j in j0s], jnp.float32),
                    tenure, dlb_, jax.random.PRNGKey(seed))
        with host_boundary("portfolio.readback"):
            rounds_done = int(rounds_done)
            return RoundsResult(
                perm=np.asarray(inc_perm, dtype=np.int64),
                round_objectives=[
                    float(x)
                    for x in np.asarray(round_js)[:rounds_done]],
                rounds=rounds_done, sweeps=int(sweeps),
                swaps=int(swaps))


def qap_objective_of(engine: RefinementEngine, g: CommGraph,
                     perm) -> float:
    """Host float64 objective against the engine's topology."""
    from ..core.objective import qap_objective
    return qap_objective(g, engine.topology, perm)
