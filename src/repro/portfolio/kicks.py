"""On-device perturbation kicks: bijective permutation perturbations
applied between portfolio rounds.

A kick must (a) stay a bijection — the refinement engine only ever
swaps, so validity is preserved downstream — and (b) have a fixed shape
regardless of the sampled randomness, so every round reuses the one
compiled executable.  Two classic perturbations satisfy both:

* **segment reversal** — reverse a random length-k window of the
  assignment array (wrapping around), the permutation analogue of a
  Lin-Kernighan double-bridge restart: it relocates a contiguous block
  of processes wholesale.
* **swap storm** — k random transpositions applied in sequence, a
  diffuse shake that spreads displacement across the whole machine.

Each kick flips a coin between the two, so a portfolio's lanes explore
both perturbation geometries over the rounds.
"""

from __future__ import annotations


def make_kick(n: int, kick_frac: float):
    """Build the jit-able kick ``(perm, key) -> perm`` for ``n``-element
    permutations touching ``ceil(kick_frac * n)`` vertices (at least 2,
    at most ``n``) per application.  ``kick_frac`` is compile-time — it
    fixes the window/storm length, hence the executable's shapes."""
    import jax
    import jax.numpy as jnp

    klen = max(2, min(n, int(round(kick_frac * n))))
    idx = jnp.arange(n, dtype=jnp.int32)

    def kick(perm, key):
        kc, ks, kw = jax.random.split(key, 3)
        # --- segment reversal: positions s .. s+klen-1 (mod n) reversed;
        # offset o = (i - s) mod n maps to klen-1-o, i.e. source index
        # (2s + klen - 1 - i) mod n — a bijection on the window
        s = jax.random.randint(ks, (), 0, n, dtype=jnp.int32)
        in_seg = ((idx - s) % n) < klen
        src = jnp.where(in_seg, (2 * s + klen - 1 - idx) % n, idx)
        reversed_ = perm[src]
        # --- swap storm: klen random transpositions in sequence (u == v
        # draws are identity transpositions — harmless, fixed shape)
        uv = jax.random.randint(kw, (klen, 2), 0, n, dtype=jnp.int32)

        def one(p, pair):
            u, v = pair[0], pair[1]
            pu, pv = p[u], p[v]
            return p.at[u].set(pv).at[v].set(pu), None

        storm, _ = jax.lax.scan(one, perm, uv)
        return jnp.where(jax.random.bernoulli(kc), reversed_, storm)

    kick.klen = klen
    return kick
