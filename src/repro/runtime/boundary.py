"""Documented host<->device boundaries.

The sanitizer CI lane runs tier-1 under ``JAX_TRANSFER_GUARD=disallow``,
which rejects every *implicit* transfer — np arrays flowing into jitted
functions, ``np.asarray``/``int()`` readbacks, Python-scalar promotion
in eager ops.  The library's real boundaries (graph upload, engine
dispatch/readback, objective readback, coarsening rebuilds) are
deliberate, so they scope a ``jax.transfer_guard("allow")`` via
:func:`host_boundary`.  Anything *outside* one of these scopes that
transfers under the sanitizer lane is a bug, which is exactly the
point.

The static checker honors the same marker: VIEM001's transfer findings
are exempt inside a ``with host_boundary(...)`` block, so the lint rule
and the runtime guard enforce one shared notion of "documented
boundary".
"""

from __future__ import annotations

import contextlib

__all__ = ["host_boundary"]


@contextlib.contextmanager
def host_boundary(tag: str):
    """Mark a deliberate host<->device transfer site.

    ``tag`` names the boundary in the style of a metrics key
    (``"engine.readback"``, ``"graph.upload"``) — it documents intent at
    the call site and gives grep one vocabulary for every crossing.
    """
    import jax
    with jax.transfer_guard("allow"):
        yield
