"""Fault tolerance: heartbeats, straggler detection, restart policy.

On a real fleet these hooks bind to the coordination service; the decision
logic below is hardware-independent and is what the tests exercise.  The
training driver (launch/train.py) calls ``monitor.record_step`` each step
and acts on the returned ``Action``.
"""

from __future__ import annotations

import enum
import time
from collections import deque
from dataclasses import dataclass, field


class Action(enum.Enum):
    CONTINUE = "continue"
    REBALANCE = "rebalance"          # shift data shards away from slow host
    EVICT_RESTART = "evict_restart"  # drop host, elastic restart from ckpt


@dataclass
class HostStats:
    history: deque = field(default_factory=lambda: deque(maxlen=64))
    missed_heartbeats: int = 0

    def push(self, dt: float):
        self.history.append(dt)
        self.missed_heartbeats = 0

    @property
    def median(self) -> float:
        if not self.history:
            return 0.0
        s = sorted(self.history)
        return s[len(s) // 2]


class StragglerMonitor:
    """Flags hosts whose step time exceeds fleet median by `threshold`×
    for `patience` consecutive steps; escalates to eviction after
    `evict_after` flags or `max_missed` heartbeats (dead host).

    Decisions are consumable two ways besides the return values:
    ``on_action(action, hosts)`` fires for every non-CONTINUE decision
    (``RemapMonitor.attach`` subscribes here to route REBALANCE through
    its replay gate), and the ``actions`` deque queues the same events
    for pull-style consumers (``drain_actions()`` empties it).
    """

    def __init__(self, n_hosts: int, threshold: float = 1.5,
                 patience: int = 3, evict_after: int = 10,
                 max_missed: int = 5, on_action=None,
                 queue_len: int = 256):
        self.hosts = {h: HostStats() for h in range(n_hosts)}
        self.threshold = threshold
        self.patience = patience
        self.evict_after = evict_after
        self.max_missed = max_missed
        self.on_action = on_action
        self.actions: deque = deque(maxlen=queue_len)
        self._flags = {h: 0 for h in range(n_hosts)}

    def _emit(self, action: Action, hosts: list[int]) -> None:
        if action == Action.CONTINUE:
            return
        self.actions.append((action, list(hosts)))
        if self.on_action is not None:
            self.on_action(action, list(hosts))

    def drain_actions(self) -> list[tuple[Action, list[int]]]:
        """Pop every queued non-CONTINUE decision (oldest first)."""
        out = list(self.actions)
        self.actions.clear()
        return out

    def heartbeat_missed(self, host: int) -> Action:
        self.hosts[host].missed_heartbeats += 1
        if self.hosts[host].missed_heartbeats >= self.max_missed:
            self._emit(Action.EVICT_RESTART, [host])
            return Action.EVICT_RESTART
        return Action.CONTINUE

    def record_step(self, step_times: dict[int, float]) -> tuple[Action,
                                                                 list[int]]:
        """step_times: host -> seconds for this step."""
        for h, dt in step_times.items():
            self.hosts[h].push(dt)
        medians = sorted(s.median for s in self.hosts.values() if s.history)
        if not medians:
            return Action.CONTINUE, []
        # lower median: with few hosts the upper median would sit on the
        # straggler itself and mask it
        fleet_median = medians[(len(medians) - 1) // 2]
        slow = []
        for h, s in self.hosts.items():
            if s.history and s.median > self.threshold * fleet_median:
                self._flags[h] += 1
                if self._flags[h] >= self.patience:
                    slow.append(h)
            else:
                self._flags[h] = max(0, self._flags[h] - 1)
        if not slow:
            return Action.CONTINUE, []
        worst = max(slow, key=lambda h: self._flags[h])
        action = Action.EVICT_RESTART \
            if self._flags[worst] >= self.evict_after else Action.REBALANCE
        self._emit(action, slow)
        return action, slow


@dataclass
class RestartPolicy:
    """Bounded exponential-backoff restart-from-checkpoint loop."""
    max_restarts: int = 20
    backoff_s: float = 5.0
    backoff_mult: float = 1.5
    max_backoff_s: float = 300.0
    restarts: int = 0

    def next_delay(self) -> float | None:
        if self.restarts >= self.max_restarts:
            return None
        d = min(self.backoff_s * self.backoff_mult ** self.restarts,
                self.max_backoff_s)
        self.restarts += 1
        return d


def run_with_restarts(train_fn, restore_fn, policy: RestartPolicy,
                      sleep=time.sleep):
    """Driver: run train_fn(state); on exception restore from checkpoint
    and retry with backoff.  train_fn returns normally when training is
    complete."""
    state = restore_fn()
    while True:
        try:
            return train_fn(state)
        except Exception:
            delay = policy.next_delay()
            if delay is None:
                raise
            sleep(delay)
            state = restore_fn()
