"""Observability layer: device-side engine telemetry, host-side tracing
spans, and export pipelines (Chrome ``trace_event`` JSON, JSON-lines,
metrics registry).

Three pieces, layered from device to host:

* :mod:`.telemetry` — :class:`EngineTelemetry`, the host-facing view of
  the fixed-shape counter arrays the sweep loop
  (:mod:`repro.engine.sweep`) carries through its ``lax.while_loop``:
  gain passes executed, exchanges applied per sweep, tabu-masked pairs,
  aspiration fires, downhill escapes, matching rounds, and the objective
  trajectory.  Collection is a *runtime* toggle (``telemetry=True`` on
  ``refine``/``execute``/``map``) that masks rather than retraces — the
  same no-retrace discipline as the tabu knobs — and the off path is
  bit-identical to the untelemetered engine.

* :mod:`.trace` — :class:`Span`/:class:`Tracer`, a lightweight
  context-manager + decorator tracing API with a bounded in-memory ring
  buffer.  Spans always measure wall-time (callers read ``span.dur`` for
  result accounting) but are only *recorded* when the tracer is enabled,
  so the disabled hot path costs one ``perf_counter`` pair — the same
  price as the ad-hoc timing it replaced.  ``Mapper.lower``,
  ``MappingPlan.execute(_batch)``, every V-cycle level, portfolio
  stages, and ``MappingService`` ticks record spans, including
  compile-vs-execute splits via engine ``trace_count()`` deltas.

* :mod:`.export` / :mod:`.metrics` — ``write_chrome_trace`` emits
  Perfetto/``chrome://tracing``-loadable ``trace_event`` JSON (spans as
  complete events, per-sweep engine counters as counter tracks),
  ``write_jsonl`` a line-per-span event log; :class:`MetricsRegistry`
  holds counters/gauges/histograms behind one lock with atomic
  deep-copied snapshots (the backing store of
  ``MappingService.stats()``).

Surfaces: ``viem --profile out.trace.json`` / ``viem --telemetry``,
``plan.describe()["timings"]``, ``MappingService.stats()`` engine
aggregates, and the span breakdowns stamped into every ``BENCH_*.json``.
"""

from .export import (chrome_trace_events, span_breakdown,
                     write_chrome_trace, write_jsonl)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      parse_prometheus)
from .telemetry import EngineTelemetry
from .trace import Span, Tracer, get_tracer, traced

__all__ = [
    "Counter", "EngineTelemetry", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "chrome_trace_events", "get_tracer",
    "parse_prometheus", "span_breakdown", "traced", "write_chrome_trace",
    "write_jsonl",
]
