"""Exporters: Chrome ``trace_event`` JSON, JSON-lines, and span
aggregation.

``write_chrome_trace`` emits the JSON-object flavor of the Trace Event
Format (``{"traceEvents": [...]}``) that Perfetto and
``chrome://tracing`` load directly: every span becomes a complete
(``ph: "X"``) event on its thread's track, and spans carrying an
:class:`~repro.obs.telemetry.EngineTelemetry` in ``attrs["telemetry"]``
additionally emit per-sweep *counter* (``ph: "C"``) tracks — objective,
exchanges, tabu-masked pairs, aspiration fires — spread evenly across
the span's wall-clock window (the device loop has no host timestamps;
the spacing is presentational, the per-sweep values are exact).

``span_breakdown`` aggregates spans by name (count/total/mean/max
seconds) — the per-kernel-form timing block stamped into every
``BENCH_*.json``.
"""

from __future__ import annotations

import json

import numpy as np

__all__ = ["chrome_trace_events", "sanitize_attrs", "span_breakdown",
           "write_chrome_trace", "write_jsonl"]

_MAX_LIST = 512     # cap exported array attributes (ring buffer ≠ dump)

# telemetry counter tracks: (track name, EngineTelemetry array field)
_COUNTER_TRACKS = (("engine/exchanges", "exchanges"),
                   ("engine/tabu_masked", "tabu_masked"),
                   ("engine/aspirations", "aspirations"),
                   ("engine/match_rounds", "match_rounds"))


def sanitize_attrs(attrs: dict) -> dict:
    """JSON-safe view of span attributes: numpy scalars → Python,
    arrays → capped lists, telemetry objects → scalar summaries,
    everything else unknown → ``repr``."""
    out = {}
    for k, v in attrs.items():
        if hasattr(v, "summary") and callable(v.summary):   # telemetry
            out[k] = v.summary()
        elif isinstance(v, np.ndarray):
            out[k] = v[:_MAX_LIST].tolist()
        elif isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        elif isinstance(v, (list, tuple)):
            out[k] = list(v)[:_MAX_LIST]
        elif v is None or isinstance(v, (bool, int, float, str, dict)):
            out[k] = v
        else:
            out[k] = repr(v)
    return out


def _counter_events(sp, ts: float, dur: float, pid: int) -> list:
    """Per-sweep counter tracks from a span's attached telemetry."""
    tel = sp.attrs.get("telemetry")
    if tel is None or getattr(tel, "passes", 0) <= 0:
        return []
    events = []
    step = dur / max(tel.passes, 1)
    for p in range(tel.passes):
        t = ts + (p + 0.5) * step
        for track, attr in _COUNTER_TRACKS:
            arr = getattr(tel, attr)
            if p < len(arr):
                events.append({"name": track, "ph": "C", "ts": t,
                               "pid": pid, "args": {"value": int(arr[p])}})
    trace = tel.objective_trace
    if len(trace):
        tstep = dur / max(len(trace) - 1, 1)
        for i, j in enumerate(trace):
            events.append({"name": "engine/objective", "ph": "C",
                           "ts": ts + i * tstep, "pid": pid,
                           "args": {"value": float(j)}})
    return events


def chrome_trace_events(spans, pid: int = 0) -> dict:
    """Trace Event Format JSON object for a span list (see module
    docstring)."""
    spans = list(spans)
    events = []
    t0 = min((sp.t0 for sp in spans), default=0.0)
    tids = {}
    for sp in spans:
        tid = tids.setdefault(sp.tid, len(tids))
        ts = (sp.t0 - t0) * 1e6
        dur = sp.dur * 1e6
        events.append({"name": sp.name, "cat": sp.cat or "viem",
                       "ph": "X", "ts": ts, "dur": dur,
                       "pid": pid, "tid": tid,
                       "args": sanitize_attrs(sp.attrs)})
        events.extend(_counter_events(sp, ts, dur, pid))
    meta = [{"name": "process_name", "ph": "M", "pid": pid,
             "args": {"name": "viem"}}]
    for ident, tid in tids.items():
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": f"thread-{ident}"}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans, path) -> int:
    """Write a Perfetto-loadable ``.trace.json``; returns the number of
    events written."""
    payload = chrome_trace_events(spans)
    with open(path, "w") as fh:
        json.dump(payload, fh)
    return len(payload["traceEvents"])


def write_jsonl(spans, path) -> int:
    """One JSON object per span (append-friendly event log)."""
    n = 0
    with open(path, "w") as fh:
        for sp in spans:
            fh.write(json.dumps(sp.to_dict()) + "\n")
            n += 1
    return n


def span_breakdown(spans) -> dict:
    """Aggregate spans by name: ``{name: {count, total_s, mean_s,
    max_s}}`` — the timing block the benchmark JSONs embed."""
    agg: dict = {}
    for sp in spans:
        a = agg.setdefault(sp.name, {"count": 0, "total_s": 0.0,
                                     "max_s": 0.0})
        a["count"] += 1
        a["total_s"] += sp.dur
        a["max_s"] = max(a["max_s"], sp.dur)
    for a in agg.values():
        a["mean_s"] = a["total_s"] / a["count"]
    return agg
