"""Counters, gauges, and histograms behind one registry lock.

:class:`MetricsRegistry` is the aggregate store behind
``MappingService.stats()`` (the legacy dict is a compatibility view over
``snapshot()``).  Two guarantees the service-level tests lean on:

* **Atomic multi-metric updates** — every metric holds the registry's
  re-entrant lock while mutating, and call sites that must update
  several metrics as one observable step (e.g. ``served`` + the latency
  histogram) take ``registry.lock`` around the group.  ``snapshot()``
  acquires the same lock, so a monitoring thread can never read a
  half-applied update.
* **Snapshots are deep copies** — ``snapshot()`` returns fresh dicts and
  scalars only; mutating a snapshot (or the registry afterwards) never
  leaks into a previously returned one.

Histograms keep a bounded sliding window (deque) for percentiles — the
same recent-window semantics the service's latency deque had — plus
monotone ``count``/``sum`` over the full lifetime.
"""

from __future__ import annotations

import threading
from collections import deque

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "parse_prometheus"]


class Counter:
    """Monotone counter."""

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self):
        with self._lock:
            return self.value

    def reset(self) -> None:
        with self._lock:
            self.value = 0


class Gauge:
    """Last-value gauge with a high-water helper (``set_max``)."""

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0.0

    def set(self, v) -> None:
        with self._lock:
            self.value = v

    def set_max(self, v) -> None:
        with self._lock:
            if v > self.value:
                self.value = v

    def snapshot(self):
        with self._lock:
            return self.value

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0


class Histogram:
    """Lifetime ``count``/``sum``/``min``/``max`` plus a bounded sliding
    window for percentiles (recent behavior, not the first N forever)."""

    def __init__(self, lock: threading.RLock, window: int = 65536):
        self._lock = lock
        self.window = int(window)
        self.reset()

    def reset(self) -> None:
        # RLock: re-enters cleanly from __init__ and registry holders
        with self._lock:
            self.count = 0
            self.total = 0.0
            self.min = None
            self.max = None
            self._recent: deque = deque(maxlen=self.window)

    def observe(self, v: float) -> None:
        with self._lock:
            self.count += 1
            self.total += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            self._recent.append(v)

    def percentile(self, q: float) -> float:
        with self._lock:
            lat = sorted(self._recent)
        if not lat:
            return 0.0
        return lat[min(len(lat) - 1, int(q * len(lat)))]

    def snapshot(self) -> dict:
        # one lock scope: count/sum/min/max and the percentile window
        # come from the same instant (and sorted(_recent) must not race
        # a concurrent observe() append)
        with self._lock:
            return {
                "count": self.count, "sum": self.total,
                "min": 0.0 if self.min is None else self.min,
                "max": 0.0 if self.max is None else self.max,
                "mean": self.total / self.count if self.count else 0.0,
                "p50": self._pct_locked(0.50),
                "p99": self._pct_locked(0.99),
                "window": len(self._recent),
            }

    def _pct_locked(self, q: float) -> float:
        # callers already hold the registry lock (snapshot path)
        lat = sorted(self._recent)
        if not lat:
            return 0.0
        return lat[min(len(lat) - 1, int(q * len(lat)))]


class MetricsRegistry:
    """Named metric store (see module docstring).  Metrics are created
    on first access (``counter``/``gauge``/``histogram``) and live for
    the registry's lifetime; ``reset()`` zeroes values but keeps the
    registrations."""

    def __init__(self):
        self.lock = threading.RLock()
        self._metrics: dict = {}

    def _get(self, name: str, kind, **kw):
        with self.lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(self.lock, **kw)
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 65536) -> Histogram:
        return self._get(name, Histogram, window=window)

    def snapshot(self) -> dict:
        """Deep-copied point-in-time view: ``{name: value-or-dict}``,
        taken atomically under the registry lock."""
        with self.lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        """Zero every metric atomically (registrations survive)."""
        with self.lock:
            for m in self._metrics.values():
                m.reset()

    def to_prometheus(self, prefix: str = "viem_") -> str:
        """Prometheus text exposition (one atomic snapshot).

        Counters/gauges map 1:1; histograms expose as summaries
        (``_count``/``_sum`` plus p50/p99 quantile samples from the
        sliding window).  Metric names sanitize dots to underscores
        under ``prefix`` — ``monitor.drift.score`` scrapes as
        ``viem_monitor_drift_score``.  Round-trips through
        :func:`parse_prometheus`.
        """
        with self.lock:
            metrics = sorted(self._metrics.items())
            lines: list[str] = []
            for name, m in metrics:
                pname = prefix + name.replace(".", "_").replace("-", "_")
                if isinstance(m, Counter):
                    lines.append(f"# TYPE {pname} counter")
                    lines.append(f"{pname} {m.snapshot()}")
                elif isinstance(m, Gauge):
                    lines.append(f"# TYPE {pname} gauge")
                    lines.append(f"{pname} {m.snapshot()}")
                else:
                    snap = m.snapshot()
                    lines.append(f"# TYPE {pname} summary")
                    lines.append(f'{pname}{{quantile="0.5"}} '
                                 f'{snap["p50"]}')
                    lines.append(f'{pname}{{quantile="0.99"}} '
                                 f'{snap["p99"]}')
                    lines.append(f"{pname}_count {snap['count']}")
                    lines.append(f"{pname}_sum {snap['sum']}")
        return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict:
    """Parse the subset of the Prometheus text format
    :meth:`MetricsRegistry.to_prometheus` emits, back into
    ``{name: {"type": ..., "samples": {label-or-"": value}}}`` — the
    round-trip check scrapers rely on."""
    out: dict = {}
    types: dict = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        name_part, value = line.rsplit(" ", 1)
        if "{" in name_part:
            name, labels = name_part.split("{", 1)
            labels = labels.rstrip("}")
        else:
            name, labels = name_part, ""
        base = name
        for suffix in ("_count", "_sum"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
                labels = suffix[1:]
                break
        entry = out.setdefault(base, {"type": types.get(base, "untyped"),
                                      "samples": {}})
        entry["samples"][labels] = float(value)
    return out
