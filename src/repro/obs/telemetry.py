"""Host-facing view of the device-side engine counters.

The sweep loop (:func:`repro.engine.sweep._make_refine`) carries
fixed-shape counter arrays through its ``lax.while_loop`` and returns
them alongside the permutation — zero extra host syncs (they ride the
same transfer as the trace).  Collection is a runtime ``jnp.bool_``
operand: off, every counter stays zero and the search outputs are
bit-identical to the untelemetered engine; toggling it never retraces
(same masking discipline as the tabu knobs, regression-tested).

Counters are indexed by *gain pass* (one per ``while_loop`` body
iteration — every applied sweep plus the final pass that found no
eligible move when the loop converged before its budget, matching the
``SearchStats.evaluated`` accounting):

* ``exchanges[p]``    — pair exchanges applied at pass ``p`` (their sum
  is exactly ``SearchStats.swaps``),
* ``tabu_masked[p]``  — candidate pairs masked out by active tabu
  tenure at pass ``p``,
* ``aspirations[p]``  — tabu pairs *unmasked* because they would beat
  the best-seen objective (the aspiration criterion firing),
* ``match_rounds[p]`` — greedy maximal-matching rounds the conflict
  resolution ran at pass ``p``,
* ``downhill_escapes`` — sweeps that applied the best non-tabu move
  *downhill* (the robust-tabu escape out of a monotone local optimum),
* ``objective_trace`` — the carried device objective, one entry per
  applied sweep (entry 0 = initial).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["EngineTelemetry"]

# device-side counter keys, in the order the sweep fn returns them
COUNTER_KEYS = ("exchanges", "tabu_masked", "aspirations", "match_rounds")


@dataclass
class EngineTelemetry:
    """One refinement's engine counters (see module docstring).  Arrays
    are trimmed to the executed gain passes; scalars are host ints."""
    passes: int = 0
    sweeps: int = 0
    exchanges: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    tabu_masked: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    aspirations: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    match_rounds: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64))
    downhill_escapes: int = 0
    objective_trace: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.float64))
    merged_from: int = 1        # >1 when lanes/levels were aggregated

    @classmethod
    def from_device(cls, tel: dict, objective_trace=None
                    ) -> "EngineTelemetry":
        """Build from the device counter dict (host numpy arrays) one
        engine call returned; arrays are trimmed to the executed
        passes."""
        passes = int(tel["passes"])
        sweeps = int(tel.get("sweeps", passes))

        def trim(key):
            return np.asarray(tel[key][:passes], np.int64)

        trace = (np.zeros(0, np.float64) if objective_trace is None
                 else np.asarray(objective_trace[:sweeps + 1], np.float64))
        return cls(passes=passes, sweeps=sweeps,
                   exchanges=trim("exchanges"),
                   tabu_masked=trim("tabu_masked"),
                   aspirations=trim("aspirations"),
                   match_rounds=trim("match_rounds"),
                   downhill_escapes=int(tel["downhill_escapes"]),
                   objective_trace=trace)

    @classmethod
    def merge(cls, parts: "list[EngineTelemetry]") -> "EngineTelemetry":
        """Aggregate several refinements (portfolio lanes, V-cycle
        levels): per-pass arrays are zero-padded to the longest part and
        summed, scalars summed, ``sweeps``/``passes`` take the maximum
        (the wall-clock-relevant depth of the vmapped call), and the
        objective trace is the elementwise minimum (the incumbent's
        envelope)."""
        parts = [p for p in parts if p is not None]
        if not parts:
            return cls()
        if len(parts) == 1:
            return parts[0]
        passes = max(p.passes for p in parts)

        def padsum(key):
            out = np.zeros(passes, np.int64)
            for p in parts:
                arr = getattr(p, key)
                out[:len(arr)] += arr
            return out

        depth = max(len(p.objective_trace) for p in parts)
        trace = np.full(depth, np.inf)
        for p in parts:
            t = p.objective_trace
            if len(t):
                ext = np.concatenate(
                    [t, np.full(depth - len(t), t[-1])])
                np.minimum(trace, ext, out=trace)
        return cls(passes=passes, sweeps=max(p.sweeps for p in parts),
                   exchanges=padsum("exchanges"),
                   tabu_masked=padsum("tabu_masked"),
                   aspirations=padsum("aspirations"),
                   match_rounds=padsum("match_rounds"),
                   downhill_escapes=sum(p.downhill_escapes
                                        for p in parts),
                   objective_trace=(np.zeros(0, np.float64)
                                    if depth == 0 else trace),
                   merged_from=sum(p.merged_from for p in parts))

    # ----------------------------------------------------------- derived
    @property
    def total_exchanges(self) -> int:
        return int(self.exchanges.sum())

    @property
    def aspiration_fires(self) -> int:
        return int(self.aspirations.sum())

    @property
    def tabu_masked_total(self) -> int:
        return int(self.tabu_masked.sum())

    @property
    def aspiration_rate(self) -> float:
        """Aspiration fires per executed gain pass."""
        return self.aspiration_fires / max(self.passes, 1)

    def summary(self) -> dict:
        """Scalar totals (JSON-safe) — span attributes and the
        ``stats()`` aggregates read this, not the raw arrays."""
        return {
            "passes": self.passes, "sweeps": self.sweeps,
            "exchanges": self.total_exchanges,
            "tabu_masked": self.tabu_masked_total,
            "aspiration_fires": self.aspiration_fires,
            "aspiration_rate": self.aspiration_rate,
            "downhill_escapes": self.downhill_escapes,
            "match_rounds": int(self.match_rounds.sum()),
            "merged_from": self.merged_from,
        }

    def to_dict(self) -> dict:
        """Full JSON-safe dump including the per-sweep arrays."""
        d = self.summary()
        d.update({
            "exchanges_per_sweep": self.exchanges.tolist(),
            "tabu_masked_per_sweep": self.tabu_masked.tolist(),
            "aspirations_per_sweep": self.aspirations.tolist(),
            "match_rounds_per_sweep": self.match_rounds.tolist(),
            "objective_trace": [float(x) for x in self.objective_trace],
        })
        return d
