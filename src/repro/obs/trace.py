"""Structured tracing spans: a context-manager/decorator API over a
bounded in-memory ring buffer (see package docstring).

Design constraints, in order:

1. **The disabled path is the hot path.**  ``span()`` always measures
   wall-time — result accounting (``MappingResult.construction_seconds``
   etc.) reads ``span.dur`` whether or not tracing is on — but the span
   is only appended to the ring buffer when the tracer is enabled, so
   serving traffic pays one ``perf_counter`` pair per span, exactly what
   the ad-hoc timing it replaced cost.
2. **Bounded memory.**  The buffer is a ``deque(maxlen=capacity)``;
   long-lived services drop the *oldest* spans (``dropped`` counts them)
   instead of growing without bound.
3. **Thread-safe.**  Spans record the emitting thread; nesting depth is
   tracked per-thread, so a service worker's spans interleave cleanly
   with client-thread spans in the exported trace.

One process-global tracer (``get_tracer()``) is shared by every layer so
a single ``enable()`` captures lower/construct/refine/execute/tick spans
end to end; independent ``Tracer`` instances remain available for tests
and embedded use.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from functools import wraps

__all__ = ["Span", "Tracer", "get_tracer", "traced"]


@dataclass
class Span:
    """One recorded operation: name, category, wall-clock window
    (``t0``/``dur`` in ``perf_counter`` seconds), emitting thread,
    per-thread nesting depth, and free-form attributes."""
    name: str
    cat: str = "viem"
    t0: float = 0.0
    dur: float = 0.0
    tid: int = 0
    depth: int = 0
    attrs: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        from .export import sanitize_attrs
        return {"name": self.name, "cat": self.cat, "t0": self.t0,
                "dur": self.dur, "tid": self.tid, "depth": self.depth,
                "attrs": sanitize_attrs(self.attrs)}


class Tracer:
    """Span recorder with a bounded ring buffer (see module docstring).

    ``span(name, **attrs)`` is a context manager yielding the live
    :class:`Span` — callers may add attributes inside the block and read
    ``span.dur`` after it.  ``wrap(name)`` is the decorator form.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.dropped = 0
        self._buf: "deque[Span]" = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._local = threading.local()

    # ------------------------------------------------------------ control
    def enable(self, capacity: int | None = None) -> "Tracer":
        """Start recording (optionally resizing the ring buffer)."""
        if capacity is not None:
            # compare-and-resize under one lock scope: the bare-read
            # check raced a concurrent enable() resizing the buffer
            with self._lock:
                if int(capacity) != self.capacity:
                    self.capacity = int(capacity)
                    self._buf = deque(self._buf, maxlen=self.capacity)
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    # ------------------------------------------------------------ record
    @contextmanager
    def span(self, name: str, cat: str = "viem", **attrs):
        sp = Span(name=name, cat=cat, t0=time.perf_counter(),
                  tid=threading.get_ident(),
                  depth=getattr(self._local, "depth", 0), attrs=attrs)
        self._local.depth = sp.depth + 1
        try:
            yield sp
        finally:
            self._local.depth = sp.depth
            sp.dur = time.perf_counter() - sp.t0
            if self.enabled:
                with self._lock:
                    if len(self._buf) == self._buf.maxlen:
                        self.dropped += 1
                    self._buf.append(sp)

    def record(self, name: str, dur: float, cat: str = "viem",
               t0: float | None = None, **attrs) -> Span:
        """Record an already-measured interval (for code that cannot
        wrap the work in a ``with`` block)."""
        sp = Span(name=name, cat=cat, dur=float(dur),
                  t0=time.perf_counter() - float(dur) if t0 is None
                  else float(t0),
                  tid=threading.get_ident(),
                  depth=getattr(self._local, "depth", 0), attrs=attrs)
        if self.enabled:
            with self._lock:
                if len(self._buf) == self._buf.maxlen:
                    self.dropped += 1
                self._buf.append(sp)
        return sp

    def wrap(self, name: str | None = None, cat: str = "viem"):
        """Decorator form: ``@tracer.wrap("stage")``."""
        def deco(fn):
            label = name or fn.__qualname__

            @wraps(fn)
            def inner(*args, **kwargs):
                with self.span(label, cat=cat):
                    return fn(*args, **kwargs)
            return inner
        return deco

    # ------------------------------------------------------------ inspect
    def spans(self) -> "list[Span]":
        """Snapshot of the ring buffer (oldest first)."""
        with self._lock:
            return list(self._buf)

    def drain(self) -> "list[Span]":
        """Snapshot and clear in one atomic step."""
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
            return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)


_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-global tracer every pipeline layer records into.  It
    is a stable singleton — hold the reference; ``enable()``/``disable``
    toggle recording without invalidating it."""
    return _GLOBAL


def traced(name: str | None = None, cat: str = "viem"):
    """Decorator recording into the *global* tracer:
    ``@traced("stage")``."""
    return _GLOBAL.wrap(name, cat=cat)
