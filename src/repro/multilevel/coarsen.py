"""Level pyramids: device graph contraction + machine-side PE pairing.

One :class:`Level` holds everything the V-cycle needs at one scale: the
contracted communication graph, the matching machine model, the level's
own candidate pairs, and the projection arrays back to the next-finer
level.  Graph contraction runs on device (:mod:`repro.kernels.contract`)
with one host sync per level boundary to assemble the next
:class:`CommGraph`; machine coarsening is pure numpy over the topology's
online distance oracle (no n×n materialization of the *fine* machine —
only the coarse nc×nc matrices are ever built).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.graph import CommGraph, from_edges
from ..kernels.contract import MAX_N
from ..runtime.boundary import host_boundary
from ..topology.base import Topology


@dataclass
class Level:
    """One scale of the V-cycle.  ``fine_u``/``fine_v`` (None at the
    finest level) give each coarse vertex's two members in the
    next-finer level, with ``fine_u < fine_v``."""
    graph: CommGraph
    machine: Topology
    pairs: np.ndarray
    fine_u: np.ndarray | None = None
    fine_v: np.ndarray | None = None


_COARSEN_JIT = None


def _coarsen_jit():
    """The one jitted device contraction entry (lazy: jax imports on
    first use).  jax re-specializes it per (n, E) shape bucket under the
    hood, so no extra per-shape wrapper layer is needed."""
    global _COARSEN_JIT
    if _COARSEN_JIT is None:
        import jax

        from ..kernels.contract import coarsen_arrays
        _COARSEN_JIT = jax.jit(coarsen_arrays)
    return _COARSEN_JIT


def coarsen_graph(g: CommGraph) -> tuple[CommGraph, np.ndarray, np.ndarray]:
    """One device contraction step: heavy-edge perfect pairing + segment
    -sum edge collapsing.  Returns ``(coarse graph, fine_u, fine_v)``
    with coarse vertex c = fine pair (fine_u[c], fine_v[c])."""
    n = g.n
    if n % 2:
        raise ValueError(f"cannot pair-contract an odd vertex count ({n})")
    if n > MAX_N:
        raise ValueError(f"contraction keys need n <= {MAX_N}, got {n}")
    import jax.numpy as jnp

    from ..kernels.pad import pad_edge_arrays
    u, v, w = g.edge_list()
    eu, ev, ew = pad_edge_arrays(u, v, w)
    labels, ceu, cev, cew, cvw = _coarsen_jit()(
        eu, ev, ew, jnp.asarray(g.vwgt.astype(np.float32)))
    with host_boundary("coarsen.rebuild"):
        labels = np.asarray(labels, dtype=np.int64)
        nc = n // 2
        # stable sort by label: each label appears exactly twice,
        # members in ascending fine-vertex order
        members = np.argsort(labels, kind="stable")
        fine_u, fine_v = members[0::2].copy(), members[1::2].copy()
        cew = np.asarray(cew, dtype=np.float64)
        live = cew > 0
        coarse = from_edges(nc, np.asarray(ceu, np.int64)[live],
                            np.asarray(cev, np.int64)[live], cew[live],
                            vwgt=np.asarray(cvw, np.float64)[:nc])
    return coarse, fine_u, fine_v


def coarsen_machine(machine: Topology) -> Topology:
    """Pair PEs (2b, 2b+1) into one coarse PE; coarse distance = mean of
    the four cross distances (zero diagonal).  Consecutive PEs are
    lowest-level siblings in tree hierarchies and last-axis neighbors in
    even tori, so the pair is the machine's natural smallest group.
    Returns an explicit :class:`MatrixTopology` — the engine's matrix
    distance form refines coarse levels unchanged."""
    from ..topology.matrix import MatrixTopology
    n = machine.n_pe
    if n % 2:
        raise ValueError(f"cannot pair-coarsen an odd PE count ({n})")
    ia = np.arange(n // 2, dtype=np.int64) * 2

    def cross(da: int, db: int) -> np.ndarray:
        return np.asarray(machine.distance((ia + da)[:, None],
                                           (ia + db)[None, :]),
                          dtype=np.float64)

    Dc = (cross(0, 0) + cross(0, 1) + cross(1, 0) + cross(1, 1)) / 4.0
    # the four cross distances of (a, b) and (b, a) are the same values
    # summed in a different order — symmetrize away the float ULPs so
    # MatrixTopology's exact-symmetry validation holds
    Dc = (Dc + Dc.T) / 2.0
    np.fill_diagonal(Dc, 0.0)
    return MatrixTopology(matrix=Dc)


def pyramid_depth(n: int, levels: int, coarsen_min: int) -> int:
    """Number of levels the V-cycle will actually build: contract while
    the coarse size stays at or above ``coarsen_min``, the vertex count
    stays even, and the ``levels`` budget allows.  Depends only on n —
    same-n graphs always share one level geometry (what makes batched
    V-cycles vmappable)."""
    depth = 1
    while depth < levels and n % 2 == 0 and n // 2 >= coarsen_min:
        n //= 2
        depth += 1
    return depth


def build_pyramid(g: CommGraph, machines: list[Topology], levels: int,
                  coarsen_min: int, pair_fn) -> list[Level]:
    """The graph-side pyramid, finest first.  ``machines`` is the
    machine-side pyramid (graph-independent, cached by the Mapper);
    ``pair_fn(graph)`` generates each level's candidate pairs."""
    depth = pyramid_depth(g.n, levels, coarsen_min)
    pyramid = [Level(g, machines[0], pair_fn(g))]
    for lvl in range(1, depth):
        coarse, fine_u, fine_v = coarsen_graph(pyramid[-1].graph)
        pyramid.append(Level(coarse, machines[lvl], pair_fn(coarse),
                             fine_u, fine_v))
    return pyramid


def project_perm(coarse_perm: np.ndarray, fine_u: np.ndarray,
                 fine_v: np.ndarray) -> np.ndarray:
    """Uncoarsen one level: coarse vertex c on coarse PE b expands to its
    two members on fine PEs (2b, 2b+1).  A bijection on [0, 2·nc) for any
    bijective ``coarse_perm`` — the refinement engine only ever swaps, so
    validity is preserved at every level of the cycle."""
    nc = len(coarse_perm)
    perm = np.empty(2 * nc, dtype=np.int64)
    perm[fine_u] = 2 * coarse_perm
    perm[fine_v] = 2 * coarse_perm + 1
    return perm
