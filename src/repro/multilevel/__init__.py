"""Device-resident multilevel mapping: coarsen → map → uncoarsen.

VieM is a *multilevel* framework — the guide's core technique contracts
the communication graph, maps the coarsest level, then uncoarsens while
refining at every level ("Better Process Mapping and Sparse Quadratic
Assignment", Schulz & Träff 2017).  PR 3's device engine sweeps a single
level and stops at a local optimum of that level's candidate set; this
package wraps it in the V-cycle that lets local search escape those
optima, with every level's refinement still inside jitted device code and
host syncs only at level boundaries.

The cycle coarsens *both* sides of the QAP:

  * **Graph side** — heavy-edge matchings and segment-sum edge collapsing
    run as fixed-shape jnp ops (:mod:`repro.kernels.contract`).  The
    matching is completed to a perfect pairing (leftovers force-paired in
    index order), so every coarse vertex holds exactly two fine
    processes and level sizes are n, n/2, n/4, … — identical across
    same-n graphs, which is what lets ``map_many`` run each level's
    refinement as ONE vmapped engine call over the whole batch.
  * **Machine side** — PEs pair symmetrically (2b, 2b+1): consecutive PEs
    are lowest-level siblings in tree hierarchies and last-axis neighbors
    in even tori, so the pair is the machine's own natural "half-PE".
    The coarse machine is an explicit :class:`MatrixTopology` whose
    distance is the mean of the four cross distances — the engine's
    matrix distance form refines coarse levels with no new kernels.

V-cycle (:func:`repro.multilevel.vcycle.vcycle_map`):

    level L (coarsest)  : any registered construction maps the n/2^L
                          coarse processes onto the n/2^L coarse PEs,
                          then the RefinementEngine refines it;
    level l < L         : the level-(l+1) permutation projects through
                          the pairing — process pair (u, v) on coarse PE
                          b lands on fine PEs (2b, 2b+1) — a bijection by
                          construction at every level, then the engine
                          refines with level l's own candidate pairs.

Coarse levels are cheap (n and the padded ELL degree both shrink — the
sparse-gain economics of Paul's robust tabu search for sparse QAP), and
the projected start lets the finest refinement converge in fewer sweeps:
on the mesh-collective benchmark the V-cycle reaches objectives at or
below the flat engine's at comparable wall-time (BENCH_multilevel.json).

Select it with ``MappingSpec(engine="device",
multilevel=MultilevelSpec())`` or ``viem --multilevel``;
``MultilevelSpec(levels=1)`` is the parity escape hatch — it reproduces
the flat PR 3 engine bit-for-bit (tested), so existing specs are
unaffected by default.
"""

from .coarsen import Level, build_pyramid, coarsen_graph, coarsen_machine, \
    pyramid_depth, project_perm
from .vcycle import vcycle_map, vcycle_map_batch

__all__ = [
    "Level", "build_pyramid", "coarsen_graph", "coarsen_machine",
    "project_perm", "pyramid_depth", "vcycle_map", "vcycle_map_batch",
]
