"""The V-cycle: construct at the coarsest level, refine while projecting
down.  Pure orchestration — pyramids come from :mod:`.coarsen`, per-level
refinement is the device :class:`~repro.engine.RefinementEngine` (host
syncs only at level boundaries), and the Mapper supplies cached engines.

Timing flows through :mod:`repro.obs` tracer spans (the one timing
source of truth): construction and every per-level refinement record a
span — with level geometry, engine retrace deltas, and (when telemetry
collection is on) the engine counter block — and the result fields are
read back off the spans' measured durations.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.local_search import SearchStats
from ..core.objective import qap_objective
from ..obs import get_tracer
from .coarsen import Level, project_perm

_TR = get_tracer()


@dataclass
class VCycleResult:
    """``perm`` plus host-facing accounting: the finest level's
    refinement stats, the projected (pre-refinement) finest objective as
    the initial objective, and the per-level refined objectives
    (coarsest → finest) for diagnostics."""
    perm: np.ndarray
    initial_objective: float
    stats: SearchStats
    construction_seconds: float
    level_objectives: list[float] = field(default_factory=list)


def _construct_coarsest(level: Level, construct_fn, cfg, seed: int
                        ) -> np.ndarray:
    return construct_fn(level.graph, level.machine, seed=seed, cfg=cfg)


def _engine_at(engine_of, lvl: int, machine):
    """Resolve a level's refinement engine: ``engine_of`` is either a
    per-level sequence (a :class:`~repro.core.plan.MappingPlan`'s
    pre-built engines, indexed by level) or a callable ``machine →
    engine`` (the legacy cache-lookup form)."""
    if callable(engine_of):
        return engine_of(machine)
    return engine_of[lvl]


def _refine_level(engine, lvl: int, level: Level, perm, j0, bucket,
                  telemetry: bool):
    """One level's refinement under a traced span: level geometry,
    wall-time, and the engine's compile-vs-execute split (trace-count
    delta — >0 means this call paid a retrace)."""
    tc = getattr(engine, "trace_count", None)
    before = tc() if tc is not None else 0
    with _TR.span("vcycle.refine", level=lvl, n=level.graph.n,
                  pairs=len(level.pairs)) as sp:
        stats = engine.refine(level.graph, perm, level.pairs, j0=j0,
                              bucket=bucket, telemetry=telemetry)
    if tc is not None:
        sp.attrs["retraces"] = tc() - before
    sp.attrs["final_objective"] = stats.final_objective
    if stats.telemetry is not None:
        sp.attrs["telemetry"] = stats.telemetry
    return stats


def vcycle_map(pyramid: list[Level], engine_of, construct_fn, cfg,
               seed: int = 0, objective0=None, bucket=None,
               telemetry: bool = False) -> VCycleResult:
    """Run one V-cycle over a built pyramid (finest first).

    ``engine_of`` supplies each level's refinement engine (sequence or
    callable, see :func:`_engine_at`); ``construct_fn(g, machine, *,
    seed, cfg)`` maps the coarsest level; ``objective0(graph, perm)``
    scores the finest level (defaults to the host float64 objective).
    ``bucket`` is the plan's finest-level :class:`ShapeBucket` — coarse
    levels keep their own (graph-independent) geometry.  ``telemetry``
    threads the engine counter collection through every level's
    refinement (the finest level's counters ride the returned stats).
    """
    coarsest = pyramid[-1]
    with _TR.span("vcycle.construct", level=len(pyramid) - 1,
                  n=coarsest.graph.n) as sp:
        perm = _construct_coarsest(coarsest, construct_fn, cfg, seed)
    t_cons = sp.dur

    level_objectives: list[float] = []
    stats = SearchStats()
    j0_fine = 0.0
    for lvl in range(len(pyramid) - 1, -1, -1):
        level = pyramid[lvl]
        if lvl == 0:
            j0_fine = (qap_objective(level.graph, level.machine, perm)
                       if objective0 is None else
                       objective0(level.graph, perm))
            jl = j0_fine
        else:
            jl = qap_objective(level.graph, level.machine, perm)
        stats = _refine_level(
            _engine_at(engine_of, lvl, level.machine), lvl, level, perm,
            jl, bucket if lvl == 0 else None, telemetry)
        level_objectives.append(stats.final_objective)
        if lvl > 0:
            perm = project_perm(perm, level.fine_u, level.fine_v)
    return VCycleResult(perm=perm, initial_objective=j0_fine, stats=stats,
                        construction_seconds=t_cons,
                        level_objectives=level_objectives)


def vcycle_map_batch(pyramids: list[list[Level]], engine_of, construct_fn,
                     cfg, seed: int = 0, objective0=None,
                     bucket=None, telemetry: bool = False
                     ) -> list[VCycleResult]:
    """Batched V-cycles over same-n graphs: the forced perfect pairing
    makes every pyramid the same depth with the same level sizes, so each
    level's refinement across the whole batch is ONE vmapped engine call
    (``refine_batch``) — the multilevel counterpart of
    ``Mapper._map_many_device``.  Per-graph results match single
    :func:`vcycle_map` calls up to the engine's batching invariants."""
    if not pyramids:
        return []
    depths = {len(p) for p in pyramids}
    if len(depths) != 1:
        raise ValueError(f"batched V-cycles need one pyramid depth, "
                         f"got {sorted(depths)}")
    with _TR.span("vcycle.construct", level=len(pyramids[0]) - 1,
                  batch=len(pyramids)) as sp:
        perms = [_construct_coarsest(p[-1], construct_fn, cfg, seed)
                 for p in pyramids]
    t_cons = sp.dur / len(pyramids)

    level_objectives = [[] for _ in pyramids]
    stats_list = [SearchStats() for _ in pyramids]
    j0_fine = [0.0] * len(pyramids)
    for lvl in range(depths.pop() - 1, -1, -1):
        levels = [p[lvl] for p in pyramids]
        if lvl == 0 and objective0 is not None:
            j0s = [objective0(lv.graph, perm)
                   for lv, perm in zip(levels, perms)]
        else:
            j0s = [qap_objective(lv.graph, lv.machine, perm)
                   for lv, perm in zip(levels, perms)]
        if lvl == 0:
            j0_fine = j0s
        engine = _engine_at(engine_of, lvl, levels[0].machine)
        tc = getattr(engine, "trace_count", None)
        before = tc() if tc is not None else 0
        with _TR.span("vcycle.refine", level=lvl, n=levels[0].graph.n,
                      batch=len(levels)) as sp:
            stats_list = engine.refine_batch(
                [lv.graph for lv in levels], perms,
                [lv.pairs for lv in levels], j0s=j0s,
                bucket=bucket if lvl == 0 else None,
                telemetry=telemetry)
        if tc is not None:
            sp.attrs["retraces"] = tc() - before
        for i, st in enumerate(stats_list):
            level_objectives[i].append(st.final_objective)
        if lvl > 0:
            perms = [project_perm(perm, lv.fine_u, lv.fine_v)
                     for lv, perm in zip(levels, perms)]
    return [VCycleResult(perm=perm, initial_objective=j0, stats=st,
                         construction_seconds=t_cons, level_objectives=lo)
            for perm, j0, st, lo
            in zip(perms, j0_fine, stats_list, level_objectives)]
