"""Sparse per-pair swap-gain kernels over padded (ELL) neighbor rows.

The paper's central speedup is the O(deg(u) + deg(v)) incremental gain
(guide §2.1).  These kernels batch that sparse gain over P candidate
pairs at once, entirely on device, against the machine topology's
device-side distance form (``Topology.kernel_params()``):

    gain(u, v) = Σ_{k∈N(u)\\{v}} w_uk · (D(π_u, π_k) − D(π_v, π_k))
               + Σ_{k∈N(v)\\{u}} w_vk · (D(π_v, π_k) − D(π_u, π_k))

Neighbor rows come from :class:`repro.core.graph.DeviceGraph` — fixed-width
(n, K) arrays padded with zero-weight entries, so the gather ``nbr[us]``
is one dense (P, K) lookup and the masked row-sum vectorizes with no
ragged indexing.  The `v ∈ N(u)` exclusion and the row padding are both
folded into the weights (w = 0 kills the term), so the reduction itself
is branch-free.

Distance forms (the same three the edge-objective kernels use):
  tree    — in-register hierarchical oracle (strides, dists),
  torus   — closed-form k-ary n-cube ring distance (dims, weights),
  matrix  — explicit D: the (P, K) gathers run as XLA gathers in the
            wrapper, the kernel reduces the weighted difference.  D may
            be a lossless int8/int16 packing (``KernelConfig.dist_dtype``)
            — gathers then read 1–2 bytes per element instead of 4 and
            the conversion to f32 is exact, so gains are bit-identical
            to the float-table path.

Two interchangeable implementations (tested equal):
  * :func:`pair_gains` — fused jnp, traceable inside ``lax.while_loop``;
    the refinement engine's default.  With a :class:`KernelConfig` whose
    pair tile is smaller than P it switches to a ``fori_loop`` over
    byte-homogeneous pair tiles, so peak memory scales with the tile
    rather than the (P, K) row block; each pair's row reduction is
    unchanged, so results stay bit-identical to the fused form.
  * :func:`pair_gains_pallas` — hand-tiled Pallas kernel streaming
    (block_rows, K) row blocks through VMEM, for TPU runs where the
    candidate set is large enough that explicit tiling wins.

:func:`edge_objective` is the matching device-side objective
Σ w_e · D(π_u, π_v) used by the engine's on-device objective updates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import KernelConfig
from .pad import pad1, pad2, round_up
from .qap_objective import _hier_distance, _torus_distance

_LANES = 128      # lane-dim padding multiple for the Pallas row blocks
_BP = 8           # sublane rows per Pallas grid step (no-config default)


# ------------------------------------------------------------ distance forms
def distance_form(kind: str, params: tuple):
    """Device distance fn ``d(p, q, D) -> f32`` for a ``kernel_params``
    kind.  ``D`` is the explicit matrix for ``kind == "matrix"`` — float32
    or a lossless int8/int16 packing (the post-gather ``astype`` is exact
    for small integers, so both give bit-identical f32 distances) — and
    an ignored dummy for the closed forms (one uniform signature so the
    engine threads a single argument list through ``jit``/``vmap``)."""
    if kind == "tree":
        strides, dists = params

        def d(p, q, D):
            return _hier_distance(p, q, strides, dists)
    elif kind == "torus":
        dims, weights = params

        def d(p, q, D):
            return _torus_distance(p, q, dims, weights)
    elif kind == "matrix":
        def d(p, q, D):
            return D[p, q].astype(jnp.float32)
    else:
        raise ValueError(f"unknown kernel_params kind {kind!r}")
    return d


def edge_objective(kind: str, params: tuple, eu: jax.Array, ev: jax.Array,
                   ew: jax.Array, perm: jax.Array, D: jax.Array,
                   config: KernelConfig | None = None) -> jax.Array:
    """Σ w_e · D(perm[u_e], perm[v_e]) — the device-side objective.  Edge
    padding (w = 0) is inert; f32.

    Without a config (or when one edge tile covers the list — the derived
    CPU geometry) this is the flat fused reduction.  With a smaller tile
    it becomes a ``fori_loop`` over (block_rows · lanes)-element chunks:
    the perm gathers and the weighted distance are materialized one chunk
    at a time, so peak memory scales with the tile, not E.
    """
    d = distance_form(kind, params)
    e = eu.shape[0]
    chunk = config.block_rows * config.lanes if config is not None else None
    if chunk is None or chunk >= e:
        return jnp.sum(ew * d(perm[eu], perm[ev], D))
    acc_dtype = jnp.dtype(config.acc_dtype)
    e_pad = round_up(e, chunk)
    eu_c = pad1(eu, e_pad).reshape(-1, chunk)
    ev_c = pad1(ev, e_pad).reshape(-1, chunk)
    ew_c = pad1(ew, e_pad).reshape(-1, chunk)

    def body(i, acc):
        w = ew_c[i]
        return acc + jnp.sum(w * d(perm[eu_c[i]], perm[ev_c[i]], D),
                             dtype=acc_dtype)

    total = jax.lax.fori_loop(0, eu_c.shape[0], body,
                              jnp.zeros((), acc_dtype))
    return total.astype(jnp.float32)


def _side_weights(nbr_rows: jax.Array, wgt_rows: jax.Array,
                  other: jax.Array) -> jax.Array:
    """Fold the `k != other` exclusion into the weights (padding already
    carries w = 0)."""
    return jnp.where(nbr_rows == other[:, None], 0.0, wgt_rows)


# ------------------------------------------------------------------ jnp path
def pair_gains(kind: str, params: tuple, nbr: jax.Array, wgt: jax.Array,
               perm: jax.Array, us: jax.Array, vs: jax.Array,
               D: jax.Array, config: KernelConfig | None = None) -> jax.Array:
    """Exact swap gains for P candidate pairs, fused jnp (f32).

    ``nbr``/``wgt``: the (n, K) ELL arrays of a ``DeviceGraph``;
    ``perm``: (n,) process→PE; ``us``/``vs``: (P,) pair endpoints.
    Padding pairs with u == v yields exactly 0 (both sides cancel).
    Positive gain = objective decreases by that amount when swapped.

    With a config whose pair tile (``config.pair_tile(K)``) is smaller
    than P, the gather + row reduction runs tile-by-tile in a
    ``fori_loop``; every pair's gain is the same K-slot reduction either
    way, so tiled and fused results are bit-identical.
    """
    d = distance_form(kind, params)

    def gains_of(a, b):
        ta = perm[nbr[a]]                               # (p, K) PE targets
        wa = _side_weights(nbr[a], wgt[a], b)
        pa = jnp.broadcast_to(perm[a][:, None], ta.shape)
        pb = jnp.broadcast_to(perm[b][:, None], ta.shape)
        sa = jnp.sum(wa * (d(pa, ta, D) - d(pb, ta, D)), axis=1)
        tb = perm[nbr[b]]
        wb = _side_weights(nbr[b], wgt[b], a)
        qa = jnp.broadcast_to(perm[a][:, None], tb.shape)
        qb = jnp.broadcast_to(perm[b][:, None], tb.shape)
        return sa + jnp.sum(wb * (d(qb, tb, D) - d(qa, tb, D)), axis=1)

    p = us.shape[0]
    tile = config.pair_tile(nbr.shape[1]) if config is not None else None
    if tile is None or tile >= p:
        return gains_of(us, vs)
    p_pad = round_up(p, tile)
    us_p = pad1(us, p_pad)                              # (u, v) = (0, 0)
    vs_p = pad1(vs, p_pad)                              # padding: zero gain

    def body(i, out):
        a = jax.lax.dynamic_slice(us_p, (i * tile,), (tile,))
        b = jax.lax.dynamic_slice(vs_p, (i * tile,), (tile,))
        return jax.lax.dynamic_update_slice(out, gains_of(a, b), (i * tile,))

    out = jax.lax.fori_loop(0, p_pad // tile, body,
                            jnp.zeros((p_pad,), jnp.float32))
    return out[:p]


# --------------------------------------------------------------- Pallas path
def _side_kernel(pa_ref, pb_ref, t_ref, w_ref, out_ref, *, dist):
    """One (bp, K) row block: out[r] = Σ_k w[r,k]·(d(pa_r,t)−d(pb_r,t))."""
    t = t_ref[...]
    pa = jnp.broadcast_to(pa_ref[...], t.shape)
    pb = jnp.broadcast_to(pb_ref[...], t.shape)
    delta = dist(pa, t) - dist(pb, t)
    out_ref[...] = jnp.sum(w_ref[...] * delta, axis=1, keepdims=True)


def _diff_kernel(da_ref, db_ref, w_ref, out_ref):
    """Matrix-form row block: distances pre-gathered in the wrapper."""
    out_ref[...] = jnp.sum(w_ref[...] * (da_ref[...] - db_ref[...]),
                           axis=1, keepdims=True)


def _wdelta_kernel(delta_ref, w_ref, out_ref):
    """Quantized matrix-form row block: the exact integer distance
    difference is computed in the wrapper (int32 subtract of the narrow
    gathers, exact f32 convert); the kernel reduces w · Δ."""
    out_ref[...] = jnp.sum(w_ref[...] * delta_ref[...], axis=1,
                           keepdims=True)


def _pallas_side(kind: str, params: tuple, pa, pb, tgt, w, D,
                 interpret: bool, bp: int) -> jax.Array:
    """(P,) masked row-sum Σ w·(d(pa,·)−d(pb,·)) through a tiled kernel."""
    p, k = tgt.shape
    pp = round_up(p, bp)
    kp = round_up(k, _LANES)
    w_p = pad2(w.astype(jnp.float32), pp, kp)           # 0-pad kills terms
    grid = (pp // bp,)
    row_spec = pl.BlockSpec((bp, 1), lambda r: (r, 0))
    blk_spec = pl.BlockSpec((bp, kp), lambda r: (r, 0))
    out_shape = jax.ShapeDtypeStruct((pp, 1), jnp.float32)
    if kind == "matrix":
        da = D[pa[:, None], tgt]                        # XLA gathers: D may
        db = D[pb[:, None], tgt]                        # not fit VMEM
        if jnp.issubdtype(D.dtype, jnp.integer):
            # int gathers move 1-2 bytes/elem; the int32 difference is
            # exact and converts exactly to f32 (bit-identical gains)
            delta = (da.astype(jnp.int32) - db.astype(jnp.int32)).astype(
                jnp.float32)
            out = pl.pallas_call(
                _wdelta_kernel, grid=grid,
                in_specs=[blk_spec, blk_spec],
                out_specs=row_spec, out_shape=out_shape,
                interpret=interpret,
            )(pad2(delta, pp, kp), w_p)
        else:
            out = pl.pallas_call(
                _diff_kernel, grid=grid,
                in_specs=[blk_spec, blk_spec, blk_spec],
                out_specs=row_spec, out_shape=out_shape,
                interpret=interpret,
            )(pad2(da.astype(jnp.float32), pp, kp),
              pad2(db.astype(jnp.float32), pp, kp), w_p)
    else:
        d = distance_form(kind, params)
        out = pl.pallas_call(
            functools.partial(_side_kernel,
                              dist=lambda x, y: d(x, y, None)),
            grid=grid,
            in_specs=[row_spec, row_spec, blk_spec, blk_spec],
            out_specs=row_spec, out_shape=out_shape,
            interpret=interpret,
        )(pad2(pa[:, None].astype(jnp.int32), pp, 1),
          pad2(pb[:, None].astype(jnp.int32), pp, 1),
          pad2(tgt.astype(jnp.int32), pp, kp), w_p)
    return out[:p, 0]


def pair_gains_pallas(kind: str, params: tuple, nbr: jax.Array,
                      wgt: jax.Array, perm: jax.Array, us: jax.Array,
                      vs: jax.Array, D: jax.Array,
                      interpret: bool = False,
                      config: KernelConfig | None = None) -> jax.Array:
    """:func:`pair_gains`, with the masked row-sum reduction hand-tiled as
    a Pallas kernel ((block_rows, K) VMEM blocks, closed-form distances
    computed in-register; block_rows from the config, seed-era 8 without
    one).  Semantics identical to the jnp path (tested)."""
    bp = config.block_rows if config is not None else _BP

    def side(a, b):
        tgt = perm[nbr[a]]
        w = _side_weights(nbr[a], wgt[a], b)
        return _pallas_side(kind, params, perm[a], perm[b], tgt, w, D,
                            interpret, bp)

    return side(us, vs) + side(vs, us)
