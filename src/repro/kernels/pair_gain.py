"""Sparse per-pair swap-gain kernels over padded (ELL) neighbor rows.

The paper's central speedup is the O(deg(u) + deg(v)) incremental gain
(guide §2.1).  These kernels batch that sparse gain over P candidate
pairs at once, entirely on device, against the machine topology's
device-side distance form (``Topology.kernel_params()``):

    gain(u, v) = Σ_{k∈N(u)\\{v}} w_uk · (D(π_u, π_k) − D(π_v, π_k))
               + Σ_{k∈N(v)\\{u}} w_vk · (D(π_v, π_k) − D(π_u, π_k))

Neighbor rows come from :class:`repro.core.graph.DeviceGraph` — fixed-width
(n, K) arrays padded with zero-weight entries, so the gather ``nbr[us]``
is one dense (P, K) lookup and the masked row-sum vectorizes with no
ragged indexing.  The `v ∈ N(u)` exclusion and the row padding are both
folded into the weights (w = 0 kills the term), so the reduction itself
is branch-free.

Distance forms (the same three the edge-objective kernels use):
  tree    — in-register hierarchical oracle (strides, dists),
  torus   — closed-form k-ary n-cube ring distance (dims, weights),
  matrix  — explicit D: the (P, K) gathers run as XLA gathers in the
            wrapper, the kernel reduces the weighted difference.

Two interchangeable implementations (tested equal):
  * :func:`pair_gains` — fused jnp, traceable inside ``lax.while_loop``;
    the refinement engine's default (XLA fuses the gather + form + rowsum
    into one pass on CPU and TPU alike),
  * :func:`pair_gains_pallas` — hand-tiled Pallas kernel streaming (bp, K)
    row blocks through VMEM, for TPU runs where the candidate set is
    large enough that explicit tiling wins.

:func:`edge_objective` is the matching device-side objective
Σ w_e · D(π_u, π_v) used by the engine's on-device objective updates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .qap_objective import _hier_distance, _torus_distance

_LANES = 128      # lane-dim padding multiple for the Pallas row blocks
_BP = 8           # sublane rows per Pallas grid step


# ------------------------------------------------------------ distance forms
def distance_form(kind: str, params: tuple):
    """Device distance fn ``d(p, q, D) -> f32`` for a ``kernel_params``
    kind.  ``D`` is the explicit matrix for ``kind == "matrix"`` and an
    ignored dummy for the closed forms (one uniform signature so the
    engine threads a single argument list through ``jit``/``vmap``)."""
    if kind == "tree":
        strides, dists = params

        def d(p, q, D):
            return _hier_distance(p, q, strides, dists)
    elif kind == "torus":
        dims, weights = params

        def d(p, q, D):
            return _torus_distance(p, q, dims, weights)
    elif kind == "matrix":
        def d(p, q, D):
            return D[p, q]
    else:
        raise ValueError(f"unknown kernel_params kind {kind!r}")
    return d


def edge_objective(kind: str, params: tuple, eu: jax.Array, ev: jax.Array,
                   ew: jax.Array, perm: jax.Array, D: jax.Array) -> jax.Array:
    """Σ w_e · D(perm[u_e], perm[v_e]) — the device-side objective.  Edge
    padding (w = 0) is inert; f32."""
    d = distance_form(kind, params)
    return jnp.sum(ew * d(perm[eu], perm[ev], D))


def _side_weights(nbr_rows: jax.Array, wgt_rows: jax.Array,
                  other: jax.Array) -> jax.Array:
    """Fold the `k != other` exclusion into the weights (padding already
    carries w = 0)."""
    return jnp.where(nbr_rows == other[:, None], 0.0, wgt_rows)


# ------------------------------------------------------------------ jnp path
def pair_gains(kind: str, params: tuple, nbr: jax.Array, wgt: jax.Array,
               perm: jax.Array, us: jax.Array, vs: jax.Array,
               D: jax.Array) -> jax.Array:
    """Exact swap gains for P candidate pairs, fused jnp (f32).

    ``nbr``/``wgt``: the (n, K) ELL arrays of a ``DeviceGraph``;
    ``perm``: (n,) process→PE; ``us``/``vs``: (P,) pair endpoints.
    Padding pairs with u == v yields exactly 0 (both sides cancel).
    Positive gain = objective decreases by that amount when swapped.
    """
    d = distance_form(kind, params)

    def side(a, b):
        ta = perm[nbr[a]]                               # (P, K) PE targets
        wa = _side_weights(nbr[a], wgt[a], b)
        pa = jnp.broadcast_to(perm[a][:, None], ta.shape)
        pb = jnp.broadcast_to(perm[b][:, None], ta.shape)
        return jnp.sum(wa * (d(pa, ta, D) - d(pb, ta, D)), axis=1)

    return side(us, vs) + side(vs, us)


# --------------------------------------------------------------- Pallas path
def _side_kernel(pa_ref, pb_ref, t_ref, w_ref, out_ref, *, dist):
    """One (bp, K) row block: out[r] = Σ_k w[r,k]·(d(pa_r,t)−d(pb_r,t))."""
    t = t_ref[...]
    pa = jnp.broadcast_to(pa_ref[...], t.shape)
    pb = jnp.broadcast_to(pb_ref[...], t.shape)
    delta = dist(pa, t) - dist(pb, t)
    out_ref[...] = jnp.sum(w_ref[...] * delta, axis=1, keepdims=True)


def _diff_kernel(da_ref, db_ref, w_ref, out_ref):
    """Matrix-form row block: distances pre-gathered in the wrapper."""
    out_ref[...] = jnp.sum(w_ref[...] * (da_ref[...] - db_ref[...]),
                           axis=1, keepdims=True)


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _pallas_side(kind: str, params: tuple, pa, pb, tgt, w, D,
                 interpret: bool) -> jax.Array:
    """(P,) masked row-sum Σ w·(d(pa,·)−d(pb,·)) through a tiled kernel."""
    p, k = tgt.shape
    pp = -(-max(p, 1) // _BP) * _BP
    kp = -(-max(k, 1) // _LANES) * _LANES
    w_p = _pad2(w.astype(jnp.float32), pp, kp)          # 0-pad kills terms
    grid = (pp // _BP,)
    row_spec = pl.BlockSpec((_BP, 1), lambda r: (r, 0))
    blk_spec = pl.BlockSpec((_BP, kp), lambda r: (r, 0))
    out_shape = jax.ShapeDtypeStruct((pp, 1), jnp.float32)
    if kind == "matrix":
        da = D[pa[:, None], tgt]                        # XLA gathers: D may
        db = D[pb[:, None], tgt]                        # not fit VMEM
        out = pl.pallas_call(
            _diff_kernel, grid=grid,
            in_specs=[blk_spec, blk_spec, blk_spec],
            out_specs=row_spec, out_shape=out_shape,
            interpret=interpret,
        )(_pad2(da.astype(jnp.float32), pp, kp),
          _pad2(db.astype(jnp.float32), pp, kp), w_p)
    else:
        d = distance_form(kind, params)
        out = pl.pallas_call(
            functools.partial(_side_kernel,
                              dist=lambda x, y: d(x, y, None)),
            grid=grid,
            in_specs=[row_spec, row_spec, blk_spec, blk_spec],
            out_specs=row_spec, out_shape=out_shape,
            interpret=interpret,
        )(_pad2(pa[:, None].astype(jnp.int32), pp, 1),
          _pad2(pb[:, None].astype(jnp.int32), pp, 1),
          _pad2(tgt.astype(jnp.int32), pp, kp), w_p)
    return out[:p, 0]


def pair_gains_pallas(kind: str, params: tuple, nbr: jax.Array,
                      wgt: jax.Array, perm: jax.Array, us: jax.Array,
                      vs: jax.Array, D: jax.Array,
                      interpret: bool = False) -> jax.Array:
    """:func:`pair_gains`, with the masked row-sum reduction hand-tiled as
    a Pallas kernel ((bp, K) VMEM blocks, closed-form distances computed
    in-register).  Semantics identical to the jnp path (tested)."""

    def side(a, b):
        tgt = perm[nbr[a]]
        w = _side_weights(nbr[a], wgt[a], b)
        return _pallas_side(kind, params, perm[a], perm[b], tgt, w, D,
                            interpret)

    return side(us, vs) + side(vs, us)
