"""Pallas TPU kernel: fused causal/sliding-window flash attention (fwd).

The dry-run baselines show attention *score* tensors dominate HBM traffic
at 4k–32k sequence lengths (§Perf iteration 1): the pure-JAX blocked
attention writes (qb × kb) f32 score blocks to HBM every step; this kernel
keeps them in VMEM — per-block traffic drops from O(qb·kb) to
O((qb + kb)·hd).

Layout: grid (B·KV·G, q_blocks, kv_blocks), kv innermost (sequential on
TPU → the online-softmax accumulators live across steps in VMEM scratch):

    q: (B·KV·G, T, hd)  block (1, qb, hd)  index (i, qi)
    k: (B·KV, S, hd)    block (1, kb, hd)  index (i // G, ki)   [GQA share]
    v: like k
    o: like q, written at the last kv step

Causal + window masks come from absolute positions derived from block
indices.  MXU dims (qb, hd, kb) are multiples of 128 at production block
sizes (512, 128, 512); VMEM footprint ≈ (qb + 2·kb + 2·qb)·hd·4B ≈ 1 MiB.

Backward runs through the reference path (the models use this kernel via
``jax.custom_vjp`` with recompute), so train cells benefit in the
recomputed forward while prefill/serve get the full win.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, q_block: int, kv_block: int, window: int,
                  scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (qb, hd)
    k = k_ref[0].astype(jnp.float32)            # (kb, hd)
    v = v_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _flash_fwd(q, k, v, groups: int, *, window: int, q_block: int,
               kv_block: int, interpret: bool):
    """q: (B·KV·G, T, hd); k, v: (B·KV, S, hd)."""
    bkg, t, hd = q.shape
    s_len = k.shape[1]
    scale = hd ** -0.5
    qb = min(q_block, t)
    while t % qb:
        qb //= 2
    kb = min(kv_block, s_len)
    while s_len % kb:
        kb //= 2
    n_q, n_k = t // qb, s_len // kb
    g = groups

    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=n_k, q_block=qb,
                          kv_block=kb, window=window, scale=scale),
        grid=(bkg, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, qb, hd), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, kb, hd), lambda i, qi, ki: (i // g, ki, 0)),
            pl.BlockSpec((1, kb, hd), lambda i, qi, ki: (i // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, hd), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),    # m
            pltpu.VMEM((qb, 1), jnp.float32),    # l
            pltpu.VMEM((qb, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out


def flash_attention_kernel(q, k, v, *, window: int = 0,
                           q_block: int = 512, kv_block: int = 512,
                           interpret: bool | None = None):
    """Drop-in flash core.  q: (B, T, H, hd); k, v: (B, S, KV, hd) with
    self-attention positions (0..T−1 == 0..S−1).  Returns (B, T, H, hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, hd = q.shape
    s_len, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = (q.reshape(b, t, kvh, g, hd).transpose(0, 2, 3, 1, 4)
          .reshape(b * kvh * g, t, hd))
    kg = k.transpose(0, 2, 1, 3).reshape(b * kvh, s_len, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kvh, s_len, hd)
    og = _flash_fwd(qg, kg, vg, g, window=window, q_block=q_block,
                    kv_block=kv_block, interpret=interpret)
    return (og.reshape(b, kvh, g, t, hd).transpose(0, 3, 1, 2, 4)
            .reshape(b, t, h, hd))
