"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def swap_gain_matrix_ref(C: jax.Array, B: jax.Array) -> jax.Array:
    """Dense gain matrix: G[u,v] = M[u,u]+M[v,v]−M[u,v]−M[v,u]−2·C[u,v]·B[u,v],
    M = C @ Bᵀ; diagonal zeroed.  Mirrors objective.dense_gain_matrix."""
    C = C.astype(jnp.float32)
    B = B.astype(jnp.float32)
    M = C @ B.T
    d = jnp.diagonal(M)
    G = d[:, None] + d[None, :] - M - M.T - 2.0 * C * B
    n = C.shape[0]
    return G * (1.0 - jnp.eye(n, dtype=jnp.float32))


def hier_distance_ref(pu: jax.Array, pv: jax.Array,
                      strides: tuple, dists: tuple) -> jax.Array:
    """Online hierarchical distance oracle, jnp version."""
    out = jnp.zeros(jnp.broadcast_shapes(pu.shape, pv.shape), jnp.float32)
    k = len(dists)
    out = jnp.where(pu != pv, jnp.float32(dists[k - 1]), out)
    for lvl in range(k - 1, 0, -1):
        same = (pu // strides[lvl]) == (pv // strides[lvl])
        out = jnp.where(same & (pu != pv), jnp.float32(dists[lvl - 1]), out)
    return out


def qap_objective_edges_ref(pu: jax.Array, pv: jax.Array, w: jax.Array,
                            strides: tuple, dists: tuple) -> jax.Array:
    return jnp.sum(w.astype(jnp.float32)
                   * hier_distance_ref(pu, pv, strides, dists))
