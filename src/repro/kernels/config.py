"""`KernelConfig` — the accelerator-geometry knobs of the kernel layer.

Every hot kernel in this package (the sparse pair-gain reduction, the
edge-list objective, their Pallas forms) used to carry hardcoded seed-era
geometry: 8 sublane rows per grid step, 1024-lane reduction rows, float32
everywhere, float32 distance gathers.  A :class:`KernelConfig` makes that
geometry an explicit, serializable artifact selected at ``Mapper.lower``
time from the plan's :class:`~repro.core.spec.ShapeBucket` and the jax
backend, cached inside the :class:`~repro.core.plan.MappingPlan`, and
reported via ``plan.describe()["kernels"]``:

  block_rows — rows per reduction tile.  Tiles are *byte-homogeneous*:
      a pair-gain tile is (block_rows · lanes / K) candidate rows of K
      neighbor slots and an edge tile is (block_rows, lanes) lanes, so
      one knob bounds peak VMEM for both paths.  Pallas grids stream
      (block_rows, K) blocks; the jnp paths ``fori_loop`` over tiles of
      the same byte budget instead of materializing the full padded row.
  lanes      — lane width of the edge-reduction rows (the last-dim
      multiple; clamped down for tiny edge lists by the pad helpers).
  acc_dtype  — accumulation dtype of the tiled reductions ("float32";
      "float64" is accepted for host-side experiments when x64 is on).
  dist_dtype — packed distance-table dtype for matrix-form topologies:
      None (float32 gathers) or "int8"/"int16" — lossless packings
      selected by :func:`quantize_table` when the table is exact small
      integers, cutting the gather path's bytes-moved 4×/2× with
      bit-identical gains (the integer differences are exact in f32).

Derivation is deliberately backend-aware: on TPU the tile budget tracks
VMEM (~256 KiB per operand tile) so large instances stream; on CPU the
budget is large enough that every benchmarked instance fits one tile and
the tiled path lowers to exactly the fused-jnp reduction (same
wall-time, same bits).  Explicit overrides (``MappingSpec.kernel``) win
over derivation, which is what the tile-geometry parity tests sweep.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

# per-operand tile byte budgets: TPU tracks VMEM (a handful of
# (block_rows, lanes) f32 operands must fit comfortably in ~16 MiB);
# CPU just bounds temporaries (XLA fuses whole-array reductions well, so
# a budget that covers benchmarked sizes keeps the tiled path identical
# to the fused one there)
_TILE_BYTES = {"tpu": 1 << 18}
_TILE_BYTES_DEFAULT = 1 << 21

_QUANT_MODES = ("auto", "off", "int8", "int16")
_INT_RANGE = {"int8": 127, "int16": 32767}


def _pow2_at_most(x: int) -> int:
    return 1 << max(int(x), 1).bit_length() - 1


def _pow2_at_least(x: int) -> int:
    return 1 << (max(int(x), 1) - 1).bit_length()


@dataclass(frozen=True)
class KernelConfig:
    """Concrete kernel geometry for ONE compiled pipeline level (see
    module docstring).  Hashable — engine pools and plan caches key on
    ``key()``."""

    block_rows: int = 8
    lanes: int = 1024
    acc_dtype: str = "float32"
    dist_dtype: str | None = None

    def validate(self) -> "KernelConfig":
        if self.block_rows < 1:
            raise ValueError("KernelConfig.block_rows must be >= 1")
        if self.lanes < 128 or self.lanes % 128:
            raise ValueError("KernelConfig.lanes must be a positive "
                             "multiple of 128")
        if self.acc_dtype not in ("float32", "float64"):
            raise ValueError(f"unknown acc_dtype {self.acc_dtype!r}; "
                             f"choose 'float32' or 'float64'")
        if self.dist_dtype not in (None, "int8", "int16"):
            raise ValueError(f"unknown dist_dtype {self.dist_dtype!r}; "
                             f"choose None, 'int8', or 'int16'")
        return self

    # ------------------------------------------------------------- identity
    def key(self) -> tuple:
        return (self.block_rows, self.lanes, self.acc_dtype,
                self.dist_dtype)

    def tag(self) -> str:
        q = self.dist_dtype or "f32"
        return f"b{self.block_rows}:l{self.lanes}:{self.acc_dtype}:{q}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown KernelConfig keys {unknown}; "
                             f"known keys: {sorted(known)}")
        return cls(**d).validate()

    def replace(self, **changes) -> "KernelConfig":
        return dataclasses.replace(self, **changes).validate()

    # ------------------------------------------------------------- geometry
    def pair_tile(self, k_pad: int) -> int:
        """Rows per pair-gain tile: the byte-homogeneous row count
        (block_rows · lanes / K, at least block_rows) so a (rows, K)
        pair tile costs the same bytes as a (block_rows, lanes) edge
        tile."""
        return self.block_rows * max(1, self.lanes // max(k_pad, 1))


def quantize_table(D, mode: str = "auto"):
    """Lossless packed form of a distance table, or ``None``.

    Returns ``(packed int array, dtype name)`` when every entry of ``D``
    is an exact integer inside the target width's range — the
    Schulz–Träff integer-distance structure every registered topology
    satisfies at benchmarked sizes — else ``None`` (``mode="auto"``) or
    a ``ValueError`` naming the loss (explicit ``"int8"``/``"int16"``:
    a forced packing must never silently change results).
    """
    if mode not in _QUANT_MODES:
        raise ValueError(f"unknown quantize mode {mode!r}; choose from "
                         f"{list(_QUANT_MODES)}")
    if mode == "off":
        return None
    D = np.asarray(D)
    integral = bool(np.all(D == np.rint(D)))
    lo, hi = (float(D.min()), float(D.max())) if D.size else (0.0, 0.0)
    if mode == "auto":
        if not integral:
            return None
        for dt in ("int8", "int16"):
            if -_INT_RANGE[dt] - 1 <= lo and hi <= _INT_RANGE[dt]:
                return np.asarray(np.rint(D), dtype=dt), dt
        return None
    if not integral:
        raise ValueError(f"cannot pack distance table to {mode}: entries "
                         f"are not exact integers (quantize='auto' falls "
                         f"back to float32)")
    if lo < -_INT_RANGE[mode] - 1 or hi > _INT_RANGE[mode]:
        raise ValueError(f"cannot pack distance table to {mode}: range "
                         f"[{lo:g}, {hi:g}] exceeds ±{_INT_RANGE[mode]}")
    return np.asarray(np.rint(D), dtype=mode), mode


def derive_kernel_config(kind: str, bucket=None, backend: str | None = None,
                         table=None, block_rows: int | None = None,
                         lanes: int | None = None,
                         acc_dtype: str | None = None,
                         quantize: str = "auto") -> KernelConfig:
    """Select the kernel geometry for one (distance form, bucket,
    backend) — the ``Mapper.lower``-time hook.

    ``bucket`` is the plan's :class:`~repro.core.spec.ShapeBucket` (or
    ``None`` for dynamic plans → seed-era defaults); ``table`` is the
    materialized distance matrix for ``kind == "matrix"`` (quantization
    candidate); the keyword overrides are the serialized knobs of
    :class:`~repro.core.spec.KernelSpec` and win over derivation.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    budget = _TILE_BYTES.get(backend, _TILE_BYTES_DEFAULT)
    e = bucket.num_edges if bucket is not None else 128
    k = bucket.max_deg if bucket is not None else 8
    k_pad = _pow2_at_least(max(k, 128))          # lane-padded ELL width
    if lanes is None:
        # ~8 reduction rows over the bucket's padded edge list, clamped
        # to the backend's tile budget (pad_to_lanes clamps small E down
        # again at call time, so oversizing here is free)
        lanes = min(max(budget // 4 // max(1, _pow2_at_least(8)), 128),
                    max(128, _pow2_at_least(-(-e // 8))))
        lanes = min(lanes, 8192 if backend != "tpu" else 1024)
        lanes = max(128, (lanes // 128) * 128)
    if block_rows is None:
        width = max(k_pad, lanes)
        block_rows = int(np.clip(_pow2_at_most(budget // (width * 4)),
                                 8, 4096))
    dist_dtype = None
    if kind == "matrix" and table is not None:
        packed = quantize_table(table, quantize)
        if packed is not None:
            dist_dtype = packed[1]
    return KernelConfig(block_rows=int(block_rows), lanes=int(lanes),
                        acc_dtype=acc_dtype or "float32",
                        dist_dtype=dist_dtype).validate()


def table_bytes(n_pe: int, dist_dtype: str | None) -> int:
    """Bytes of one n×n distance table under a packing — the bench's
    bytes-moved accounting for the gather path."""
    itemsize = {"int8": 1, "int16": 2, None: 4}[dist_dtype]
    return n_pe * n_pe * itemsize
