"""Pallas TPU kernel: full pair-exchange gain matrix for the QAP.

The hot spot the paper optimizes is (re)computing swap gains.  On TPU the
mesh-mapping instance (n = 512 … 8192 logical devices) admits a dense
MXU formulation (DESIGN §3): with B[u,v] = D[perm[u], perm[v]] and
M = C @ B.T,

    G[u,v] = M[u,u] + M[v,v] − M[u,v] − M[v,u] − 2·C[u,v]·B[u,v]

(G[u,v] > 0 ⇔ swapping PEs of u and v improves the objective by G[u,v]).

Kernel layout: grid (i, j, k) over T×T tiles, k innermost (sequential on
TPU, so VMEM scratch accumulates across k):

    acc  += C[i,k] @ B[j,k]ᵀ + B[i,k] @ C[j,k]ᵀ      (M[i,j] + M[j,i])
    d_i  += rowsum(C[i,k] ∘ B[i,k])                   (diag contributions)
    d_j  += rowsum(C[j,k] ∘ B[j,k])
    corr  = 2·C[i,k] ∘ B[i,k]      when k == j        (the C∘B (i,j) tile)

finalize at k == K−1:  G[i,j] = d_i + d_jᵀ − acc − corr, diagonal zeroed.

Square tiles (bm == bn == bk == T) make the k == j slice of the C[i,·]/
B[i,·] operands exactly the (i, j) tile needed for the elementwise
correction.  VMEM footprint: 4 input tiles + out + 2 big scratch + 2 row
scratch ≈ 7·T²·4 B ≈ 460 KiB at T = 128 — comfortably inside v5e VMEM,
and all matmul dims are multiples of 128 (MXU-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swap_gain_kernel(ci_ref, bi_ref, cj_ref, bj_ref, out_ref,
                      acc_ref, di_ref, dj_ref, corr_ref, *, k_steps: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        di_ref[...] = jnp.zeros_like(di_ref)
        dj_ref[...] = jnp.zeros_like(dj_ref)
        corr_ref[...] = jnp.zeros_like(corr_ref)

    ci = ci_ref[...]
    bi = bi_ref[...]
    cj = cj_ref[...]
    bj = bj_ref[...]

    # M[i,j] + M[j,i] accumulation — two MXU contractions over k
    acc_ref[...] += (
        jax.lax.dot_general(ci, bj, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(bi, cj, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32))
    # diagonal terms d[u] = Σ_k C[u,k]·B[u,k]
    di_ref[...] += jnp.sum(ci * bi, axis=1, keepdims=True)
    dj_ref[...] += jnp.sum(cj * bj, axis=1, keepdims=True)

    # elementwise correction tile 2·C[i,j] ∘ B[i,j] materializes at k == j
    @pl.when(k == j)
    def _corr():
        corr_ref[...] = 2.0 * ci * bi

    @pl.when(k == k_steps - 1)
    def _finalize():
        g = (di_ref[...] + dj_ref[...].T
             - acc_ref[...] - corr_ref[...])
        t = g.shape[0]

        @pl.when(i == j)
        def _mask():
            rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
            out_ref[...] = jnp.where(rows == cols, 0.0, g)

        @pl.when(i != j)
        def _nomask():
            out_ref[...] = g


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def swap_gain_matrix(C: jax.Array, B: jax.Array, tile: int = 128,
                     interpret: bool = False) -> jax.Array:
    """Full gain matrix G (n×n, f32) from communication matrix C and the
    permuted distance matrix B[u,v] = D[perm[u], perm[v]].

    n is padded to a tile multiple; the zero padding contributes zero to
    every term, and padded rows/cols are sliced off the result.
    """
    n = C.shape[0]
    if C.shape != (n, n) or B.shape != (n, n):
        raise ValueError(f"C and B must be (n, n), got {C.shape}, {B.shape}")
    t = min(tile, max(8, n))
    n_pad = -(-n // t) * t
    Cp = jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(
        C.astype(jnp.float32))
    Bp = jnp.zeros((n_pad, n_pad), jnp.float32).at[:n, :n].set(
        B.astype(jnp.float32))
    steps = n_pad // t
    out = pl.pallas_call(
        functools.partial(_swap_gain_kernel, k_steps=steps),
        grid=(steps, steps, steps),
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),   # C[i, k]
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),   # B[i, k]
            pl.BlockSpec((t, t), lambda i, j, k: (j, k)),   # C[j, k]
            pl.BlockSpec((t, t), lambda i, j, k: (j, k)),   # B[j, k]
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((t, t), jnp.float32),   # acc
            pltpu.VMEM((t, 1), jnp.float32),   # d_i
            pltpu.VMEM((t, 1), jnp.float32),   # d_j
            pltpu.VMEM((t, t), jnp.float32),   # corr
        ],
        interpret=interpret,
    )(Cp, Bp, Cp, Bp)
    return out[:n, :n]
