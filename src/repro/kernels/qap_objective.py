"""Pallas TPU kernel: sparse QAP objective over an edge list.

J(C, D, Π) = Σ_{e=(u,v)} w_e · D(Π(u), Π(v)) — the paper's O(m) evaluation
(guide §2.1) with the *online* hierarchical distance oracle computed
arithmetically in-register (guide's `hierarchyonline`): no n×n distance
matrix, no gather — the hierarchy levels k are small and static, so the
oracle unrolls to k compare/select steps on the VPU.

Inputs are pre-gathered PE ids pu = Π[u], pv = Π[v] (the gather is done in
the jit'd wrapper; XLA handles it well) shaped (rows, L) so each grid step
streams one (1, L) lane-aligned block from VMEM and accumulates a partial
sum in SMEM scratch; the single grid dimension is sequential on TPU which
makes the scalar accumulation race-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hier_distance(pu, pv, strides, dists):
    """Vector online distance oracle: d = dists[lca_level-1], 0 if equal."""
    out = jnp.zeros(pu.shape, jnp.float32)
    k = len(dists)
    # from the top level down: overwrite with smaller distances when the
    # pair is in the same subtree at that level
    out = jnp.where(pu != pv, jnp.float32(dists[k - 1]), out)
    for lvl in range(k - 1, 0, -1):
        same = (pu // strides[lvl]) == (pv // strides[lvl])
        out = jnp.where(same & (pu != pv), jnp.float32(dists[lvl - 1]), out)
    return out


def _qap_obj_kernel(pu_ref, pv_ref, w_ref, out_ref, acc_ref, *,
                    strides: tuple, dists: tuple, rows: int):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        acc_ref[0, 0] = 0.0

    pu = pu_ref[...]
    pv = pv_ref[...]
    w = w_ref[...]
    d = _hier_distance(pu, pv, strides, dists)
    acc_ref[0, 0] += jnp.sum(w * d)

    @pl.when(r == rows - 1)
    def _done():
        out_ref[0, 0] = acc_ref[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("strides", "dists", "lanes", "interpret"))
def qap_objective_edges(pu: jax.Array, pv: jax.Array, w: jax.Array,
                        strides: tuple, dists: tuple,
                        lanes: int = 1024, interpret: bool = False
                        ) -> jax.Array:
    """Σ w_e · D(pu_e, pv_e) with the hierarchy (strides, dists).

    pu, pv: (E,) int32 PE ids; w: (E,) f32.  Padded with pu == pv (distance
    0) to a lane multiple and reshaped to (rows, lanes).
    """
    e = pu.shape[0]
    lanes = min(lanes, max(128, 1 << (max(e - 1, 1)).bit_length()))
    e_pad = -(-max(e, 1) // lanes) * lanes
    pad = e_pad - e
    pu_p = jnp.pad(pu.astype(jnp.int32), (0, pad)).reshape(-1, lanes)
    pv_p = jnp.pad(pv.astype(jnp.int32), (0, pad)).reshape(-1, lanes)
    w_p = jnp.pad(w.astype(jnp.float32), (0, pad)).reshape(-1, lanes)
    rows = pu_p.shape[0]
    out = pl.pallas_call(
        functools.partial(_qap_obj_kernel, strides=tuple(strides),
                          dists=tuple(dists), rows=rows),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, lanes), lambda r: (r, 0)),
            pl.BlockSpec((1, lanes), lambda r: (r, 0)),
            pl.BlockSpec((1, lanes), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda r: (0, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(pu_p, pv_p, w_p)
    return out[0, 0]
