"""Pallas TPU kernels: sparse QAP objective over an edge list.

J(C, D, Π) = Σ_{e=(u,v)} w_e · D(Π(u), Π(v)) — the paper's O(m) evaluation
(guide §2.1) with the distance oracle in one of three device-side forms,
selected by the machine topology's ``kernel_params()``:

  tree    — online hierarchical oracle computed arithmetically in-register
            (guide's `hierarchyonline`): the k levels are small and static,
            so the oracle unrolls to k compare/select steps on the VPU,
  torus   — closed-form k-ary n-cube oracle: per-axis div/mod coordinates
            and ring distance, unrolled over the (static) axes — like the
            tree path, large n never materializes an n×n matrix anywhere,
  matrix  — explicit-D topologies: the (E,)-gather d_e = D[pu_e, pv_e]
            runs in the jit'd wrapper (XLA's gather is the right tool; D
            may exceed VMEM), and the Pallas kernel reduces Σ w_e · d_e.
            D may be a lossless int8/int16 packing — the gather then
            moves 1–2 bytes per edge instead of 4 and the post-gather
            f32 convert is exact, so the objective is bit-identical.

Inputs are pre-gathered PE ids pu = Π[u], pv = Π[v] (the gather is done in
the jit'd wrapper; XLA handles it well) shaped (rows, L) so each grid step
streams one (block_rows, L) lane-aligned block from VMEM and accumulates a
partial sum in SMEM scratch; the single grid dimension is sequential on
TPU which makes the scalar accumulation race-free.  ``lanes`` and
``block_rows`` come from the plan's :class:`~repro.kernels.config
.KernelConfig` (seed-era (1, 1024) without one); peak VMEM per step is
the (block_rows, lanes) tile, independent of E.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pad import pad_to_lanes as _pad_to_lanes


def _hier_distance(pu, pv, strides, dists):
    """Vector online distance oracle: d = dists[lca_level-1], 0 if equal."""
    out = jnp.zeros(pu.shape, jnp.float32)
    k = len(dists)
    # from the top level down: overwrite with smaller distances when the
    # pair is in the same subtree at that level
    out = jnp.where(pu != pv, jnp.float32(dists[k - 1]), out)
    for lvl in range(k - 1, 0, -1):
        same = (pu // strides[lvl]) == (pv // strides[lvl])
        out = jnp.where(same & (pu != pv), jnp.float32(dists[lvl - 1]), out)
    return out


def _torus_distance(pu, pv, dims, weights):
    """Closed-form k-ary n-cube oracle: Σ_a w_a · ring(|x_a − y_a|, k_a).
    Axis 0 is innermost in the PE index (mixed radix); the per-axis
    div/mod unrolls over the static axis list on the VPU."""
    out = jnp.zeros(pu.shape, jnp.float32)
    stride = 1
    for d, w in zip(dims, weights):
        xa = (pu // stride) % d
        ya = (pv // stride) % d
        delta = jnp.abs(xa - ya)
        out += jnp.float32(w) * jnp.minimum(delta, d - delta).astype(
            jnp.float32)
        stride *= d
    return out


def _qap_obj_kernel(pu_ref, pv_ref, w_ref, out_ref, acc_ref, *,
                    strides: tuple, dists: tuple, steps: int):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        acc_ref[0, 0] = 0.0

    pu = pu_ref[...]
    pv = pv_ref[...]
    w = w_ref[...]
    d = _hier_distance(pu, pv, strides, dists)
    acc_ref[0, 0] += jnp.sum(w * d)

    @pl.when(r == steps - 1)
    def _done():
        out_ref[0, 0] = acc_ref[0, 0]


def _qap_obj_torus_kernel(pu_ref, pv_ref, w_ref, out_ref, acc_ref, *,
                          dims: tuple, weights: tuple, steps: int):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        acc_ref[0, 0] = 0.0

    d = _torus_distance(pu_ref[...], pv_ref[...], dims, weights)
    acc_ref[0, 0] += jnp.sum(w_ref[...] * d)

    @pl.when(r == steps - 1)
    def _done():
        out_ref[0, 0] = acc_ref[0, 0]


def _weighted_sum_kernel(d_ref, w_ref, out_ref, acc_ref, *, steps: int):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        acc_ref[0, 0] = 0.0

    acc_ref[0, 0] += jnp.sum(w_ref[...] * d_ref[...])

    @pl.when(r == steps - 1)
    def _done():
        out_ref[0, 0] = acc_ref[0, 0]


def _reduce_call(kernel, blocks, block_rows: int, lanes: int,
                 interpret: bool):
    """Shared pallas_call shape for the three reductions: stream
    (block_rows, lanes) tiles down a sequential grid, accumulate one
    scalar in SMEM scratch."""
    rows = blocks[0].shape[0]
    steps = rows // block_rows
    out = pl.pallas_call(
        functools.partial(kernel, steps=steps),
        grid=(steps,),
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda r: (r, 0))
                  for _ in blocks],
        out_specs=pl.BlockSpec((1, 1), lambda r: (0, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(*blocks)
    return out[0, 0]


@functools.partial(jax.jit,
                   static_argnames=("strides", "dists", "lanes",
                                    "block_rows", "interpret"))
def qap_objective_edges(pu: jax.Array, pv: jax.Array, w: jax.Array,
                        strides: tuple, dists: tuple,
                        lanes: int = 1024, block_rows: int = 1,
                        interpret: bool = False) -> jax.Array:
    """Σ w_e · D(pu_e, pv_e) with the hierarchy (strides, dists).

    pu, pv: (E,) int32 PE ids; w: (E,) f32.  Padded with pu == pv (distance
    0) to a lane multiple and reshaped to (rows, lanes).
    """
    e = pu.shape[0]
    pu_p, pv_p, w_p = _pad_to_lanes(
        [pu.astype(jnp.int32), pv.astype(jnp.int32),
         w.astype(jnp.float32)], e, lanes, block_rows)
    kernel = functools.partial(_qap_obj_kernel, strides=tuple(strides),
                               dists=tuple(dists))
    return _reduce_call(kernel, [pu_p, pv_p, w_p], block_rows,
                        pu_p.shape[1], interpret)


@functools.partial(jax.jit,
                   static_argnames=("dims", "weights", "lanes",
                                    "block_rows", "interpret"))
def qap_objective_edges_torus(pu: jax.Array, pv: jax.Array, w: jax.Array,
                              dims: tuple, weights: tuple,
                              lanes: int = 1024, block_rows: int = 1,
                              interpret: bool = False) -> jax.Array:
    """Σ w_e · D_torus(pu_e, pv_e) for the k-ary n-cube (dims, weights)."""
    e = pu.shape[0]
    pu_p, pv_p, w_p = _pad_to_lanes(
        [pu.astype(jnp.int32), pv.astype(jnp.int32),
         w.astype(jnp.float32)], e, lanes, block_rows)
    kernel = functools.partial(_qap_obj_torus_kernel, dims=tuple(dims),
                               weights=tuple(weights))
    return _reduce_call(kernel, [pu_p, pv_p, w_p], block_rows,
                        pu_p.shape[1], interpret)


@functools.partial(jax.jit,
                   static_argnames=("lanes", "block_rows", "interpret"))
def qap_objective_edges_matrix(pu: jax.Array, pv: jax.Array, w: jax.Array,
                               D: jax.Array, lanes: int = 1024,
                               block_rows: int = 1,
                               interpret: bool = False) -> jax.Array:
    """Σ w_e · D[pu_e, pv_e] for an explicit distance matrix.

    The per-edge gather runs as an XLA gather in this wrapper (D may not
    fit VMEM, and XLA pipelines HBM gathers well); the Pallas kernel does
    the lane-aligned weighted reduction.  Gather-then-convert keeps the
    table in its storage dtype — an int8/int16 packing moves 1–2 bytes
    per edge and converts exactly, bit-identical to a float32 table.
    """
    e = pu.shape[0]
    d = D[pu, pv].astype(jnp.float32)
    d_p, w_p = _pad_to_lanes([d, w.astype(jnp.float32)], e, lanes,
                             block_rows)
    return _reduce_call(_weighted_sum_kernel, [d_p, w_p], block_rows,
                        d_p.shape[1], interpret)
