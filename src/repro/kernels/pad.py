"""Shared padding helpers for every kernel entry point.

All device kernels in this package consume *padded* arrays: ELL neighbor
rows, edge lists, candidate-pair lists, and lane-aligned reduction
blocks.  The padding invariants are the foundation of the plan/bucket
machinery (``ShapeBucket`` padding must be inert), so the helpers live
in ONE place and every kernel wrapper — ``pair_gain``,
``qap_objective``, the contraction feeders in
:mod:`repro.multilevel.coarsen`, and :class:`repro.core.graph.DeviceGraph`
— pads through them:

  * zero padding is inert for every distance form: an edge (0, 0, w=0)
    contributes w·D(p0, p0) = 0, a neighbor slot with w = 0 kills its
    term, and a candidate pair (u, u) has exactly zero gain;
  * padding only ever *appends* — the live prefix of an array never
    moves, so reductions visit live elements in the same order
    regardless of how much padding follows (what makes results
    bit-identical across tight/pow2/oversized buckets).
"""

from __future__ import annotations


def round_up(x: int, quantum: int) -> int:
    """The smallest multiple of ``quantum`` that is >= max(x, 1)."""
    return -(-max(int(x), 1) // quantum) * quantum


def pad1(a, length: int):
    """Zero-pad a 1-D array (jnp or numpy-compatible) to ``length``."""
    import jax.numpy as jnp
    return jnp.pad(a, (0, length - a.shape[0]))


def pad2(a, rows: int, cols: int):
    """Zero-pad a 2-D array to (rows, cols)."""
    import jax.numpy as jnp
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def pad_to_lanes(arrs, e: int, lanes: int, block_rows: int = 1):
    """Zero-pad 1-D edge arrays of live length ``e`` to a lane multiple
    and reshape each to (rows, lanes), rows a multiple of ``block_rows``
    (so a Pallas grid can stream (block_rows, lanes) tiles without a
    ragged tail).  The lane width is clamped so tiny edge lists do not
    blow up into one enormous padded row.  Zero padding is inert for
    every oracle form: pu == pv == 0 gives distance 0 for
    tree/torus/matrix, and w == 0 kills the term regardless."""
    lanes = min(lanes, max(128, 1 << (max(e - 1, 1)).bit_length()))
    rows = round_up(round_up(e, lanes) // lanes, block_rows)
    e_pad = rows * lanes
    return [pad1(a, e_pad).reshape(rows, lanes) for a in arrs]


def pad_edge_arrays(u, v, w, base: int = 128):
    """Host edge triplet → padded device arrays (eu, ev, ew): int32
    endpoints, float32 weights, length rounded up to a ``base`` multiple
    with inert (0, 0, 0.0) padding.  The one idiom behind
    ``DeviceGraph.from_comm`` and the contraction feeder in
    :mod:`repro.multilevel.coarsen`."""
    import jax.numpy as jnp
    import numpy as np
    u = np.asarray(u)
    e = round_up(len(u), base)
    pad = e - len(u)
    return (jnp.asarray(np.pad(u, (0, pad)).astype(np.int32)),
            jnp.asarray(np.pad(np.asarray(v), (0, pad)).astype(np.int32)),
            jnp.asarray(np.pad(np.asarray(w), (0, pad)).astype(np.float32)))
