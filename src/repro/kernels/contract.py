"""Device-side graph coarsening: heavy-edge matching + edge collapsing.

The multilevel V-cycle (:mod:`repro.multilevel`) contracts the
communication graph level by level.  Both halves of one contraction run
as fixed-shape, padding-inert jnp ops over the padded edge arrays of a
:class:`~repro.core.graph.DeviceGraph` (``eu``/``ev``/``ew``, each
undirected edge once, zero-weight padding):

  1. **Matching** — greedy *maximal* matching by the classic heavy-edge
     rating r(e) = w(e) / min(deg u, deg v) (the sorted-rating rule of the
     host partitioner, guide §2.2), realized with the refinement engine's
     conflict-matching pattern: rounds of locally-dominant edges (highest
     rating at both endpoints, ties toward the lowest edge index) selected
     via scatter-max / scatter-min inside a ``lax.while_loop``.  Leftover
     unmatched vertices are then force-paired in index order, so the
     matching is a *perfect pairing* whenever n is even — every coarse
     vertex aggregates exactly two fine vertices, which is what lets the
     V-cycle pair the machine side symmetrically and keep permutation
     projection a bijection at every level.
  2. **Collapsing** — map edge endpoints through the coarse labels, kill
     intra-pair edges and padding (weight → 0), then merge duplicate
     coarse edges by a sort + segment-sum: sort the (lo·n + hi) keys,
     segment ids from run heads, one ``scatter-add`` of the sorted
     weights.  Output arrays keep the padded length E; dead slots carry
     (0, 0, 0.0) — inert under any distance form, and invariant under
     *further* edge padding (live keys sort before the sentinel, so their
     segment ids — and hence the live prefix of the output — do not move).

Everything is shape-static and jittable; the host only syncs at level
boundaries to assemble the next level's :class:`CommGraph` (sparse-gain
economics per Paul's robust tabu search for sparse QAP: the coarse levels
shrink both n and the padded ELL degree, so they are cheap).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# int32 edge keys are lo*n + hi with a sentinel at n*n: n must stay below
# floor(sqrt(2^31 - 1)); the host wrappers enforce it.
MAX_N = 46_340


def edge_ratings(eu: jax.Array, ev: jax.Array, ew: jax.Array,
                 n: int) -> jax.Array:
    """Heavy-edge ratings r(e) = w(e) / min(deg u, deg v); padding
    (w = 0) rates 0.  Degrees are counted from the live edges."""
    live = (ew > 0).astype(jnp.float32)
    deg = jnp.zeros((n,), jnp.float32).at[eu].add(live).at[ev].add(live)
    mindeg = jnp.maximum(jnp.minimum(deg[eu], deg[ev]), 1.0)
    return jnp.where(ew > 0, ew / mindeg, 0.0)


def heavy_edge_matching(eu: jax.Array, ev: jax.Array, ew: jax.Array,
                        n: int) -> jax.Array:
    """Perfect pairing of ``n`` (even) vertices: greedy maximal matching
    by heavy-edge rating priority, then forced index-order pairing of the
    leftovers.  Returns ``match`` (n,) int32 — an involution with
    ``match[u] != u`` for every vertex."""
    e = eu.shape[0]
    rating = edge_ratings(eu, ev, ew, n)
    pos = rating > 0
    idx = jnp.arange(e, dtype=jnp.int32)
    oob = jnp.int32(n)                           # scatter-drop index

    def cond(state):
        match, used = state
        return jnp.any(pos & ~used[eu] & ~used[ev])

    def body(state):
        match, used = state
        elig = pos & ~used[eu] & ~used[ev]
        re = jnp.where(elig, rating, -jnp.inf)
        vmax = jnp.full((n,), -jnp.inf, jnp.float32)
        vmax = vmax.at[eu].max(re).at[ev].max(re)
        cand = elig & (re >= vmax[eu]) & (re >= vmax[ev])
        vmin = jnp.full((n,), e, jnp.int32)
        masked_idx = jnp.where(cand, idx, e)
        vmin = vmin.at[eu].min(masked_idx).at[ev].min(masked_idx)
        new = cand & (vmin[eu] == idx) & (vmin[ev] == idx)
        match = match.at[jnp.where(new, eu, oob)].set(
            ev.astype(jnp.int32), mode="drop")
        match = match.at[jnp.where(new, ev, oob)].set(
            eu.astype(jnp.int32), mode="drop")
        used = used.at[jnp.where(new, eu, oob)].set(True, mode="drop")
        used = used.at[jnp.where(new, ev, oob)].set(True, mode="drop")
        return match, used

    match0 = jnp.arange(n, dtype=jnp.int32)
    match, used = jax.lax.while_loop(
        cond, body, (match0, jnp.zeros((n,), jnp.bool_)))

    # forced pairing: the unmatched vertices, in index order, pair up
    # consecutively (rank r partners rank r^1) — n even keeps their count
    # even, so nobody is left single
    free = ~used
    rank = jnp.cumsum(free.astype(jnp.int32)) - 1
    byrank = jnp.zeros((n,), jnp.int32).at[
        jnp.where(free, rank, oob)].set(match0, mode="drop")
    return jnp.where(free, byrank[rank ^ 1], match)


def labels_of_matching(match: jax.Array) -> jax.Array:
    """Coarse labels of a perfect pairing: pairs are numbered by the
    order of their smaller endpoint, so labels are 0..n/2-1 and
    deterministic.  (n,) int32."""
    n = match.shape[0]
    ids = jnp.arange(n, dtype=match.dtype)
    rep = jnp.minimum(ids, match)
    is_rep = rep == ids
    lab_of_rep = jnp.cumsum(is_rep.astype(jnp.int32)) - 1
    return lab_of_rep[rep]


def contract_edges(eu: jax.Array, ev: jax.Array, ew: jax.Array,
                   labels: jax.Array, n: int
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Segment-sum edge collapsing: coarse edge arrays of the same padded
    length E, duplicate coarse edges merged, intra-cluster edges
    (self-loops) and padding dead (endpoints (0, 0), weight 0.0).  The
    live prefix is invariant under further (0, 0, 0.0) edge padding."""
    e = eu.shape[0]
    lu, lv = labels[eu], labels[ev]
    lo, hi = jnp.minimum(lu, lv), jnp.maximum(lu, lv)
    dead = (lu == lv) | (ew <= 0)
    sentinel = jnp.int32(n) * jnp.int32(n)
    key = jnp.where(dead, sentinel, lo.astype(jnp.int32) * n + hi)
    order = jnp.argsort(key, stable=True)
    key_s, w_s = key[order], jnp.where(dead, 0.0, ew)[order]
    head = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                            key_s[1:] != key_s[:-1]])
    seg = jnp.cumsum(head.astype(jnp.int32)) - 1
    wsum = jnp.zeros((e,), ew.dtype).at[seg].add(w_s)
    # every element of a segment carries the same key, so scatter-max is
    # a deterministic "set"
    keyrep = jnp.zeros((e,), jnp.int32).at[seg].max(key_s)
    live = (keyrep != sentinel) & (wsum > 0)
    out_u = jnp.where(live, keyrep // n, 0).astype(eu.dtype)
    out_v = jnp.where(live, keyrep % n, 0).astype(ev.dtype)
    return out_u, out_v, jnp.where(live, wsum, 0.0)


def contract_vwgt(vwgt: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-cluster summed vertex weights, fixed output shape (n,) —
    entries at and beyond the cluster count are zero."""
    n = vwgt.shape[0]
    return jnp.zeros((n,), vwgt.dtype).at[labels].add(vwgt)


def coarsen_arrays(eu: jax.Array, ev: jax.Array, ew: jax.Array,
                   vwgt: jax.Array) -> tuple:
    """One full device contraction step: matching → labels → collapsed
    edges + vertex weights.  Returns ``(labels, ceu, cev, cew, cvw)``;
    jit this once per (E, n) shape bucket."""
    n = vwgt.shape[0]
    match = heavy_edge_matching(eu, ev, ew, n)
    labels = labels_of_matching(match)
    ceu, cev, cew = contract_edges(eu, ev, ew, labels, n)
    return labels, ceu, cev, cew, contract_vwgt(vwgt, labels)
