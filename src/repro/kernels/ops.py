"""Jit'd public wrappers for the QAP kernels with backend dispatch.

On TPU the Pallas kernels compile natively; elsewhere (this CPU container)
they run in ``interpret=True`` mode, which executes the kernel body in
Python — bit-identical semantics, used by the allclose test sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from ..runtime.boundary import host_boundary
from .qap_objective import qap_objective_edges
from .swap_gain import swap_gain_matrix


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def gain_matrix(C, D, perm, tile: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """Gain matrix for all pair exchanges under assignment ``perm``.

    C: (n,n) symmetric communication matrix; D: (n,n) PE distances;
    perm: (n,) process→PE.  Returns (n,n) f32, G[u,v] = improvement from
    swapping u and v.
    """
    interpret = _interpret_default() if interpret is None else interpret
    C = jnp.asarray(C)
    D = jnp.asarray(D)
    perm = jnp.asarray(perm)
    B = D[perm][:, perm]
    return swap_gain_matrix(C, B, tile=tile, interpret=interpret)


def gain_matrix_ref(C, D, perm) -> jax.Array:
    C = jnp.asarray(C, jnp.float32)
    D = jnp.asarray(D, jnp.float32)
    perm = jnp.asarray(perm)
    return ref.swap_gain_matrix_ref(C, D[perm][:, perm])


def objective(graph, hierarchy, perm,
              interpret: bool | None = None) -> float:
    """Sparse QAP objective on device (kernel path).  Accepts the core
    CommGraph/Hierarchy types; each undirected edge counted once."""
    interpret = _interpret_default() if interpret is None else interpret
    u, v, w = graph.edge_list()
    perm = np.asarray(perm)
    pu = jnp.asarray(perm[u], jnp.int32)
    pv = jnp.asarray(perm[v], jnp.int32)
    with host_boundary("objective.readback"):
        return float(qap_objective_edges(
            pu, pv, jnp.asarray(w, jnp.float32),
            strides=tuple(int(s) for s in hierarchy.strides),
            dists=tuple(float(d) for d in hierarchy.distances),
            interpret=interpret))


def objective_ref(graph, hierarchy, perm) -> float:
    u, v, w = graph.edge_list()
    perm = np.asarray(perm)
    with host_boundary("objective.readback"):
        return float(ref.qap_objective_edges_ref(
            jnp.asarray(perm[u], jnp.int32),
            jnp.asarray(perm[v], jnp.int32),
            jnp.asarray(w, jnp.float32),
            tuple(int(s) for s in hierarchy.strides),
            tuple(float(d) for d in hierarchy.distances)))
