"""Pallas TPU kernels for the perf-critical hot spots (DESIGN §3):

  pair_gain        — sparse per-pair swap gains over padded ELL neighbor
                     rows (the refinement engine's gain pass)
  qap_objective    — sparse edge-sum objective w/ in-register hierarchy oracle
  config           — `KernelConfig`: bucket/backend-derived tile geometry
                     and lossless int8/int16 distance-table packing,
                     selected at `Mapper.lower` time
  pad              — the one set of padding helpers every entry pads with
                     (inert zero padding, append-only)
  flash_attention  — fused causal/SWA attention forward (§Perf A3)
  swap_gain        — dense O(n²) pair-exchange gain matrix (MXU matmul
                     form).  REFERENCE PATH: never selected by plans —
                     the engine's sparse candidate-pair gains are the
                     product path (wiring the dense form into selection
                     would change candidate sets and results).  It stays
                     importable for `kernels.ops.gain_matrix`, the
                     `--backend pallas` dense gain surface, and the
                     microbench's dense/sparse crossover report
                     (BENCH_kernels.json), but is deliberately not in
                     ``__all__``.

Each kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); CPU validation runs interpret=True (tests/test_kernels.py,
tests/test_flash_kernel.py, tests/test_engine.py).
"""

from . import ops, pad, ref
from .config import KernelConfig, derive_kernel_config, quantize_table
from .flash_attention import flash_attention_kernel
from .pair_gain import edge_objective, pair_gains, pair_gains_pallas
from .qap_objective import qap_objective_edges
from .swap_gain import swap_gain_matrix  # noqa: F401  (reference path)

__all__ = ["ops", "pad", "ref", "flash_attention_kernel",
           "qap_objective_edges", "pair_gains", "pair_gains_pallas",
           "edge_objective", "KernelConfig", "derive_kernel_config",
           "quantize_table"]
