"""Pallas TPU kernels for the perf-critical hot spots (DESIGN §3):

  swap_gain        — full QAP pair-exchange gain matrix (MXU matmul form)
  pair_gain        — sparse per-pair swap gains over padded ELL neighbor
                     rows (the refinement engine's gain pass)
  qap_objective    — sparse edge-sum objective w/ in-register hierarchy oracle
  flash_attention  — fused causal/SWA attention forward (§Perf A3)

Each kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); CPU validation runs interpret=True (tests/test_kernels.py,
tests/test_flash_kernel.py, tests/test_engine.py).
"""

from . import ops, ref
from .flash_attention import flash_attention_kernel
from .pair_gain import edge_objective, pair_gains, pair_gains_pallas
from .qap_objective import qap_objective_edges
from .swap_gain import swap_gain_matrix

__all__ = ["ops", "ref", "flash_attention_kernel", "qap_objective_edges",
           "swap_gain_matrix", "pair_gains", "pair_gains_pallas",
           "edge_objective"]
