"""What-if replay: predict step-time under a candidate mapping BEFORE
committing it, byteprofile-analysis style.

The predictor is the three-term roofline (:mod:`repro.analysis.
roofline`): compute and memory terms come from an optional
:class:`~repro.analysis.hlo.HloCost` of the running program (zero when
profiling traffic alone), while the collective term is re-priced for a
*specific permutation* from the live traffic graph:

    comm_s(perm) = sum_e  w_e * d(perm[u_e], perm[v_e])
                   / (n_devices * link_bandwidth)

i.e. the QAP objective itself, interpreted as hop-weighted wire bytes
and normalized to per-device seconds — so "the candidate halves the
objective" translates directly into a predicted collective-term
speedup, and a compute-bound program correctly predicts *no* step-time
win (max-of-terms), gating pointless remaps off.

``evaluate`` is the accept/reject gate: a candidate is accepted only if
its predicted step time improves on the incumbent's by at least
``margin`` (relative) AND its objective strictly improves.  Every
verdict records a ``monitor.replay`` span (visible in the Perfetto
trace) plus accept/reject counters.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..analysis.hlo import HloCost
from ..analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                 roofline_from_cost)
from ..core.graph import CommGraph
from ..core.objective import qap_objective
from ..obs import MetricsRegistry, get_tracer

_TR = get_tracer()


@dataclass
class ReplayVerdict:
    accepted: bool
    predicted_incumbent_s: float
    predicted_candidate_s: float
    predicted_improvement: float    # relative step-time win, >= 0 is better
    margin: float
    objective_incumbent: float
    objective_candidate: float

    def row(self) -> dict:
        return {
            "accepted": self.accepted,
            "predicted_incumbent_s": self.predicted_incumbent_s,
            "predicted_candidate_s": self.predicted_candidate_s,
            "predicted_improvement": self.predicted_improvement,
            "margin": self.margin,
            "objective_incumbent": self.objective_incumbent,
            "objective_candidate": self.objective_candidate,
        }


class WhatIfReplay:
    """Step-time predictor + margin gate for candidate mappings.

    ``topology`` supplies the distance oracle ``d``; ``cost`` (optional)
    the fixed compute/memory terms; ``objective_fn(g, perm)`` overrides
    the QAP pricing (pass ``plan.objective`` for backend parity —
    default is the host oracle).
    """

    def __init__(self, topology, margin: float = 0.02,
                 cost: HloCost | None = None, link_bw: float = ICI_BW,
                 objective_fn=None,
                 registry: MetricsRegistry | None = None):
        if margin < 0:
            raise ValueError("margin must be >= 0")
        self.topology = topology
        self.margin = float(margin)
        self.cost = cost
        self.link_bw = float(link_bw)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._objective = objective_fn if objective_fn is not None else \
            (lambda g, p: qap_objective(g, topology, p))

    # ------------------------------------------------------------ prediction
    def _fixed_terms(self) -> tuple[float, float]:
        if self.cost is None:
            return 0.0, 0.0
        r = roofline_from_cost(self.cost, peak_flops=PEAK_FLOPS_BF16,
                               hbm_bw=HBM_BW)
        return r.compute_s, r.memory_s

    def comm_seconds(self, live: CommGraph, perm: np.ndarray,
                     objective: float | None = None) -> float:
        """Hop-weighted wire-byte seconds per device for this mapping."""
        j = self._objective(live, perm) if objective is None \
            else float(objective)
        return j / (max(1, live.n) * self.link_bw)

    def predict_step_time(self, live: CommGraph, perm: np.ndarray,
                          objective: float | None = None) -> float:
        """max(compute, memory, comm(perm)) — perfect-overlap roofline."""
        compute_s, memory_s = self._fixed_terms()
        return max(compute_s, memory_s,
                   self.comm_seconds(live, perm, objective))

    # ------------------------------------------------------------------ gate
    def evaluate(self, live: CommGraph, incumbent: np.ndarray,
                 candidate: np.ndarray,
                 j_incumbent: float | None = None,
                 j_candidate: float | None = None) -> ReplayVerdict:
        """Accept the candidate iff predicted step time improves by
        >= ``margin`` (relative) and the objective strictly improves."""
        with _TR.span("monitor.replay", n=live.n,
                      margin=self.margin) as sp:
            ji = self._objective(live, incumbent) if j_incumbent is None \
                else float(j_incumbent)
            jc = self._objective(live, candidate) if j_candidate is None \
                else float(j_candidate)
            ti = self.predict_step_time(live, incumbent, objective=ji)
            tc = self.predict_step_time(live, candidate, objective=jc)
            win = 0.0 if ti <= 0 else 1.0 - tc / ti
            accepted = bool(win >= self.margin and jc < ji)
            sp.attrs.update(accepted=accepted,
                            predicted_incumbent_s=ti,
                            predicted_candidate_s=tc,
                            predicted_improvement=win,
                            objective_incumbent=ji,
                            objective_candidate=jc)
            reg = self.registry
            with reg.lock:
                reg.counter("monitor.replay.evaluated").inc()
                reg.counter("monitor.replay.accepted" if accepted
                            else "monitor.replay.rejected").inc()
                reg.gauge("monitor.replay.predicted_improvement").set(win)
        return ReplayVerdict(accepted=accepted,
                             predicted_incumbent_s=ti,
                             predicted_candidate_s=tc,
                             predicted_improvement=win,
                             margin=self.margin,
                             objective_incumbent=ji,
                             objective_candidate=jc)
