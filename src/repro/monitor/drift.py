"""Drift scoring with hysteresis: has traffic diverged enough from the
graph the incumbent plan was lowered for that a remap is worth trying?

Two complementary signals, both normalized so thresholds are
workload-independent:

* **edge-weight L1** — ``sum(|live - base|) / sum(base)`` over the
  union of edges.  Structure-sensitive: new flows and vanished flows
  both count, even when the incumbent objective happens not to move.
* **objective delta** — ``J_live(incumbent) / J_base(incumbent) - 1``,
  how much worse the *incumbent permutation* prices under live traffic.
  Placement-sensitive: a shift confined to already-colocated pairs
  scores near zero here, correctly reporting "drifted but still well
  mapped".

The detector triggers when the combined score holds at or above
``high`` for ``patience`` consecutive windows (jitter never
accumulates: one quiet window decays the streak), then *disarms* until
the score falls below ``low`` — the classic two-threshold hysteresis
loop, so one long drift episode yields one remap attempt, not one per
window.  ``rebaseline()`` (called when a remap commits) re-arms against
the new baseline.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.graph import CommGraph
from ..obs import MetricsRegistry, get_tracer
from .profiler import _edge_dict

_TR = get_tracer()


def edge_weight_l1(base: CommGraph, live: CommGraph) -> float:
    """Normalized L1 distance between edge-weight maps: 0 = identical,
    1 = all baseline traffic rerouted (can exceed 1 when live total
    outgrows the baseline)."""
    be, le = _edge_dict(base), _edge_dict(live)
    total = sum(be.values())
    if total <= 0:
        return 0.0 if not le else float("inf")
    l1 = 0.0
    for k in be.keys() | le.keys():
        l1 += abs(le.get(k, 0.0) - be.get(k, 0.0))
    return l1 / total


@dataclass
class DriftScore:
    """One window's drift measurement + detector state."""
    l1: float
    objective_delta: float
    score: float
    triggered: bool
    armed: bool
    streak: int


class DriftDetector:
    """Hysteresis drift detector over (baseline graph, incumbent perm).

    ``objective_fn(g, perm) -> float`` prices a permutation on a graph
    (pass ``plan.objective`` so the score uses the plan's backend).
    ``high``/``low`` are the trigger/re-arm watermarks on the combined
    score ``max(l1, objective_delta)``; ``patience`` is how many
    consecutive windows must hold at/above ``high`` before triggering.
    """

    def __init__(self, baseline: CommGraph, perm, objective_fn,
                 high: float = 0.10, low: float = 0.05,
                 patience: int = 2,
                 registry: MetricsRegistry | None = None):
        if low > high:
            raise ValueError(f"hysteresis needs low <= high, got "
                             f"low={low} high={high}")
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.high = float(high)
        self.low = float(low)
        self.patience = int(patience)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._objective = objective_fn
        self._streak = 0
        self._armed = True
        self.rebaseline(baseline, perm)

    def rebaseline(self, baseline: CommGraph, perm) -> None:
        """Adopt a new (graph, incumbent) reference — called after a
        committed remap; re-arms the trigger."""
        self.baseline = baseline
        self.perm = perm
        self.j_base = float(self._objective(baseline, perm))
        self._streak = 0
        self._armed = True

    def update(self, live: CommGraph) -> DriftScore:
        """Score one closed window; ``triggered`` fires at most once per
        excursion above ``high`` (re-arms below ``low``)."""
        with _TR.span("monitor.drift") as sp:
            l1 = edge_weight_l1(self.baseline, live)
            j_live = float(self._objective(live, self.perm))
            delta = (0.0 if self.j_base == 0
                     else j_live / self.j_base - 1.0)
            score = max(l1, delta)
            if score >= self.high:
                self._streak += 1
            else:
                self._streak = max(0, self._streak - 1)
            if score < self.low:
                self._armed = True
            triggered = (self._armed and self._streak >= self.patience)
            if triggered:
                self._armed = False
                self._streak = 0
            sp.attrs.update(l1=l1, objective_delta=delta, score=score,
                            triggered=triggered)
            reg = self.registry
            with reg.lock:
                reg.gauge("monitor.drift.l1").set(l1)
                reg.gauge("monitor.drift.objective_delta").set(delta)
                reg.gauge("monitor.drift.score").set(score)
                if triggered:
                    reg.counter("monitor.drift.triggers").inc()
        return DriftScore(l1=l1, objective_delta=delta, score=score,
                          triggered=triggered, armed=self._armed,
                          streak=self._streak)
