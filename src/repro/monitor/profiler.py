"""Live traffic ingestion: observations → windowed, smoothed CommGraph.

A :class:`TrafficProfiler` accumulates traffic observations for the
current window — compiled HLO text (priced through
:func:`~repro.core.comm_model.device_comm_graph`'s ring-collective
model), an already-extracted :class:`~repro.core.graph.CommGraph`, raw
``(u, v, bytes)`` edge observations, or recorded tracer spans carrying
``src``/``dst``/``bytes`` attributes — and on ``end_window()`` folds
them into an EMA-smoothed live graph:

    smoothed = alpha * window + (1 - alpha) * smoothed

Edges whose smoothed weight decays below ``min_weight`` are dropped, so
traffic that stops flowing eventually leaves the graph instead of
haunting the drift score forever.  Each window publishes gauges
(``monitor.traffic.bytes``, ``.edges``, ``.windows``) and an edge-bytes
histogram into the registry, so the live traffic shape is scrapeable
next to the decision counters.
"""

from __future__ import annotations

from collections import defaultdict

import numpy as np

from ..core.comm_model import device_comm_graph
from ..core.graph import CommGraph, from_edges
from ..obs import MetricsRegistry, get_tracer

_TR = get_tracer()


def _edge_dict(g: CommGraph) -> dict[tuple[int, int], float]:
    u, v, w = g.edge_list()
    return {(int(a), int(b)): float(c) for a, b, c in zip(u, v, w)}


def graph_from_dict(n: int, edges: dict[tuple[int, int], float]
                    ) -> CommGraph:
    """Build a CommGraph from an undirected ``{(u, v): w}`` dict
    (self-loops and non-positive weights dropped)."""
    keep = [(u, v, w) for (u, v), w in edges.items()
            if u != v and w > 0]
    if not keep:
        return CommGraph(np.zeros(n + 1, np.int64), np.zeros(0, np.int64),
                         np.zeros(0), np.ones(n))
    arr = np.asarray([(u, v) for u, v, _ in keep], dtype=np.int64)
    w = np.asarray([w for _, _, w in keep])
    return from_edges(n, arr[:, 0], arr[:, 1], w)


class TrafficProfiler:
    """Windowed EMA profiler over per-device-pair traffic (bytes).

    ``alpha`` is the EMA weight of the newest window (1.0 = no
    smoothing, each window stands alone); ``min_weight`` prunes decayed
    edges.  ``live()`` returns the current smoothed graph; windows with
    zero observations decay every edge toward zero.
    """

    def __init__(self, n_devices: int, alpha: float = 0.5,
                 min_weight: float = 1.0,
                 registry: MetricsRegistry | None = None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.n = int(n_devices)
        self.alpha = float(alpha)
        self.min_weight = float(min_weight)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.windows = 0
        self._window: dict[tuple[int, int], float] = defaultdict(float)
        self._smooth: dict[tuple[int, int], float] = {}

    # ------------------------------------------------------------- ingestion
    def _add(self, u: int, v: int, w: float) -> None:
        if u == v or w <= 0:
            return
        if not (0 <= u < self.n and 0 <= v < self.n):
            raise ValueError(f"edge ({u}, {v}) outside device range "
                             f"[0, {self.n})")
        self._window[(u, v) if u < v else (v, u)] += float(w)

    def ingest_edges(self, us, vs, ws) -> None:
        """Raw per-pair byte observations (directions folded)."""
        for u, v, w in zip(us, vs, ws):
            self._add(int(u), int(v), float(w))

    def ingest_graph(self, g: CommGraph) -> None:
        """An already-extracted traffic graph for this window."""
        if g.n != self.n:
            raise ValueError(f"graph has {g.n} vertices, profiler "
                             f"expects {self.n}")
        for (u, v), w in _edge_dict(g).items():
            self._add(u, v, w)

    def ingest_hlo(self, hlo_text: str) -> None:
        """Compiled HLO for one (re)compiled step: collectives priced
        through the ring model into per-device-pair bytes."""
        self.ingest_graph(device_comm_graph(hlo_text, self.n))

    def ingest_spans(self, spans) -> None:
        """Recorded tracer spans carrying ``src``/``dst``/``bytes``
        attrs (e.g. a transport layer annotating sends)."""
        for sp in spans:
            attrs = getattr(sp, "attrs", None) or {}
            if {"src", "dst", "bytes"} <= set(attrs):
                self._add(int(attrs["src"]), int(attrs["dst"]),
                          float(attrs["bytes"]))

    def prime(self, g: CommGraph) -> None:
        """Seed the EMA so ``live()`` starts exactly at ``g`` (instead
        of ``alpha * g`` after one ingested window) — the monitor primes
        with the baseline so window one scores drift against it, not
        against a half-decayed copy."""
        if g.n != self.n:
            raise ValueError(f"graph has {g.n} vertices, profiler "
                             f"expects {self.n}")
        self._smooth = {k: w for k, w in _edge_dict(g).items()
                        if w >= self.min_weight}

    # --------------------------------------------------------------- windows
    def end_window(self) -> CommGraph:
        """Close the window: fold observations into the EMA, publish
        window metrics, return the smoothed live graph."""
        with _TR.span("monitor.window", n=self.n,
                      observed_edges=len(self._window)):
            a = self.alpha
            smooth = {k: (1 - a) * w for k, w in self._smooth.items()}
            for k, w in self._window.items():
                smooth[k] = smooth.get(k, 0.0) + a * w
            self._smooth = {k: w for k, w in smooth.items()
                            if w >= self.min_weight}
            self._window = defaultdict(float)
            self.windows += 1
            live = self.live()
            reg = self.registry
            with reg.lock:
                reg.counter("monitor.windows").inc()
                reg.gauge("monitor.traffic.bytes").set(
                    float(sum(self._smooth.values())))
                reg.gauge("monitor.traffic.edges").set(
                    float(len(self._smooth)))
                hist = reg.histogram("monitor.traffic.edge_bytes")
                for w in self._smooth.values():
                    hist.observe(w)
        return live

    def live(self) -> CommGraph:
        """The current EMA-smoothed traffic graph."""
        return graph_from_dict(self.n, self._smooth)

    def live_edges(self) -> dict[tuple[int, int], float]:
        return dict(self._smooth)
