"""Dirty-region computation for incremental remaps.

A drift episode usually moves a *fraction* of the traffic; re-solving
the whole QAP throws away the still-good incumbent structure and pays
full construction + refinement.  Instead:

1. ``dirty_vertices`` — processes incident to an edge whose weight
   moved by more than ``rel_tol`` of the baseline weight (new and
   vanished edges always count).
2. ``expand_dirty`` — grow the set ``hops`` steps along the live
   graph's adjacency, so the refinement can trade placement with the
   immediate neighborhood of the shifted region.
3. ``dirty_pair_mask`` — the boolean mask over the plan's *fixed*
   candidate-pair array selecting pairs that touch the dirty set.

``MappingPlan.execute_warm`` consumes the mask by substituting inert
``(u, u)`` self-pairs — the engine's own padding convention — so the
pair array length, the padded device shape, and the compiled executable
are identical to a full refinement: masking, never retracing.
"""

from __future__ import annotations

import numpy as np

from ..core.graph import CommGraph
from .profiler import _edge_dict


def dirty_vertices(base: CommGraph, live: CommGraph,
                   rel_tol: float = 0.05) -> np.ndarray:
    """Vertices whose incident traffic changed beyond ``rel_tol``
    (relative to the baseline edge; appear/disappear always dirty)."""
    be, le = _edge_dict(base), _edge_dict(live)
    dirty = np.zeros(base.n, dtype=bool)
    for k in be.keys() | le.keys():
        b, l = be.get(k), le.get(k)
        if b is None or l is None or abs(l - b) > rel_tol * b:
            dirty[k[0]] = dirty[k[1]] = True
    return dirty


def expand_dirty(g: CommGraph, dirty: np.ndarray,
                 hops: int = 1) -> np.ndarray:
    """Grow the dirty set ``hops`` steps along ``g``'s adjacency."""
    dirty = np.asarray(dirty, dtype=bool).copy()
    u, v, _ = g.edge_list()
    for _ in range(max(0, int(hops))):
        touch = dirty[u] | dirty[v]
        nxt = dirty.copy()
        np.logical_or.at(nxt, u, touch)
        np.logical_or.at(nxt, v, touch)
        if np.array_equal(nxt, dirty):
            break
        dirty = nxt
    return dirty


def dirty_pair_mask(pairs: np.ndarray, dirty: np.ndarray) -> np.ndarray:
    """Boolean mask over candidate pairs touching a dirty vertex."""
    pairs = np.asarray(pairs)
    if len(pairs) == 0:
        return np.zeros(0, dtype=bool)
    dirty = np.asarray(dirty, dtype=bool)
    return dirty[pairs[:, 0]] | dirty[pairs[:, 1]]
