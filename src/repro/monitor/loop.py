"""The closed loop: profile → detect drift → incremental remap →
what-if gate → commit or roll back.

:class:`RemapMonitor` owns an incumbent permutation and the plan it was
lowered under, and advances in discrete windows: feed traffic
observations (``observe_hlo``/``observe_graph``/``observe_edges``),
then call ``tick()``.  Each tick closes the profiler window, scores
drift with hysteresis, and — only when the detector triggers — runs an
*incremental* remap: the dirty region's candidate pairs stay active,
everything else is masked to inert self-pairs, and the device engine
refines the incumbent in one warm call that reuses the plan's compiled
executable (zero retraces — the shapes never change).  The refined
candidate must then clear the what-if replay margin before it replaces
the incumbent; a rejected candidate leaves the incumbent untouched and
the detector disarmed until traffic drifts further.

``handle_action`` feeds :class:`~repro.runtime.fault_tolerance.Action`
signals through the *same* gate: ``REBALANCE`` marks the processes
mapped onto the slow hosts' PEs dirty and forces a gated remap attempt
at the next tick; ``EVICT_RESTART`` forces a full-region attempt.
``attach`` subscribes directly to a ``StragglerMonitor``'s ``on_action``
callback.  Every decision is spans + counters on the shared registry,
so ``viem remap-watch --profile`` shows the whole loop in Perfetto.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.graph import CommGraph
from ..obs import MetricsRegistry, get_tracer
from ..runtime.fault_tolerance import Action
from .drift import DriftDetector, DriftScore
from .profiler import TrafficProfiler
from .remap import dirty_pair_mask, dirty_vertices, expand_dirty
from .replay import ReplayVerdict, WhatIfReplay

_TR = get_tracer()


@dataclass(frozen=True)
class MonitorConfig:
    """Knobs of the closed loop (see README "Closed-loop remapping")."""
    alpha: float = 0.5            # profiler EMA weight of newest window
    min_weight: float = 1.0       # drop smoothed edges below this
    drift_high: float = 0.10      # trigger watermark on the drift score
    drift_low: float = 0.05       # re-arm watermark (hysteresis)
    drift_patience: int = 2       # consecutive hot windows to trigger
    replay_margin: float = 0.02   # required relative step-time win
    dirty_rel_tol: float = 0.05   # edge-weight change that marks dirty
    dirty_hops: int = 1           # halo growth around the dirty set
    telemetry: bool = False       # engine counters on warm remaps


@dataclass
class TickReport:
    """One window's decision record (also emitted as spans/counters)."""
    window: int
    drift: DriftScore
    triggered: bool
    remapped: bool
    verdict: ReplayVerdict | None = None
    dirty: int = 0
    active_pairs: int = 0
    remap_seconds: float = 0.0
    retraces: int = 0
    forced_by: str | None = None
    skipped: str | None = None


class RemapMonitor:
    """Profile-driven remapping loop over one lowered plan.

    ``plan`` must be lowered with a bucket that admits the traffic the
    loop will see (lower with ``schedule="pow2"`` for headroom);
    ``baseline`` is the graph the incumbent was mapped for; ``perm``
    the incumbent permutation (default: map ``baseline`` through the
    plan).  ``cost`` (an :class:`~repro.analysis.hlo.HloCost`) anchors
    the replay's compute/memory terms; ``on_remap(perm, verdict)`` is
    called after every committed remap (wire it to
    ``make_production_mesh(devices=...)`` re-meshing).
    """

    def __init__(self, plan, baseline: CommGraph,
                 perm: np.ndarray | None = None,
                 config: MonitorConfig = MonitorConfig(),
                 cost=None, registry: MetricsRegistry | None = None,
                 on_remap=None, seed: int | None = None):
        self.plan = plan
        self.config = config
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.on_remap = on_remap
        self.seed = seed
        if perm is None:
            perm = plan.execute(baseline, seed=seed).perm
        self.incumbent = np.asarray(perm, dtype=np.int64).copy()
        self.baseline = baseline
        # the FIXED candidate set: masks vary per remap, the array (and
        # with it the padded device shape) never does
        self.pairs = plan.candidate_pairs(baseline, seed)
        self.profiler = TrafficProfiler(
            baseline.n, alpha=config.alpha, min_weight=config.min_weight,
            registry=self.registry)
        self.profiler.prime(baseline)
        self.detector = DriftDetector(
            baseline, self.incumbent, plan.objective,
            high=config.drift_high, low=config.drift_low,
            patience=config.drift_patience, registry=self.registry)
        self.replay = WhatIfReplay(
            plan.topology, margin=config.replay_margin, cost=cost,
            objective_fn=plan.objective, registry=self.registry)
        self.remaps = 0
        self.ticks = 0
        self._forced: list[tuple[str, np.ndarray]] = []
        self.history: list[TickReport] = []

    # ---------------------------------------------------------- observations
    def observe_hlo(self, hlo_text: str) -> None:
        self.profiler.ingest_hlo(hlo_text)

    def observe_graph(self, g: CommGraph) -> None:
        self.profiler.ingest_graph(g)

    def observe_edges(self, us, vs, ws) -> None:
        self.profiler.ingest_edges(us, vs, ws)

    # -------------------------------------------------------- fault signals
    def handle_action(self, action: Action, hosts=(),
                      pes_per_host: int | None = None) -> None:
        """Consume a fault-tolerance action: force a gated remap attempt
        at the next tick with the affected PEs' processes dirty.
        ``hosts`` are host indices; each host owns a contiguous block of
        ``pes_per_host`` PEs (default: evenly split)."""
        if action == Action.CONTINUE:
            return
        n = self.baseline.n
        dirty = np.zeros(n, dtype=bool)
        if action == Action.EVICT_RESTART or not len(list(hosts)):
            dirty[:] = True
        else:
            hosts = list(hosts)
            if pes_per_host is None:
                pes_per_host = max(1, n // max(1, max(hosts) + 1))
            pe_dirty = np.zeros(n, dtype=bool)
            for h in hosts:
                pe_dirty[h * pes_per_host:(h + 1) * pes_per_host] = True
            # processes currently mapped onto the slow hosts' PEs
            dirty = pe_dirty[self.incumbent]
        self._forced.append((action.value, dirty))
        self.registry.counter(f"monitor.action.{action.value}").inc()

    def attach(self, straggler_monitor) -> None:
        """Subscribe to a ``StragglerMonitor``'s action stream."""
        straggler_monitor.on_action = self.handle_action

    # ------------------------------------------------------------------ tick
    def tick(self) -> TickReport:
        """Close the window and run one decision round."""
        cfg = self.config
        self.ticks += 1
        with _TR.span("monitor.tick", window=self.ticks) as sp:
            live = self.profiler.end_window()
            score = self.detector.update(live)
            forced_by = self._forced[0][0] if self._forced else None
            triggered = score.triggered or bool(self._forced)
            report = TickReport(window=self.ticks, drift=score,
                                triggered=triggered, remapped=False,
                                forced_by=forced_by)
            if not triggered:
                sp.attrs.update(triggered=False, remapped=False)
                self.history.append(report)
                return report
            if self.plan.bucket is not None \
                    and not self.plan.bucket.admits(live):
                # live traffic outgrew the plan's padded shapes: an
                # incremental remap cannot reuse the executable — defer
                # to an operator re-lower instead of silently retracing
                self.registry.counter("monitor.bucket_exceeded").inc()
                report.skipped = "bucket_exceeded"
                self._forced.clear()
                sp.attrs.update(triggered=True, skipped=report.skipped)
                self.history.append(report)
                return report
            dirty = dirty_vertices(self.detector.baseline, live,
                                   rel_tol=cfg.dirty_rel_tol)
            for _, fd in self._forced:
                dirty |= fd
            self._forced.clear()
            dirty = expand_dirty(live, dirty, hops=cfg.dirty_hops)
            mask = dirty_pair_mask(self.pairs, dirty)
            report.dirty = int(dirty.sum())
            report.active_pairs = int(mask.sum())
            with _TR.span("monitor.remap", dirty=report.dirty,
                          active_pairs=report.active_pairs) as rsp:
                engines = self.plan.engines or []
                before = sum(e.trace_count() for e in engines)
                res = self.plan.execute_warm(
                    live, self.incumbent, pairs=self.pairs, active=mask,
                    seed=self.seed, telemetry=cfg.telemetry)
                report.retraces = \
                    sum(e.trace_count() for e in engines) - before
                rsp.attrs["retraces"] = report.retraces
            report.remap_seconds = rsp.dur
            verdict = self.replay.evaluate(
                live, self.incumbent, res.perm,
                j_incumbent=res.initial_objective,
                j_candidate=res.final_objective)
            report.verdict = verdict
            if verdict.accepted:
                self.incumbent = np.asarray(res.perm, np.int64).copy()
                self.baseline = live
                self.detector.rebaseline(live, self.incumbent)
                self.remaps += 1
                self.registry.counter("monitor.remaps.committed").inc()
                self.registry.histogram("monitor.remap_seconds") \
                    .observe(report.remap_seconds)
                report.remapped = True
                if self.on_remap is not None:
                    self.on_remap(self.incumbent, verdict)
            else:
                self.registry.counter("monitor.remaps.rolled_back").inc()
            sp.attrs.update(triggered=True, remapped=report.remapped,
                            dirty=report.dirty)
        self.history.append(report)
        return report
