"""Closed-loop remapping: live traffic profiling → drift detection →
what-if replay → incremental remap.

The guide's premise is that the communication graph of a running
program should drive its process-to-PE mapping; this package keeps
driving it *after* launch.  Four stages, each independently usable:

* :class:`TrafficProfiler` (:mod:`.profiler`) — windowed ingestion of
  live traffic (compiled HLO via :func:`~repro.core.comm_model.
  device_comm_graph`, recorded spans, or raw edge observations) into an
  EMA-smoothed live :class:`~repro.core.graph.CommGraph` per window,
  published as gauges/histograms in a
  :class:`~repro.obs.MetricsRegistry`.
* :class:`DriftDetector` (:mod:`.drift`) — scores divergence between
  the live graph and the baseline the incumbent plan was lowered for
  (normalized edge-weight L1 plus objective-under-incumbent delta) with
  hysteresis (trigger high-watermark, re-arm low-watermark, patience)
  so jitter never triggers remaps.
* :class:`WhatIfReplay` (:mod:`.replay`) — predicts step-time under a
  candidate mapping with the roofline/comm model *before* committing,
  and accepts only if the predicted improvement clears a configurable
  margin.  Every verdict is a span + counters, exportable to the
  existing Perfetto trace.
* :class:`RemapMonitor` (:mod:`.loop`) — the loop: profile → detect →
  incremental warm remap of only the dirty region (an inert-pair
  runtime mask on the plan's fixed candidate set —
  ``MappingPlan.execute_warm`` — masking, never retracing) → replay
  gate → commit or roll back.  ``handle_action`` consumes
  :class:`~repro.runtime.fault_tolerance.Action` signals so straggler
  ``REBALANCE``/eviction flows through the same accept/reject gate.
"""

from .drift import DriftDetector, DriftScore, edge_weight_l1
from .loop import MonitorConfig, RemapMonitor, TickReport
from .profiler import TrafficProfiler
from .remap import dirty_pair_mask, dirty_vertices, expand_dirty
from .replay import ReplayVerdict, WhatIfReplay

__all__ = [
    "DriftDetector", "DriftScore", "edge_weight_l1",
    "MonitorConfig", "RemapMonitor", "TickReport",
    "TrafficProfiler",
    "dirty_pair_mask", "dirty_vertices", "expand_dirty",
    "ReplayVerdict", "WhatIfReplay",
]
