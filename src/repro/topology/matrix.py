"""`matrix` — explicit distance matrix: true general sparse QAP.

The guide's framing is mapping *against an arbitrary distance matrix*;
this backend is that arbitrary matrix, loadable from disk:

  * Metis/Chaco graph format (guide §3.1): an edge-weighted graph over the
    n PEs whose edge weight is the distance between its endpoints; PE
    pairs without an edge have distance 0 — a *sparse* D, exactly the
    sparse-QAP benchmark encoding,
  * ``.npy`` — a dense float n×n numpy array,
  * plain text — n whitespace-separated rows of n floats (optionally a
    leading line with n).

D must be square, symmetric, non-negative, zero-diagonal (validated on
build).  ``split`` uses farthest-pair seeded balanced halving — a generic
recursive decomposition so the top-down construction works for machines
with no closed-form structure.
"""

from __future__ import annotations

import numpy as np

from .base import Topology, balanced_halves, register_topology


def load_distance_matrix(path) -> np.ndarray:
    """Load D from ``.npy``, Metis graph (edge weight = distance), or a
    plain dense text file."""
    path = str(path)
    if path.endswith(".npy"):
        return np.asarray(np.load(path), dtype=np.float64)
    with open(path) as fh:
        text = fh.read()
    body = [ln for ln in text.splitlines()
            if ln.strip() and not ln.lstrip().startswith("%")]
    if not body:
        raise ValueError(f"{path}: empty distance file")
    header = body[0].split()

    def _is_int(tok: str) -> bool:
        return tok.lstrip("+").isdigit()

    # Metis header `n m [f]`: an all-integer first line with a positive
    # vertex count.  A dense text distance matrix can never match: its
    # first row starts with the zero diagonal entry ("0" or "0.0"), and a
    # leading-count-line variant has a single token.
    if (len(header) in (2, 3) and all(_is_int(t) for t in header)
            and int(header[0]) > 0):
        import io

        from ..core.graph import read_metis
        g = read_metis(io.StringIO(text))
        return g.to_dense().astype(np.float64)
    # dense text: optional leading `n` line, then n rows of n floats
    rows = [np.fromstring(ln, sep=" ") for ln in body]
    if len(rows[0]) == 1 and len(rows) == int(rows[0][0]) + 1:
        rows = rows[1:]
    D = np.vstack(rows)
    if D.shape[0] != D.shape[1]:
        raise ValueError(f"{path}: distance matrix must be square, "
                         f"got {D.shape}")
    return D.astype(np.float64)


@register_topology("matrix")
class MatrixTopology(Topology):
    """Explicit distance matrix.  Build from an in-memory ``matrix`` or a
    ``file`` path (see :func:`load_distance_matrix`)."""

    def __init__(self, matrix=None, file=None):
        if (matrix is None) == (file is None):
            raise ValueError("matrix topology needs exactly one of "
                             "matrix=, file=")
        if file is not None:
            matrix = load_distance_matrix(file)
        D = np.ascontiguousarray(np.asarray(matrix, dtype=np.float64))
        if D.ndim != 2 or D.shape[0] != D.shape[1]:
            raise ValueError(f"distance matrix must be square, "
                             f"got shape {D.shape}")
        if np.any(np.diag(D) != 0.0):
            raise ValueError("distance matrix must have a zero diagonal")
        if not np.array_equal(D, D.T):
            raise ValueError("distance matrix must be symmetric")
        if np.any(D < 0):
            raise ValueError("distances must be non-negative")
        D.setflags(write=False)
        self.D = D
        self._matrix = D                 # base-class cache, pre-filled
        self.file = str(file) if file is not None else None

    # ------------------------------------------------------------ contract
    @property
    def n_pe(self) -> int:
        return self.D.shape[0]

    def distance(self, p, q):
        p = np.asarray(p, dtype=np.int64)
        q = np.asarray(q, dtype=np.int64)
        out = self.D[p, q]
        return out if np.ndim(out) else float(out)

    def distance_matrix(self) -> np.ndarray:
        return self.D

    def split(self, pe_ids: np.ndarray) -> "list[np.ndarray] | None":
        pe_ids = np.asarray(pe_ids, dtype=np.int64)
        if len(pe_ids) <= 2:
            return None
        return balanced_halves(self.D, pe_ids)

    def spec_params(self) -> dict:
        if self.file is not None:
            return {"file": self.file}
        return {"matrix": self.D.tolist()}
