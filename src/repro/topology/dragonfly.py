"""`dragonfly` — the hierarchical min-hop model of a dragonfly network.

Standard dragonfly (Kim et al.): ``pes_per_router`` terminals per router,
``routers_per_group`` routers all-to-all connected inside a group by local
links, ``n_groups`` groups all-to-all connected by global links.  Min-hop
distance classes:

    same router                  → d_router          (through one router)
    same group, different router → d_local           (one local link)
    different groups             → 2·d_local + d_global
                                   (local hop to the gateway router, one
                                    global link, local hop at the far end —
                                    the canonical worst-case l-g-l route)

Three distance classes keyed by the lowest common enclosure — i.e. a
three-level hierarchy with factors (p, a, g); the derived ``Hierarchy``
reuses the closed-form tree kernel path.  Distance monotonicity
(d_router ≤ d_local ≤ 2·d_local + d_global) is validated on build.
"""

from __future__ import annotations

from ..core.hierarchy import Hierarchy
from .base import register_topology
from .tree import TreeTopology


@register_topology("dragonfly")
class DragonflyTopology(TreeTopology):
    def __init__(self, pes_per_router: int = 4, routers_per_group: int = 8,
                 n_groups: int = 9, d_router: float = 1.0,
                 d_local: float = 2.0, d_global: float = 10.0):
        self.pes_per_router = int(pes_per_router)
        self.routers_per_group = int(routers_per_group)
        self.n_groups = int(n_groups)
        self.d_router = float(d_router)
        self.d_local = float(d_local)
        self.d_global = float(d_global)
        if min(d_router, d_local, d_global) < 0:
            raise ValueError("dragonfly link costs must be >= 0")
        if d_router > d_local:
            raise ValueError("dragonfly expects d_router <= d_local "
                             "(a local link crosses at least one router)")
        factors = (self.pes_per_router, self.routers_per_group,
                   self.n_groups)
        dists = (self.d_router, self.d_local,
                 2.0 * self.d_local + self.d_global)
        super().__init__(hierarchy=Hierarchy(factors, dists))

    def spec_params(self) -> dict:
        return {"pes_per_router": self.pes_per_router,
                "routers_per_group": self.routers_per_group,
                "n_groups": self.n_groups,
                "d_router": self.d_router,
                "d_local": self.d_local,
                "d_global": self.d_global}
