"""TPU machine-model presets built on the topology backends.

The tree presets in :mod:`repro.core.hierarchy` approximate the ICI mesh
with nested distance classes; these are the honest models: a v5e pod is a
16×16 2D torus of chips, a v5p pod a 3D torus — wraparound ICI links,
per-axis hop distance.  Multi-pod fleets add a pod axis whose weight is
the DCN/ICI cost ratio (~60×); with 2 pods the "ring" over pods is a
single DCN link, exactly right, and for small pod counts a ring is the
standard DCN modeling compromise.
"""

from __future__ import annotations

from .torus import TorusTopology


def tpu_v5e_torus(pods: int = 1, dcn_weight: float = 60.0) -> TorusTopology:
    """v5e: 16×16 2D ICI torus per pod (256 chips); ``pods`` > 1 appends a
    DCN pod axis.  Axis weights are relative link costs (ICI hop = 1)."""
    if pods == 1:
        return TorusTopology((16, 16), (1.0, 1.0))
    return TorusTopology((16, 16, pods), (1.0, 1.0, float(dcn_weight)))


def tpu_v5p_torus(dims=(8, 8, 16), pods: int = 1,
                  dcn_weight: float = 60.0) -> TorusTopology:
    """v5p: 3D ICI torus per pod (default 8×8×16 = 1024 chips)."""
    dims = tuple(int(d) for d in dims)
    if pods == 1:
        return TorusTopology(dims, (1.0,) * len(dims))
    return TorusTopology(dims + (pods,),
                         (1.0,) * len(dims) + (float(dcn_weight),))
