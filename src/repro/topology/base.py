"""The distance-oracle contract every machine model implements.

The guide frames process mapping as *sparse quadratic assignment against an
arbitrary distance matrix* — the machine model is whatever defines
D(p, q).  A :class:`Topology` is exactly that definition plus the three
hooks the rest of the framework needs:

  distance(p, q)     — vectorized online oracle (numpy, float64); the hot
                       path of every search driver, so no n×n materialize.
  matrix()           — the materialized D, cached (the guide's `hierarchy`
                       distance construction; small-n only).
  kernel_params()    — hashable descriptor of the device-side distance
                       representation.  ("tree", strides, dists) and
                       ("torus", dims, weights) select closed-form device
                       oracles computed in-register; ("matrix",
                       fingerprint) selects the gather path.  Both the
                       Pallas objective/gain kernels and the refinement
                       engine (``repro.engine``) consume it, and the
                       Mapper keys its kernel and engine caches on it.
  split(pe_ids)      — the machine's natural recursive decomposition, used
                       by the top-down construction in place of hierarchy
                       factors.  Returns equal-size(±1) sub-groups of PE
                       ids, or None for a leaf.

Backends register with ``@register_topology("name")`` and become
addressable from :class:`~repro.core.spec.TopologySpec`, the ``viem`` CLI
(``--topology=name``), and ``Mapper`` — the same plug-in pattern as
``@register_construction``.
"""

from __future__ import annotations

import abc
from typing import Callable

import numpy as np


class Topology(abc.ABC):
    """A machine model behind the distance-oracle contract.

    Subclasses must define ``kind`` (the registry name), ``n_pe`` and
    ``distance``; everything else has contract-respecting defaults.
    """

    kind: str = "abstract"

    # ------------------------------------------------------------- contract
    @property
    @abc.abstractmethod
    def n_pe(self) -> int:
        """Number of processing elements."""

    @abc.abstractmethod
    def distance(self, p, q):
        """Online distance oracle D(p, q): vectorized over numpy arrays,
        symmetric, zero on the diagonal, no n×n materialization."""

    def distance_matrix(self) -> np.ndarray:
        """Materialized D (computed fresh; see :meth:`matrix` for the
        cached form) — small n only."""
        idx = np.arange(self.n_pe)
        return self.distance(idx[:, None], idx[None, :])

    def matrix(self) -> np.ndarray:
        """Materialized D, computed once per instance and cached."""
        m = getattr(self, "_matrix", None)
        if m is None:
            m = self.distance_matrix()
            m.setflags(write=False)
            self._matrix = m
        return m

    def kernel_params(self) -> tuple:
        """Hashable device-side distance representation.  The default is
        the explicit-matrix path: the Pallas objective and the refinement
        engine gather from the materialized D (fingerprint keys the
        Mapper's kernel and engine caches)."""
        return ("matrix", self._fingerprint())

    def split(self, pe_ids: np.ndarray) -> "list[np.ndarray] | None":
        """Natural recursive decomposition of the PE set ``pe_ids``:
        a list of equal-size(±1) sub-arrays whose union is ``pe_ids``,
        or ``None`` when the set has no further structure (leaf — the
        construction assigns ranks arbitrarily)."""
        return None

    # ---------------------------------------------------------------- spec
    def spec_params(self) -> dict:
        """JSON-safe constructor parameters: ``make_topology(self.kind,
        **self.spec_params())`` rebuilds an equivalent topology."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support spec round-trips")

    # -------------------------------------------------------------- helpers
    def _fingerprint(self) -> int:
        fp = getattr(self, "_fp", None)
        if fp is None:
            fp = hash((self.kind, self.n_pe,
                       self.matrix().tobytes()))
            self._fp = fp
        return fp

    def validate(self) -> "Topology":
        """Cheap sanity checks of the contract on a small sample."""
        n = self.n_pe
        if n <= 0:
            raise ValueError(f"{self.kind}: n_pe must be positive, got {n}")
        idx = np.arange(min(n, 64))
        d_self = np.asarray(self.distance(idx, idx))
        if np.any(d_self != 0.0):
            raise ValueError(f"{self.kind}: D(p, p) must be 0")
        return self

    def __repr__(self):
        return f"<{type(self).__name__} kind={self.kind!r} n_pe={self.n_pe}>"


# ------------------------------------------------------------------ registry
TOPOLOGIES: dict[str, Callable[..., Topology]] = {}


def register_topology(name: str) -> Callable:
    """Register a ``Topology`` subclass (or factory) under ``name``.

    Registered names auto-populate the ``viem`` CLI ``--topology`` choices
    and are valid ``TopologySpec.kind`` values."""
    def deco(factory):
        if name in TOPOLOGIES:
            raise ValueError(f"topology {name!r} is already registered")
        TOPOLOGIES[name] = factory
        if isinstance(factory, type):
            factory.kind = name
        return factory
    return deco


def resolve_topology(name: str) -> Callable[..., Topology]:
    try:
        return TOPOLOGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; registered: "
            f"{sorted(TOPOLOGIES)}") from None


def list_topologies() -> list[str]:
    return sorted(TOPOLOGIES)


def make_topology(kind: str, **params) -> Topology:
    """Build a registered topology from JSON-safe parameters."""
    return resolve_topology(kind)(**params)


def as_topology(machine) -> Topology:
    """Coerce a machine model to the Topology contract.

    ``Hierarchy`` instances wrap into a :class:`TreeTopology` sharing the
    *same* ``Hierarchy`` object (so its cached distance oracle is reused
    and results stay bit-for-bit identical); topologies pass through."""
    if isinstance(machine, Topology):
        return machine
    from ..core.hierarchy import Hierarchy
    if isinstance(machine, Hierarchy):
        from .tree import TreeTopology
        return TreeTopology(hierarchy=machine)
    raise TypeError(f"cannot interpret {type(machine).__name__} as a "
                    f"machine topology")


def balanced_halves(D: np.ndarray, pe_ids: np.ndarray) -> list[np.ndarray]:
    """Generic 2-way decomposition for matrix-defined machines: seed with
    an (approximate) farthest pair, then split the ids into two balanced
    halves by which seed each PE is closer to (ties/balance resolved by
    the margin ordering).  Deterministic."""
    ids = np.asarray(pe_ids, dtype=np.int64)
    sub = D[np.ix_(ids, ids)]
    s1 = int(np.argmax(sub[0]))
    s2 = int(np.argmax(sub[s1]))
    if s1 == s2:                       # all-zero distances: arbitrary halves
        mid = (len(ids) + 1) // 2
        return [ids[:mid], ids[mid:]]
    margin = sub[s1] - sub[s2]         # >0 → closer to seed 2
    order = np.argsort(margin, kind="stable")
    mid = (len(ids) + 1) // 2
    return [ids[np.sort(order[:mid])], ids[np.sort(order[mid:])]]
