"""`fattree` — k-ary fat-tree with per-level up-link costs.

A folded-Clos / fat-tree network: PEs hang off edge switches, switches
aggregate level by level.  A message between PEs whose lowest common
switch sits at level l traverses l up-links and l down-links, so

    D(p, q) = 2 · Σ_{i ≤ l} link_costs[i-1]        (l = LCA level)

— tree-*shaped* like the guide's hierarchy, but parameterized by per-hop
link cost rather than per-level distance, with the up+down doubling made
explicit.  Internally this reduces to a derived ``Hierarchy`` with
``distances = 2·cumsum(link_costs)`` (non-decreasing by construction), so
the closed-form tree kernel path applies unchanged.
"""

from __future__ import annotations

import numpy as np

from ..core.hierarchy import Hierarchy
from .base import register_topology
from .tree import TreeTopology


@register_topology("fattree")
class FatTreeTopology(TreeTopology):
    """``arities`` = ports per switch level (innermost first, like the
    hierarchy's factors); ``link_costs`` = cost of one up-link at each
    level (default 1.0 each — pure hop count)."""

    def __init__(self, arities, link_costs=None):
        arities = tuple(int(a) for a in arities)
        if link_costs is None:
            link_costs = [1.0] * len(arities)
        link_costs = tuple(float(c) for c in link_costs)
        if len(link_costs) != len(arities):
            raise ValueError("fattree arities and link_costs differ "
                             "in length")
        if any(c < 0 for c in link_costs):
            raise ValueError("fattree link costs must be >= 0")
        self.arities = arities
        self.link_costs = link_costs
        dists = tuple(float(2.0 * c) for c in np.cumsum(link_costs))
        super().__init__(hierarchy=Hierarchy(arities, dists))

    def spec_params(self) -> dict:
        return {"arities": list(self.arities),
                "link_costs": list(self.link_costs)}
