"""Pluggable machine models behind one distance-oracle contract.

The guide frames process mapping as sparse quadratic assignment against an
*arbitrary* distance matrix; this package supplies the machine models:

    tree       — the guide's homogeneous hierarchy (wraps core.Hierarchy,
                 bit-identical),
    torus      — k-ary n-cube with per-axis link weights (TPU ICI),
    fattree    — k-ary fat-tree with per-level up-link costs,
    dragonfly  — hierarchical min-hop dragonfly (router/group/global),
    matrix     — explicit distance matrix (true general sparse QAP),
                 loadable from Metis/.npy/dense-text files.

Every backend implements :class:`Topology` — ``n_pe``, a vectorized online
``distance`` oracle, a cached materialized ``matrix()``, ``kernel_params``
selecting the device-side Pallas distance representation, and a ``split``
hook exposing the machine's natural recursive decomposition to the
top-down construction.  ``@register_topology`` makes third-party machine
models addressable from ``TopologySpec``, the ``viem`` CLI, and ``Mapper``
without touching core dispatch::

    from repro.topology import make_topology, TorusTopology
    topo = make_topology("torus", dims=[16, 16])       # by name
    topo = TorusTopology((16, 16))                     # directly
    Mapper(topo, MappingSpec(...)).map(g)
"""

from .base import (Topology, as_topology, balanced_halves, list_topologies,
                   make_topology, register_topology, resolve_topology)
from .dragonfly import DragonflyTopology
from .fattree import FatTreeTopology
from .matrix import MatrixTopology, load_distance_matrix
from .presets import tpu_v5e_torus, tpu_v5p_torus
from .torus import TorusTopology
from .tree import TreeTopology

__all__ = [
    "Topology", "as_topology", "balanced_halves", "register_topology",
    "resolve_topology", "list_topologies", "make_topology",
    "TreeTopology", "TorusTopology", "FatTreeTopology",
    "DragonflyTopology", "MatrixTopology", "load_distance_matrix",
    "tpu_v5e_torus", "tpu_v5p_torus",
]
