"""`torus` — k-ary n-cube with per-axis link weights.

The honest TPU ICI model: a v5e pod is a 16×16 2D torus, a v5p pod a 3D
torus — wraparound links, hop distance per axis, *not* a tree.  Distance
is the weighted Manhattan ring distance

    D(p, q) = Σ_a  w_a · min(|x_a − y_a|, k_a − |x_a − y_a|)

with PE index = mixed-radix coordinates (axis 0 innermost, matching the
hierarchy's innermost-first convention).  Closed-form, so the Pallas
objective kernel computes it arithmetically in-register — large n never
materializes n×n on host (``kernel_params`` = ("torus", dims, weights)).

``split`` halves the longest (weight-scaled) axis — the machine's natural
recursive decomposition for the top-down construction: blocks stay
compact sub-boxes, exactly the subtree analogue.
"""

from __future__ import annotations

import numpy as np

from .base import Topology, register_topology


def _smallest_factor(n: int) -> int:
    for f in range(2, int(n ** 0.5) + 1):
        if n % f == 0:
            return f
    return n


@register_topology("torus")
class TorusTopology(Topology):
    """k-ary n-cube: ``dims`` = (k_1, ..., k_n) PEs per axis (axis 0
    innermost in the PE index), ``weights`` = per-axis link weight
    (default 1.0 each — pure hop count)."""

    def __init__(self, dims, weights=None):
        self.dims = tuple(int(d) for d in dims)
        if not self.dims or any(d <= 0 for d in self.dims):
            raise ValueError(f"torus dims must be positive, got {dims}")
        if weights is None:
            weights = [1.0] * len(self.dims)
        self.weights = tuple(float(w) for w in weights)
        if len(self.weights) != len(self.dims):
            raise ValueError("torus dims and weights differ in length")
        if any(w < 0 for w in self.weights):
            raise ValueError("torus link weights must be >= 0")
        # strides[a] = PE-index stride of axis a (axis 0 innermost)
        self.strides = tuple(
            int(np.prod(self.dims[:a], dtype=np.int64))
            for a in range(len(self.dims)))

    # ------------------------------------------------------------ contract
    @property
    def n_pe(self) -> int:
        return int(np.prod(self.dims, dtype=np.int64))

    def coords(self, p) -> list[np.ndarray]:
        """Mixed-radix coordinates of PE index ``p``, one array per axis."""
        p = np.asarray(p, dtype=np.int64)
        return [(p // s) % d for s, d in zip(self.strides, self.dims)]

    def distance(self, p, q):
        p = np.asarray(p, dtype=np.int64)
        q = np.asarray(q, dtype=np.int64)
        out = np.zeros(np.broadcast(p, q).shape, dtype=np.float64)
        for s, d, w in zip(self.strides, self.dims, self.weights):
            delta = np.abs((p // s) % d - (q // s) % d)
            out += w * np.minimum(delta, d - delta)
        return out if out.ndim else float(out)

    def kernel_params(self) -> tuple:
        return ("torus", self.dims, self.weights)

    def split(self, pe_ids: np.ndarray) -> "list[np.ndarray] | None":
        """Split the sub-box along its longest (weight-scaled) axis into
        the axis extent's smallest prime factor many equal slabs."""
        pe_ids = np.asarray(pe_ids, dtype=np.int64)
        if len(pe_ids) <= 1:
            return None
        cs = self.coords(pe_ids)
        best_axis, best_cost, best_vals = -1, -1.0, None
        for a, (c, w) in enumerate(zip(cs, self.weights)):
            vals = np.unique(c)
            if len(vals) < 2:
                continue
            # span cost: how much distance the axis contributes
            cost = (len(vals) // 2) * max(w, 1e-12)
            if cost > best_cost:
                best_axis, best_cost, best_vals = a, cost, vals
        if best_axis < 0:
            return None
        f = _smallest_factor(len(best_vals))
        chunk = len(best_vals) // f
        c = cs[best_axis]
        parts = []
        for i in range(f):
            sel = np.isin(c, best_vals[i * chunk:(i + 1) * chunk])
            parts.append(pe_ids[sel])
        return parts

    def spec_params(self) -> dict:
        return {"dims": list(self.dims), "weights": list(self.weights)}
