"""`tree` — the guide's homogeneous hierarchy behind the Topology contract.

A thin wrapper around :class:`repro.core.hierarchy.Hierarchy`: the wrapped
object computes every distance, so results are bit-for-bit identical to
the legacy ``Hierarchy`` path (tested).  It also duck-types the hierarchy
attributes (``factors``, ``distances``, ``k``, ``strides``, ``oracle``) so
the factor-driven construction algorithms run their exact legacy code.
"""

from __future__ import annotations

import numpy as np

from ..core.hierarchy import DistanceOracle, Hierarchy
from .base import Topology, register_topology


@register_topology("tree")
class TreeTopology(Topology):
    """Homogeneous tree hierarchy (guide §2.2): ``factors`` a_1..a_k
    innermost first, ``distances`` d_1..d_k non-decreasing."""

    def __init__(self, factors=None, distances=None, *,
                 hierarchy: Hierarchy | None = None):
        if hierarchy is None:
            hierarchy = Hierarchy(tuple(int(f) for f in factors),
                                  tuple(float(d) for d in distances))
        self.hierarchy = hierarchy

    # ----------------------------------------------------- hierarchy duck
    @property
    def factors(self) -> tuple:
        return self.hierarchy.factors

    @property
    def distances(self) -> tuple:
        return self.hierarchy.distances

    @property
    def k(self) -> int:
        return self.hierarchy.k

    @property
    def strides(self) -> np.ndarray:
        return self.hierarchy.strides

    @property
    def oracle(self) -> DistanceOracle:
        """The wrapped hierarchy's cached oracle — shared with every other
        Mapper/TreeTopology over the same ``Hierarchy`` instance."""
        return self.hierarchy.oracle

    # ------------------------------------------------------------ contract
    @property
    def n_pe(self) -> int:
        return self.hierarchy.n_pe

    def distance(self, p, q):
        return self.hierarchy.distance(p, q)

    def distance_matrix(self) -> np.ndarray:
        return self.hierarchy.distance_matrix()

    def matrix(self) -> np.ndarray:
        return self.hierarchy.oracle.matrix()

    def kernel_params(self) -> tuple:
        strides, dists = self.hierarchy.oracle.kernel_params()
        return ("tree", strides, dists)

    def split(self, pe_ids: np.ndarray) -> "list[np.ndarray] | None":
        """Split a level-l subtree block into its a_l child subtrees.
        ``pe_ids`` must be a full subtree's PE set (the recursion only ever
        produces those); unstructured sets are leaves."""
        pe_ids = np.asarray(pe_ids, dtype=np.int64)
        s = len(pe_ids)
        strides = self.strides
        lvl = int(np.searchsorted(strides, s))
        if lvl >= len(strides) or strides[lvl] != s or lvl <= 1 \
                or s <= self.factors[0]:
            return None
        a = self.factors[lvl - 1]
        return list(pe_ids.reshape(a, s // a))

    def spec_params(self) -> dict:
        return {"factors": [int(f) for f in self.factors],
                "distances": [float(d) for d in self.distances]}
