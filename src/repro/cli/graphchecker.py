"""`graphchecker` — validate the Metis graph format (guide §4.3)."""

from __future__ import annotations

import argparse
import sys

from ..core import GraphFormatError, read_metis


def main(argv=None):
    ap = argparse.ArgumentParser(prog="graphchecker", description=__doc__)
    ap.add_argument("file", help="Path to the graph file.")
    args = ap.parse_args(argv)
    try:
        g = read_metis(args.file)
    except GraphFormatError as e:
        print(f"The graph format seems to be corrupt:\n  {e}")
        sys.exit(1)
    print(f"The graph format seems correct. (n={g.n}, m={g.num_edges})")


if __name__ == "__main__":
    main()
