"""`evaluator` — compute the QAP objective of a given mapping (guide §4.4).

``--compare_spec spec.json`` additionally runs VieM with that
:class:`MappingSpec` and reports how the given mapping stacks up against
what the solver would produce.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

from ..core import Hierarchy, Mapper, MappingSpec, qap_objective, read_metis
from ..core.comm_model import logical_traffic_summary


def main(argv=None):
    ap = argparse.ArgumentParser(prog="evaluator", description=__doc__)
    ap.add_argument("file", help="Path to file (graph/model).")
    ap.add_argument("--input_mapping", required=True)
    ap.add_argument("--hierarchy_parameter_string", required=True)
    ap.add_argument("--distance_parameter_string", required=True)
    ap.add_argument("--compare_spec", default=None,
                    help="MappingSpec JSON: also solve with this spec and "
                         "print the comparison")
    args = ap.parse_args(argv)

    g = read_metis(args.file)
    h = Hierarchy.from_strings(args.hierarchy_parameter_string,
                               args.distance_parameter_string)
    perm = np.loadtxt(args.input_mapping, dtype=np.int64)
    if sorted(perm) != list(range(g.n)):
        sys.exit("evaluator: mapping is not a permutation of 0..n-1")
    j = qap_objective(g, h, perm)
    print(f"objective J(C,D,Pi) = {j:.6g}")
    for k, v in logical_traffic_summary(g, h, perm).items():
        print(f"  {k} = {v:.6g}")
    if args.compare_spec:
        try:
            spec = MappingSpec.from_json(
                Path(args.compare_spec).read_text()).validate()
            res = Mapper(h, spec).map(g)
        except (ValueError, OSError) as exc:
            sys.exit(f"evaluator: {exc}")
        ratio = j / res.final_objective if res.final_objective else \
            float("inf")
        print(f"viem[{spec.construction}+{spec.neighborhood}] "
              f"J = {res.final_objective:.6g}")
        print(f"given/viem ratio    = {ratio:.3f}")


if __name__ == "__main__":
    main()
