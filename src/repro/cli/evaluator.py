"""`evaluator` — compute the QAP objective of a given mapping (guide §4.4).

The mapping is scored against the same machine model it was built for:
the tree hierarchy flags, or ``--topology`` / ``--distance_matrix_file``
for any other registered machine model (same flags as ``viem``).

``--compare_spec spec.json`` additionally runs VieM with that
:class:`MappingSpec` and reports how the given mapping stacks up against
what the solver would produce.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

from ..core import Mapper, MappingSpec, qap_objective, read_metis
from ..core.comm_model import logical_traffic_summary
from .machine import add_topology_flags, topology_from_args


def main(argv=None):
    ap = argparse.ArgumentParser(prog="evaluator", description=__doc__)
    ap.add_argument("file", help="Path to file (graph/model).")
    ap.add_argument("--input_mapping", required=True)
    add_topology_flags(ap)
    ap.add_argument("--compare_spec", default=None,
                    help="MappingSpec JSON: also solve with this spec and "
                         "print the comparison")
    ap.add_argument("--seeds", type=int, default=1,
                    help="with --compare_spec: solve with N consecutive "
                         "seeds (spec.seed .. spec.seed+N-1) and report "
                         "best/median/spread — the multistart variance "
                         "portfolio search collapses")
    args = ap.parse_args(argv)
    if args.seeds < 1:
        sys.exit("evaluator: --seeds must be >= 1")

    g = read_metis(args.file)
    try:
        topo = topology_from_args(args)
    except (ValueError, OSError) as exc:
        sys.exit(f"evaluator: {exc}")
    perm = np.loadtxt(args.input_mapping, dtype=np.int64)
    if sorted(perm) != list(range(g.n)):
        sys.exit("evaluator: mapping is not a permutation of 0..n-1")
    if g.n != topo.n_pe:
        sys.exit(f"evaluator: model has {g.n} vertices but the machine "
                 f"specifies {topo.n_pe} PEs — they must match")
    j = qap_objective(g, topo, perm)
    print(f"machine topology    = {topo.kind} ({topo.n_pe} PEs)")
    print(f"objective J(C,D,Pi) = {j:.6g}")
    if hasattr(topo, "hierarchy"):     # per-level traffic is tree-specific
        for k, v in logical_traffic_summary(g, topo.hierarchy,
                                            perm).items():
            print(f"  {k} = {v:.6g}")
    if args.compare_spec:
        try:
            spec = MappingSpec.from_json(
                Path(args.compare_spec).read_text()).validate()
            # staged explicitly so the plan geometry is reportable (and
            # so every seed reuses the one compiled plan)
            plan = Mapper(topo, spec).lower_for(g)
            results = [plan.execute(g, seed=spec.seed + i)
                       for i in range(args.seeds)]
        except (ValueError, OSError) as exc:
            sys.exit(f"evaluator: {exc}")
        js = sorted(r.final_objective for r in results)
        best = js[0]
        ratio = j / best if best else float("inf")
        print(f"viem[{spec.construction}+{spec.neighborhood}] "
              f"J = {best:.6g}")
        if args.seeds > 1:
            median = float(np.median(js))
            print(f"viem seeds          = {args.seeds} "
                  f"(seed {spec.seed}..{spec.seed + args.seeds - 1})")
            print(f"viem best/median    = {best:.6g} / {median:.6g}")
            print(f"viem spread         = {js[-1] - js[0]:.6g} "
                  f"(worst {js[-1]:.6g})")
        print(f"viem plan           = bucket {plan.bucket.tag()}, "
              f"{len(plan.machines)} level(s), engine={spec.engine}")
        print(f"given/viem ratio    = {ratio:.3f}")


if __name__ == "__main__":
    main()
