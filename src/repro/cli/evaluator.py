"""`evaluator` — compute the QAP objective of a given mapping (guide §4.4)."""

from __future__ import annotations

import argparse
import sys

import numpy as np

from ..core import Hierarchy, qap_objective, read_metis
from ..core.comm_model import logical_traffic_summary


def main(argv=None):
    ap = argparse.ArgumentParser(prog="evaluator", description=__doc__)
    ap.add_argument("file", help="Path to file (graph/model).")
    ap.add_argument("--input_mapping", required=True)
    ap.add_argument("--hierarchy_parameter_string", required=True)
    ap.add_argument("--distance_parameter_string", required=True)
    args = ap.parse_args(argv)

    g = read_metis(args.file)
    h = Hierarchy.from_strings(args.hierarchy_parameter_string,
                               args.distance_parameter_string)
    perm = np.loadtxt(args.input_mapping, dtype=np.int64)
    if sorted(perm) != list(range(g.n)):
        sys.exit("evaluator: mapping is not a permutation of 0..n-1")
    j = qap_objective(g, h, perm)
    print(f"objective J(C,D,Pi) = {j:.6g}")
    for k, v in logical_traffic_summary(g, h, perm).items():
        print(f"  {k} = {v:.6g}")


if __name__ == "__main__":
    main()
