"""`viem` — the mapping program (guide §4.1), flag-for-flag.

Usage:
    python -m repro.cli.viem graph.metis \
        --hierarchy_parameter_string=4:8:16 \
        --distance_parameter_string=1:10:100 \
        [--topology=torus --topology_params='{"dims": [16, 16]}'] \
        [--distance_matrix_file=D.metis]    # explicit matrix (sparse QAP)
        [--seed=0] [--preconfiguration_mapping=eco]
        [--construction_algorithm=hierarchytopdown]
        [--distance_construction_algorithm=hierarchyonline]
        [--local_search_neighborhood=communication]
        [--communication_neighborhood_dist=10]
        [--engine=host|device]          # host drivers vs jitted device sweep
        [--explain]                     # lower only; print plan.describe()
        [--multilevel] [--multilevel_levels=4] [--multilevel_coarsen_min=64]
        [--portfolio] [--portfolio_lanes=8] [--portfolio_rounds=4]
        [--portfolio_tabu_tenure=8] [--portfolio_kick=0.15]
        [--portfolio_stagnation=3]
        [--kernel_block_rows=N] [--kernel_lanes=N]   # pin tile geometry
        [--kernel_quantize={auto,off,int8,int16}]    # distance packing
        [--preconfiguration={strong,eco,fast}]  # one flag: partition +
                                        # engine sweeps + multilevel knobs
        [--config=spec.json]            # load a MappingSpec (flags override)
        [--output_filename=permutation]
    python -m repro.cli.viem --list-algorithms

Algorithm and machine-model ``choices`` come from the registries, so
third-party ``@register_construction`` / ``@register_neighborhood`` /
``@register_topology`` plug-ins are addressable here without touching
this file.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

import numpy as np

from ..core import Mapper, MappingSpec, list_constructions, \
    list_neighborhoods, read_metis
from .machine import add_topology_flags, machine_flags_given, \
    topology_from_args


def _print_algorithms():
    from ..topology import list_topologies
    print("constructions:")
    for name in list_constructions():
        print(f"  {name}")
    print("neighborhoods:")
    for name in list_neighborhoods():
        print(f"  {name}")
    print("  none  (skip local search)")
    print("topologies:")
    for name in list_topologies():
        print(f"  {name}")


def build_spec(args) -> MappingSpec:
    """--config (if given) seeds the spec; explicit flags override it."""
    base = None
    if args.config:
        base = MappingSpec.from_json(Path(args.config).read_text())
    return MappingSpec.from_flags(args, base=base).validate()


def main(argv=None):
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "remap-watch":
        # the closed-loop monitor driver (repro.monitor): profile →
        # drift → what-if replay → incremental remap
        from .remap_watch import main as remap_watch_main
        return remap_watch_main(argv[1:])
    if argv and argv[0] == "lint":
        # the invariant lint engine (repro.staticcheck): VIEM001-004
        # AST rules + the lowered-jaxpr audit
        from ..staticcheck.__main__ import main as lint_main
        return lint_main(argv[1:])
    ap = argparse.ArgumentParser(prog="viem", description=__doc__)
    ap.add_argument("file", nargs="?", help="Path to file (model).")
    ap.add_argument("--list-algorithms", action="store_true",
                    help="print registered algorithms and exit")
    ap.add_argument("--config", default=None,
                    help="path to a MappingSpec JSON; explicit flags "
                         "override values from the file")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--preconfiguration_mapping", "--preconfiguration",
                    default=None, choices=["strong", "eco", "fast"],
                    help="one coherent quality/speed knob: partitioner "
                         "effort (seed trials, FM passes), device-engine "
                         "sweep budget (32/64/128), and — with "
                         "--multilevel — V-cycle depth (2/4/6 levels)")
    ap.add_argument("--construction_algorithm", default=None,
                    choices=list_constructions())
    ap.add_argument("--distance_construction_algorithm", default="hierarchy",
                    choices=["hierarchy", "hierarchyonline"])
    add_topology_flags(ap)
    ap.add_argument("--local_search_neighborhood", default=None,
                    choices=list_neighborhoods() + ["none"])
    ap.add_argument("--communication_neighborhood_dist", type=int,
                    default=None)
    ap.add_argument("--parallel_sweeps",
                    action=argparse.BooleanOptionalAction, default=None)
    ap.add_argument("--engine", default=None, choices=["host", "device"],
                    help="where the refinement loop runs: the reference "
                         "host drivers, or the jitted device-resident "
                         "sweep engine (repro.engine)")
    ap.add_argument("--explain", action="store_true",
                    help="lower the plan for this graph WITHOUT executing "
                         "and pretty-print plan.describe(): levels, "
                         "padded shape bucket, kernel form and selected "
                         "KernelConfig per level (tile geometry, "
                         "quantized table dtype under 'kernels'), engine "
                         "sweep budgets")
    ap.add_argument("--multilevel",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="coarsen → map → uncoarsen V-cycle over the "
                         "device engine (repro.multilevel); knob "
                         "defaults follow --preconfiguration")
    ap.add_argument("--multilevel_levels", type=int, default=None,
                    help="max V-cycle levels incl. the finest (1 = flat, "
                         "bit-identical to the plain device engine)")
    ap.add_argument("--multilevel_coarsen_min", type=int, default=None,
                    help="stop contracting below this many coarse "
                         "vertices")
    ap.add_argument("--portfolio",
                    action=argparse.BooleanOptionalAction, default=None,
                    help="device-side portfolio search: vmapped multistart "
                         "lanes with tabu memory, perturbation kicks, and "
                         "tournament selection (repro.portfolio)")
    ap.add_argument("--portfolio_lanes", type=int, default=None,
                    help="restart trajectories per request (one vmapped "
                         "engine call; 1 = single-trajectory)")
    ap.add_argument("--portfolio_rounds", type=int, default=None,
                    help="refine rounds at the finest level (rounds-1 "
                         "perturb→refine rounds after the first)")
    ap.add_argument("--portfolio_tabu_tenure", type=int, default=None,
                    help="sweeps of tabu memory per applied exchange "
                         "(0 = monotone sweep, bit-identical)")
    ap.add_argument("--portfolio_kick", type=float, default=None,
                    help="fraction of vertices each between-round "
                         "perturbation kick touches")
    ap.add_argument("--portfolio_stagnation", type=int, default=None,
                    help="stop after this many rounds without improving "
                         "the incumbent")
    ap.add_argument("--kernel_block_rows", type=int, default=None,
                    help="pin the kernel reduction-tile row count "
                         "(default: derived from the plan bucket and "
                         "backend at lower time; see --explain "
                         "'kernels')")
    ap.add_argument("--kernel_lanes", type=int, default=None,
                    help="pin the kernel lane width (multiple of 128; "
                         "default: derived)")
    ap.add_argument("--kernel_quantize", default=None,
                    choices=["auto", "off", "int8", "int16"],
                    help="matrix-topology distance-table packing: 'auto' "
                         "packs to int8/int16 when lossless (bit-"
                         "identical results, 4-8x less gather "
                         "bandwidth), 'off' keeps float32 tables, an "
                         "explicit width errors if the table does not "
                         "fit losslessly")
    ap.add_argument("--profile", metavar="TRACE_JSON", default=None,
                    help="record tracer spans for this run and write a "
                         "Chrome trace_event JSON (load in Perfetto or "
                         "chrome://tracing); implies --telemetry so the "
                         "trace carries per-sweep engine counter tracks")
    ap.add_argument("--telemetry", action="store_true",
                    help="collect device-engine per-sweep counters "
                         "(exchanges, tabu-masked pairs, aspiration "
                         "fires, downhill escapes) and print a summary — "
                         "a runtime toggle, never a recompile")
    ap.add_argument("--metrics-out", metavar="FILE", default=None,
                    help="write the run's metrics registry as Prometheus "
                         "text (objectives, timings, engine counters) — "
                         "the same exposition MappingService.prometheus() "
                         "serves")
    ap.add_argument("--output_filename", default="permutation")
    args = ap.parse_args(argv)

    if args.list_algorithms:
        _print_algorithms()
        return

    if not args.file:
        ap.error("the graph file argument is required")

    try:
        spec = build_spec(args)
        # the machine model: explicit CLI flags win; otherwise a machine
        # carried inside --config (spec.topology) is honored
        if spec.topology is not None and not machine_flags_given(args):
            topo = spec.topology.build()
        else:
            topo = topology_from_args(args)
    except (ValueError, OSError) as exc:
        sys.exit(f"viem: {exc}")
    g = read_metis(args.file)
    if g.n != topo.n_pe:
        sys.exit(f"viem: model has {g.n} vertices but the machine "
                 f"specifies {topo.n_pe} PEs — they must match (guide §4.1)")
    mapper = Mapper(topo, spec)
    if args.explain:
        import json
        print(json.dumps(mapper.lower_for(g).describe(), indent=2))
        return
    tracer = None
    if args.profile:
        from ..obs import get_tracer
        tracer = get_tracer()
        tracer.enable()
    telemetry = args.telemetry or bool(args.profile)
    # `hierarchyonline` vs `hierarchy` is a memory/speed knob; the oracle
    # is online in both cases here and they agree bit-for-bit (tested).
    res = mapper.map(g, telemetry=telemetry)
    np.savetxt(args.output_filename, res.perm, fmt="%d")
    print(f"machine topology     = {topo.kind} ({topo.n_pe} PEs)")
    print(f"initial objective  J = {res.initial_objective:.6g}")
    print(f"final objective    J = {res.final_objective:.6g}")
    print(f"improvement          = {res.improvement:.2%}")
    print(f"construction time    = {res.construction_seconds:.3f}s")
    print(f"local search time    = {res.search_seconds:.3f}s")
    tel = None if res.search_stats is None else res.search_stats.telemetry
    if telemetry and tel is not None:
        s = tel.summary()
        print(f"engine sweeps        = {s['sweeps']} "
              f"(passes {s['passes']})")
        print(f"engine exchanges     = {s['exchanges']}")
        print(f"tabu masked pairs    = {s['tabu_masked']}")
        print(f"aspiration fires     = {s['aspiration_fires']} "
              f"(rate {s['aspiration_rate']:.3f}/pass)")
        print(f"downhill escapes     = {s['downhill_escapes']}")
    if tracer is not None:
        from ..obs import write_chrome_trace
        n_events = write_chrome_trace(tracer.spans(), args.profile)
        print(f"wrote {args.profile} ({len(tracer)} spans, "
              f"{n_events} trace events)")
    if args.metrics_out:
        from ..obs import MetricsRegistry
        reg = MetricsRegistry()
        with reg.lock:
            reg.counter("run.count").inc()
            reg.gauge("run.initial_objective").set(res.initial_objective)
            reg.gauge("run.final_objective").set(res.final_objective)
            reg.gauge("run.improvement").set(res.improvement)
            reg.histogram("run.construction_seconds").observe(
                res.construction_seconds)
            reg.histogram("run.search_seconds").observe(
                res.search_seconds)
            if tel is not None:
                s = tel.summary()
                reg.counter("engine.sweeps").inc(s["sweeps"])
                reg.counter("engine.exchanges").inc(s["exchanges"])
                reg.counter("engine.tabu_masked").inc(
                    s["tabu_masked"])
        with open(args.metrics_out, "w") as fh:
            fh.write(reg.to_prometheus())
        print(f"wrote {args.metrics_out}")
    print(f"wrote {args.output_filename}")


if __name__ == "__main__":
    main()
