"""`viem` — the mapping program (guide §4.1), flag-for-flag.

Usage:
    python -m repro.cli.viem graph.metis \
        --hierarchy_parameter_string=4:8:16 \
        --distance_parameter_string=1:10:100 \
        [--seed=0] [--preconfiguration_mapping=eco]
        [--construction_algorithm=hierarchytopdown]
        [--distance_construction_algorithm=hierarchyonline]
        [--local_search_neighborhood=communication]
        [--communication_neighborhood_dist=10]
        [--output_filename=permutation]
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from ..core import Hierarchy, map_processes, read_metis


def main(argv=None):
    ap = argparse.ArgumentParser(prog="viem", description=__doc__)
    ap.add_argument("file", help="Path to file (model).")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preconfiguration_mapping", default="eco",
                    choices=["strong", "eco", "fast"])
    ap.add_argument("--construction_algorithm", default="hierarchytopdown",
                    choices=["random", "identity", "growing",
                             "hierarchybottomup", "hierarchytopdown"])
    ap.add_argument("--distance_construction_algorithm", default="hierarchy",
                    choices=["hierarchy", "hierarchyonline"])
    ap.add_argument("--hierarchy_parameter_string", required=True)
    ap.add_argument("--distance_parameter_string", required=True)
    ap.add_argument("--local_search_neighborhood", default="communication",
                    choices=["nsquare", "nsquarepruned", "communication"])
    ap.add_argument("--communication_neighborhood_dist", type=int,
                    default=10)
    ap.add_argument("--output_filename", default="permutation")
    args = ap.parse_args(argv)

    g = read_metis(args.file)
    h = Hierarchy.from_strings(args.hierarchy_parameter_string,
                               args.distance_parameter_string)
    if g.n != h.n_pe:
        sys.exit(f"viem: model has {g.n} vertices but the hierarchy "
                 f"specifies {h.n_pe} PEs — they must match (guide §4.1)")
    # `hierarchyonline` vs `hierarchy` is a memory/speed knob; the oracle
    # is online in both cases here and they agree bit-for-bit (tested).
    res = map_processes(
        g, h,
        construction_algorithm=args.construction_algorithm,
        local_search_neighborhood=args.local_search_neighborhood,
        communication_neighborhood_dist=args.communication_neighborhood_dist,
        preconfiguration_mapping=args.preconfiguration_mapping,
        seed=args.seed)
    np.savetxt(args.output_filename, res.perm, fmt="%d")
    print(f"initial objective  J = {res.initial_objective:.6g}")
    print(f"final objective    J = {res.final_objective:.6g}")
    print(f"improvement          = {res.improvement:.2%}")
    print(f"construction time    = {res.construction_seconds:.3f}s")
    print(f"local search time    = {res.search_seconds:.3f}s")
    print(f"wrote {args.output_filename}")


if __name__ == "__main__":
    main()
