"""`generate_model` — build a model of computation and communication by
partitioning an application graph (guide §4.2)."""

from __future__ import annotations

import argparse

from ..core import read_metis, write_metis
from ..core.comm_model import generate_model


def main(argv=None):
    ap = argparse.ArgumentParser(prog="generate_model", description=__doc__)
    ap.add_argument("file", help="Graph to partition and build the model "
                                 "from.")
    ap.add_argument("--k", type=int, required=True,
                    help="Number of blocks, i.e. vertices in the model.")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preconfiguration", default="eco",
                    choices=["strong", "eco", "fast", "fastsocial",
                             "ecosocial", "strongsocial"])
    ap.add_argument("--imbalance", type=float, default=3.0)
    ap.add_argument("--output_filename", default="model.graph")
    args = ap.parse_args(argv)

    g = read_metis(args.file)
    pre = args.preconfiguration.replace("social", "")  # social ≡ base here
    model, labels = generate_model(g, args.k, preconfiguration=pre,
                                   imbalance=args.imbalance / 100.0,
                                   seed=args.seed)
    write_metis(model, args.output_filename)
    print(f"partitioned n={g.n} m={g.num_edges} into k={args.k} blocks; "
          f"model has {model.num_edges} edges")
    print(f"wrote {args.output_filename}")


if __name__ == "__main__":
    main()
