"""`viem remap-watch` — drive the closed remapping loop from the CLI.

Maps the baseline graph once, then replays a stream of traffic windows
through the monitor, printing one decision line per window:

    python -m repro.cli.viem remap-watch graph.metis \
        --hierarchy_parameter_string=4:8:16 \
        --distance_parameter_string=1:10:100 \
        [--windows=8] [--window-file=w1.metis ...]   # live windows
        [--inject-shift=3 --shift-factor=8 --shift-frac=0.25]
        [--jitter=0.01] [--alpha=0.5]
        [--drift-high=0.1 --drift-low=0.05 --patience=2]
        [--margin=0.02] [--dirty-hops=1] [--dirty-rel-tol=0.05]
        [--evict-host=N]      # simulated straggler REBALANCE signal
        [--profile=trace.json] [--metrics-out=metrics.prom]

Without ``--window-file`` the windows are synthesized from the baseline:
multiplicative jitter every window, plus — from ``--inject-shift``
onwards — a sustained traffic shift multiplying every edge incident to
a random ``--shift-frac`` of vertices by ``--shift-factor``.  The
decision spans land in the ``--profile`` Perfetto trace; the monitor
counters land in ``--metrics-out`` (Prometheus text).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from ..core import Mapper, MappingSpec, read_metis
from ..core.graph import from_edges
from .machine import add_topology_flags, topology_from_args


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="viem remap-watch",
                                 description=__doc__)
    ap.add_argument("file", help="baseline communication graph (METIS)")
    add_topology_flags(ap)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preconfiguration_mapping", "--preconfiguration",
                    dest="preconfiguration_mapping", default="eco",
                    choices=["strong", "eco", "fast"])
    ap.add_argument("--communication_neighborhood_dist", type=int,
                    default=10)
    ap.add_argument("--windows", type=int, default=8,
                    help="number of synthesized traffic windows")
    ap.add_argument("--window-file", action="append", default=None,
                    metavar="GRAPH",
                    help="explicit per-window traffic graph (repeatable; "
                         "overrides synthesis)")
    ap.add_argument("--jitter", type=float, default=0.01,
                    help="multiplicative weight noise per window")
    ap.add_argument("--inject-shift", type=int, default=None,
                    metavar="WINDOW",
                    help="from this window on, scale a vertex subset's "
                         "traffic by --shift-factor")
    ap.add_argument("--shift-factor", type=float, default=8.0)
    ap.add_argument("--shift-frac", type=float, default=0.25)
    ap.add_argument("--evict-host", type=int, default=None,
                    help="simulate a straggler on this host index "
                         "(REBALANCE through the replay gate)")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="profiler EMA weight of the newest window")
    ap.add_argument("--drift-high", type=float, default=0.10)
    ap.add_argument("--drift-low", type=float, default=0.05)
    ap.add_argument("--patience", type=int, default=2)
    ap.add_argument("--margin", type=float, default=0.02,
                    help="replay gate: required relative step-time win")
    ap.add_argument("--dirty-hops", type=int, default=1)
    ap.add_argument("--dirty-rel-tol", type=float, default=0.05)
    ap.add_argument("--telemetry", action="store_true")
    ap.add_argument("--profile", metavar="TRACE_JSON", default=None)
    ap.add_argument("--metrics-out", metavar="FILE", default=None,
                    help="write the monitor registry as Prometheus text")
    args = ap.parse_args(argv)

    from ..monitor import MonitorConfig, RemapMonitor
    from ..runtime.fault_tolerance import Action

    try:
        topo = topology_from_args(args)
        g = read_metis(args.file)
    except (ValueError, OSError) as exc:
        sys.exit(f"viem remap-watch: {exc}")
    if g.n != topo.n_pe:
        sys.exit(f"viem remap-watch: model has {g.n} vertices but the "
                 f"machine specifies {topo.n_pe} PEs")
    tracer = None
    if args.profile:
        from ..obs import get_tracer
        tracer = get_tracer()
        tracer.enable()
    spec = MappingSpec(
        construction="hierarchytopdown", neighborhood="communication",
        neighborhood_dist=args.communication_neighborhood_dist,
        preconfiguration=args.preconfiguration_mapping,
        engine="device", seed=args.seed)
    # pow2 bucket headroom: drifted windows keep fitting the executable
    plan = Mapper(topo, spec).lower_for(g, schedule="pow2")
    cfg = MonitorConfig(
        alpha=args.alpha, min_weight=0.0, drift_high=args.drift_high,
        drift_low=args.drift_low, drift_patience=args.patience,
        replay_margin=args.margin, dirty_rel_tol=args.dirty_rel_tol,
        dirty_hops=args.dirty_hops, telemetry=args.telemetry)
    mon = RemapMonitor(plan, g, config=cfg, seed=args.seed)
    print(f"baseline J = {plan.objective(g, mon.incumbent):.6g} "
          f"({topo.kind}, {topo.n_pe} PEs)")

    if args.window_file:
        windows = [read_metis(f) for f in args.window_file]
    else:
        rng = np.random.default_rng(args.seed)
        u, v, w = g.edge_list()
        shifted = np.zeros(g.n, dtype=bool)
        shifted[rng.permutation(g.n)[:max(1, int(args.shift_frac
                                                 * g.n))]] = True
        windows = []
        for t in range(args.windows):
            wt = w * rng.uniform(1 - args.jitter, 1 + args.jitter,
                                 size=len(w))
            if args.inject_shift is not None and t >= args.inject_shift:
                wt = np.where(shifted[u] | shifted[v],
                              wt * args.shift_factor, wt)
            windows.append(from_edges(g.n, u, v, wt))

    for t, win in enumerate(windows):
        if args.evict_host is not None and t == len(windows) // 2:
            mon.handle_action(Action.REBALANCE, [args.evict_host])
            print(f"window {t}: injected REBALANCE(host="
                  f"{args.evict_host})")
        mon.observe_graph(win)
        r = mon.tick()
        verdict = ("" if r.verdict is None else
                   f" win={r.verdict.predicted_improvement:+.2%}"
                   f" J {r.verdict.objective_incumbent:.6g}->"
                   f"{r.verdict.objective_candidate:.6g}")
        state = ("remapped" if r.remapped
                 else r.skipped or ("rejected" if r.verdict else
                                    ("armed" if r.drift.armed else
                                     "disarmed")))
        forced = f" forced={r.forced_by}" if r.forced_by else ""
        print(f"window {t}: score={r.drift.score:.4f} "
              f"l1={r.drift.l1:.4f} dJ={r.drift.objective_delta:+.4f} "
              f"{state}{forced} dirty={r.dirty} "
              f"active={r.active_pairs}/{len(mon.pairs)} "
              f"retraces={r.retraces}{verdict}")

    print(f"remaps committed     = {mon.remaps}")
    print(f"final objective    J = "
          f"{plan.objective(windows[-1], mon.incumbent):.6g} (last window)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(mon.registry.to_prometheus())
        print(f"wrote {args.metrics_out}")
    if tracer is not None:
        from ..obs import write_chrome_trace
        n_events = write_chrome_trace(tracer.spans(), args.profile)
        print(f"wrote {args.profile} ({len(tracer)} spans, "
              f"{n_events} trace events)")


if __name__ == "__main__":
    main()
