"""Shared machine-model flags for the `viem` and `evaluator` CLIs.

The guide's tree flags stay primary (``--hierarchy_parameter_string`` /
``--distance_parameter_string``); ``--topology`` selects any registered
machine model instead, parameterized by ``--topology_params`` (a JSON
object passed to the backend factory) or, for explicit matrices,
``--distance_matrix_file`` (Metis graph / .npy / dense text — guide §3).
"""

from __future__ import annotations

import json


def add_topology_flags(ap) -> None:
    from ..topology import list_topologies
    ap.add_argument("--topology", default=None,
                    choices=list_topologies(),
                    help="machine model (default: tree built from the "
                         "hierarchy/distance parameter strings)")
    ap.add_argument("--topology_params", default=None, metavar="JSON",
                    help="JSON object of constructor parameters for "
                         "--topology, e.g. '{\"dims\": [16, 16]}'")
    ap.add_argument("--distance_matrix_file", default=None,
                    help="explicit distance matrix (Metis graph with edge "
                         "weight = distance, .npy, or dense text); "
                         "implies --topology=matrix")
    ap.add_argument("--hierarchy_parameter_string")
    ap.add_argument("--distance_parameter_string")


def machine_flags_given(args) -> bool:
    """True when the invocation names a machine model explicitly (so it
    should override a machine carried inside a ``--config`` spec)."""
    return bool(args.topology or args.topology_params
                or args.distance_matrix_file
                or args.hierarchy_parameter_string
                or args.distance_parameter_string)


def _build(kind: str, params: dict):
    from ..topology import make_topology
    try:
        return make_topology(kind, **params)
    except TypeError as exc:
        # e.g. --topology=tree with partial --topology_params: surface the
        # factory's complaint as a user-facing CLI error, not a traceback
        raise ValueError(
            f"invalid parameters for topology {kind!r}: {exc}") from exc


def topology_from_args(args):
    """Build the machine model a CLI invocation asked for.

    Raises ``ValueError`` with a user-facing message on conflicting or
    missing flags."""
    params = {}
    if args.topology_params:
        params = json.loads(args.topology_params)
        if not isinstance(params, dict):
            raise ValueError("--topology_params must be a JSON object")
    if args.distance_matrix_file:
        if args.topology not in (None, "matrix"):
            raise ValueError("--distance_matrix_file implies "
                             f"--topology=matrix, not {args.topology!r}")
        params.setdefault("file", args.distance_matrix_file)
        return _build("matrix", params)
    kind = args.topology or "tree"
    if kind == "tree" and not params:
        if not args.hierarchy_parameter_string or \
                not args.distance_parameter_string:
            raise ValueError(
                "--hierarchy_parameter_string and "
                "--distance_parameter_string are required for the tree "
                "machine model (guide §4.1), or pick --topology=...")
        from ..core.hierarchy import Hierarchy
        from ..topology import TreeTopology
        return TreeTopology(hierarchy=Hierarchy.from_strings(
            args.hierarchy_parameter_string,
            args.distance_parameter_string))
    return _build(kind, params)
