"""Dry-run analysis: HLO cost extraction + roofline model."""

from .hlo import HloCost, analyze, parse_module
from .roofline import Roofline, roofline_from_cost

__all__ = ["HloCost", "analyze", "parse_module", "Roofline",
           "roofline_from_cost"]
