"""Three-term roofline model for TPU v5e from dry-run HLO analysis.

    compute term    = per-device FLOPs / peak FLOP/s
    memory term     = per-device HBM bytes / HBM bandwidth
    collective term = per-device ICI wire bytes / ICI bw
                      + per-device DCN wire bytes / DCN bw  (cross-pod)

All inputs come from :mod:`repro.analysis.hlo` (per-device, trip-count
corrected).  The dominant term is the bottleneck; the roofline fraction of
an iso-FLOP ideal step is  compute / max(compute, memory, collective).
"""

from __future__ import annotations

from dataclasses import dataclass

from .hlo import HloCost

# TPU v5e hardware constants (per chip) — from the assignment spec.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link; v5e has multiple links but we
                                # price conservatively at one link's worth
DCN_BW = 6.25e9                 # B/s per chip across pods (50 Gb/s NIC share)


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    ici_s: float
    dcn_s: float
    flops: float
    hbm_bytes: float
    ici_bytes: float
    dcn_bytes: float
    model_flops: float = 0.0      # analytic 6·N·D (set by caller)

    @property
    def collective_s(self) -> float:
        return self.ici_s + self.dcn_s

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound on step time: perfect overlap → max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of ideal (compute-only) throughput this step can reach
        assuming perfect overlap: compute / max-term."""
        if self.step_time_s == 0:
            return 0.0
        return self.compute_s / self.step_time_s

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-device-normalized by the caller):
        <1 means remat/redundant compute inflates the HLO."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU: useful model FLOPs over peak during step_time."""
        if self.step_time_s == 0 or self.model_flops == 0:
            return 0.0
        return self.model_flops / (self.step_time_s * PEAK_FLOPS_BF16)

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "ici_s": self.ici_s, "dcn_s": self.dcn_s,
            "bound": self.bound, "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
            "model_flops_ratio": self.model_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def roofline_from_cost(cost: HloCost, model_flops_per_device: float = 0.0,
                       peak_flops: float = PEAK_FLOPS_BF16,
                       hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW,
                       dcn_bw: float = DCN_BW) -> Roofline:
    return Roofline(
        compute_s=cost.flops / peak_flops,
        memory_s=cost.hbm_bytes / hbm_bw,
        ici_s=cost.ici_bytes / ici_bw,
        dcn_s=cost.dcn_bytes / dcn_bw,
        flops=cost.flops,
        hbm_bytes=cost.hbm_bytes,
        ici_bytes=cost.ici_bytes,
        dcn_bytes=cost.dcn_bytes,
        model_flops=model_flops_per_device,
    )
