"""Post-optimization HLO text analyzer: FLOPs, HBM traffic, collective bytes.

Why not ``compiled.cost_analysis()``: measured on this container it reports
per-partition numbers (fine) but counts ``while`` (scan) bodies **once**
regardless of trip count — a 56-layer scanned transformer would be
under-counted 56×.  This module parses ``compiled.as_text()`` and:

  * multiplies instruction costs by loop trip counts (``backend_config``
    known_trip_count when present, else the max s32 constant in the while's
    condition computation — scans lower to `i < N` conditions),
  * computes dot FLOPs exactly from shapes + contracting dims
    (2 · numel(out) · Π contracted), elementwise/reduce ops at 1 FLOP/elem,
  * approximates HBM traffic as Σ (operand + result bytes) of *top-level*
    instructions — instructions inside fusion computations don't touch HBM,
  * prices collectives with ring-algorithm wire factors and replica-group
    sizes parsed from both iota (``[32,16]<=[512]``, with optional
    transpose suffix) and explicit-list syntax, and splits traffic into
    intra-pod (ICI) vs cross-pod (DCN) given a pod size.

All shapes in SPMD-partitioned HLO are per-device, so every number here is
per-device — exactly what the roofline terms need.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# opcodes that don't move HBM bytes at top level
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "while", "conditional", "call", "iota", "rng-get-and-update-state",
    "partition-id", "replica-id", "domain", "opt-barrier",
}

# elementwise/shape ops that TPU XLA fuses into neighboring producers/
# consumers — their traffic is accounted by the ops they fuse into.  The
# CPU backend (our dry-run host) leaves many of these unfused at top level;
# counting them would overstate TPU HBM traffic by ~10×.
_FUSED_FREE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "negate", "abs", "sign", "cosine", "sine", "sqrt", "rsqrt", "cbrt",
    "compare", "select", "and", "or", "xor", "not", "clamp", "convert",
    "broadcast", "reshape", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "is-finite", "reduce-precision", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "stochastic-convert", "erf", "logistic", "remainder", "rem",
}


def shape_numel_bytes(shape_str: str) -> tuple[int, int]:
    """(numel, bytes) of a shape string; tuples summed (numel of first part)."""
    total_b = 0
    total_n = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_n += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_n, total_b


@dataclass
class Instruction:
    name: str
    shape: str
    opcode: str
    rest: str           # operand list + attributes (raw tail of the line)

    def attr(self, key: str) -> str | None:
        m = re.search(key + r"=(\{[^}]*\}|\[[^\]]*\][^,]*|[^,\s]+)", self.rest)
        return m.group(1) if m else None

    @property
    def operands(self) -> list[str]:
        # operands are the %refs before the first '), ' attribute boundary
        depth = 0
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return _OPERAND_RE.findall(self.rest[:end])


@dataclass
class Computation:
    name: str
    instructions: list[Instruction] = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # name -> shape str


@dataclass
class CollectiveStat:
    op: str
    wire_bytes: float = 0.0      # ring-priced per-device wire traffic
    raw_bytes: float = 0.0       # operand bytes × multiplier
    count: float = 0.0
    group_size: int = 1
    cross_pod: bool = False
    ici_wire: float = 0.0        # hierarchical decomposition (DESIGN §4):
    dcn_wire: float = 0.0        # RS-in-pod → AR-across-pods → AG-in-pod


@dataclass
class HloCost:
    """Per-device cost model extracted from optimized HLO."""
    flops: float = 0.0                 # total (dot + elementwise)
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: list = field(default_factory=list)
    trip_counts: dict = field(default_factory=dict)

    @property
    def ici_bytes(self) -> float:
        return sum(c.ici_wire for c in self.collectives)

    @property
    def dcn_bytes(self) -> float:
        return sum(c.dcn_wire for c in self.collectives)

    @property
    def collective_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives)

    def by_type(self) -> dict:
        agg: dict = defaultdict(float)
        for c in self.collectives:
            agg[c.op] += c.wire_bytes
        return dict(agg)


# ---------------------------------------------------------------- parsing
def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith(("HloModule", "//", "#")):
            continue
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if stripped.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if stripped == "}" or stripped.startswith("}"):
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi and cur is not None:
            ins = Instruction(name=mi.group(1), shape=mi.group(2),
                              opcode=mi.group(3), rest=mi.group(4))
            cur.instructions.append(ins)
            cur.symbols[ins.name] = ins.shape
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _find_trip_count(while_ins: Instruction,
                     comps: dict[str, Computation]) -> int:
    bc = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)', while_ins.rest)
    if bc:
        return int(bc.group(1))
    cond = re.search(r"condition=%?([\w.\-]+)", while_ins.rest)
    if cond and cond.group(1) in comps:
        best = 1
        for ins in comps[cond.group(1)].instructions:
            if ins.opcode == "constant" and ins.shape.startswith(("s32", "u32", "s64")):
                m = re.match(r"\s*(\d+)", ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best
    return 1


def _replica_group_info(ins: Instruction, pod_size: int | None
                        ) -> tuple[int, int]:
    """(group size, pods spanned) from the replica_groups attr."""
    rest = ins.rest

    def pods_of(groups):
        if not pod_size:
            return 1
        best = 1
        for grp in groups:
            best = max(best, len({i // pod_size for i in grp}))
        return best

    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?",
                  rest)
    if m:
        n_groups, g_size = int(m.group(1)), int(m.group(2))
        shape = tuple(int(d) for d in m.group(3).split(","))
        ids = np.arange(int(np.prod(shape))).reshape(shape)
        if m.group(5):
            ids = ids.transpose(tuple(int(d) for d in m.group(5).split(",")))
        groups = ids.reshape(n_groups, g_size)
        return g_size, pods_of(groups.tolist())
    mg = re.search(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}", rest)
    if mg:
        groups = [[int(x) for x in grp.split(",")]
                  for grp in re.findall(r"\{([\d,]+)\}", mg.group(1))]
        return len(groups[0]), pods_of(groups)
    if "source_target_pairs" in rest:
        pairs = re.findall(r"\{(\d+),(\d+)\}", rest)
        cross = pod_size and any(
            int(a) // pod_size != int(b) // pod_size for a, b in pairs)
        return 2, 2 if cross else 1
    return 2, 1


def _ring_factor(op: str, g: int) -> float:
    """Per-device wire bytes per operand byte under ring algorithms."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return float(g - 1)          # operand is the local shard
    if op in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
        return (g - 1) / g           # operand is the full local buffer
    if op == "collective-broadcast":
        return 1.0
    return 1.0                       # collective-permute


def _dot_flops(ins: Instruction, symbols: dict) -> float:
    out_numel, _ = shape_numel_bytes(ins.shape)
    ops = ins.operands
    if not ops:
        return 0.0
    lhs_shape = symbols.get(ops[0], "")
    mdims = _SHAPE_RE.search(lhs_shape)
    if not mdims:
        return 0.0
    dims = [int(d) for d in mdims.group(2).split(",")] if mdims.group(2) else []
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    k = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            k *= dims[int(d)] if int(d) < len(dims) else 1
    return 2.0 * out_numel * k


def _hbm_traffic(ins: Instruction, comp: Computation,
                 comps: dict, out_bytes: int) -> float:
    """HBM bytes for one top-level instruction.

    In-place slice updates (dynamic-update-slice, and fusions rooted in
    one — scan-carry saves, KV-cache writes) move only the slice, not the
    whole buffer; XLA aliases the big operand.  Dynamic-slice reads only
    the slice.  Everything else: operands + output."""
    op = ins.opcode
    if op == "dynamic-update-slice":
        upd = shape_numel_bytes(
            comp.symbols.get(ins.operands[1], ""))[1] if len(
                ins.operands) > 1 else out_bytes
        return 2.0 * upd
    if op == "dynamic-slice":
        return 2.0 * out_bytes
    if op == "fusion":
        mm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
        called = comps.get(mm.group(1)) if mm else None
        if called is not None and called.instructions:
            root = called.instructions[-1]
            if root.opcode == "dynamic-update-slice":
                # traffic = small operands of the fusion + 2× slice size
                big = max((shape_numel_bytes(
                    comp.symbols.get(o, ""))[1] for o in ins.operands),
                    default=0)
                upd = shape_numel_bytes(
                    called.symbols.get(root.operands[1], ""))[1] if len(
                        root.operands) > 1 else 0
                operand_bytes = sum(
                    shape_numel_bytes(comp.symbols.get(o, ""))[1]
                    for o in ins.operands)
                return (operand_bytes - big) + 2.0 * max(upd, 1)
    operand_bytes = sum(
        shape_numel_bytes(comp.symbols.get(o, ""))[1]
        for o in ins.operands)
    return operand_bytes + out_bytes


def analyze(hlo_text: str, pod_size: int | None = None) -> HloCost:
    """Analyze optimized (post-SPMD) HLO text into a per-device HloCost."""
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # ---- call multipliers + HBM-level flags ------------------------------
    mult: dict[str, float] = defaultdict(float)
    hbm_level: dict[str, bool] = defaultdict(bool)
    trip_counts: dict[str, int] = {}
    stack = [(entry.name, 1.0, True)]
    seen_edges = set()
    while stack:
        cname, m, hbm = stack.pop()
        mult[cname] += m
        hbm_level[cname] = hbm_level[cname] or hbm
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instructions:
            edge_key = (cname, ins.name)
            if edge_key in seen_edges:
                continue
            seen_edges.add(edge_key)
            if ins.opcode == "while":
                tc = _find_trip_count(ins, comps)
                trip_counts[ins.name] = tc
                for role in ("body", "condition"):
                    mm = re.search(role + r"=%?([\w.\-]+)", ins.rest)
                    if mm and mm.group(1) in comps:
                        stack.append((mm.group(1), m * tc, hbm))
            elif ins.opcode == "conditional":
                for mm in re.finditer(
                        r"(?:true_computation|false_computation|branch_computations=\{[^}]*)"
                        r"=?%?([\w.\-]+)", ins.rest):
                    if mm.group(1) in comps:
                        stack.append((mm.group(1), m, hbm))
            else:
                mm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if mm and mm.group(1) in comps:
                    # fusion internals: flops counted, HBM not
                    stack.append((mm.group(1), m, False))
                mm = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
                if mm and mm.group(1) in comps:
                    stack.append((mm.group(1), m, False))

    cost = HloCost(trip_counts=trip_counts)
    coll_agg: dict[tuple, CollectiveStat] = {}

    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        hbm = hbm_level.get(cname, False)
        for ins in comp.instructions:
            op = ins.opcode
            out_numel, out_bytes = shape_numel_bytes(ins.shape)
            # ---- flops
            if op == "dot":
                f = _dot_flops(ins, comp.symbols)
                cost.flops += m * f
                cost.dot_flops += m * f
            elif op == "convolution":
                # rare in our models; approximate via output * kernel numel
                kshape = comp.symbols.get(ins.operands[1], "") if len(
                    ins.operands) > 1 else ""
                kn, _ = shape_numel_bytes(kshape)
                cost.flops += m * 2.0 * out_numel * max(kn, 1) ** 0.5
            elif op in ("reduce", "reduce-window"):
                in_numel = shape_numel_bytes(
                    comp.symbols.get(ins.operands[0], ""))[0] if ins.operands \
                    else out_numel
                cost.flops += m * in_numel
            elif op == "fusion":
                pass  # internals counted in the called computation
            elif op not in _FREE_OPS and not op.startswith(
                    tuple(COLLECTIVE_OPS)):
                cost.flops += m * out_numel  # 1 flop/elem estimate
            # ---- HBM bytes (top level only, skip free + fusable ops)
            if hbm and op not in _FREE_OPS and op not in _FUSED_FREE_OPS:
                cost.hbm_bytes += m * _hbm_traffic(ins, comp, comps,
                                                   out_bytes)
            # ---- collectives (count the -start of async pairs, skip -done)
            base_op = op.replace("-start", "")
            if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
                operand_bytes = sum(
                    shape_numel_bytes(comp.symbols.get(o, ""))[1]
                    for o in ins.operands) or out_bytes
                g, pods = _replica_group_info(ins, pod_size)
                cross = pods > 1
                # hierarchical decomposition: groups spanning pods run as
                # RS-within-pod → cross-pod phase → AG-within-pod (what
                # multi-slice XLA actually emits); the cross-pod phase
                # per-chip bytes amortize over the pod-local members.
                members = max(1, g // pods)
                if cross:
                    ici = _ring_factor(base_op, members) * operand_bytes
                    dcn = (_ring_factor(base_op, pods) * operand_bytes
                           / members)
                    if base_op == "all-gather":
                        # shard s: AG-in-pod (m−1)·s; cross-pod each chip
                        # forwards its pod's slice share: (P−1)·s
                        ici = (members - 1) * operand_bytes
                        dcn = (pods - 1) * operand_bytes
                else:
                    ici = _ring_factor(base_op, g) * operand_bytes
                    dcn = 0.0
                wire = ici + dcn
                key = (base_op, g, cross)
                st = coll_agg.setdefault(
                    key, CollectiveStat(op=base_op, group_size=g,
                                        cross_pod=cross))
                st.wire_bytes += m * wire
                st.raw_bytes += m * operand_bytes
                st.count += m
                st.ici_wire += m * ici
                st.dcn_wire += m * dcn

    cost.collectives = list(coll_agg.values())
    return cost


# ------------------------------------------------- materialized collectives
def _materialize_groups(ins: Instruction) -> list[list[int]] | None:
    """Full replica-group membership for a collective instruction."""
    rest = ins.rest
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?",
                  rest)
    if m:
        n_groups, g_size = int(m.group(1)), int(m.group(2))
        shape = tuple(int(d) for d in m.group(3).split(","))
        ids = np.arange(int(np.prod(shape))).reshape(shape)
        if m.group(5):
            ids = ids.transpose(tuple(int(d) for d in m.group(5).split(",")))
        return ids.reshape(n_groups, g_size).tolist()
    mg = re.search(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}", rest)
    if mg:
        return [[int(x) for x in grp.split(",")]
                for grp in re.findall(r"\{([\d,]+)\}", mg.group(1))]
    if "source_target_pairs" in rest:
        pairs = re.findall(r"\{(\d+),(\d+)\}", rest)
        return [[int(a), int(b)] for a, b in pairs]
    return None


def collective_instances(hlo_text: str):
    """Yield (op, groups, operand_bytes, multiplier) for every collective in
    the module, with while-loop multipliers applied — the input to the
    VieM communication-graph extraction (core.comm_model)."""
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        return
    mult: dict[str, float] = defaultdict(float)
    stack = [(entry.name, 1.0)]
    seen = set()
    while stack:
        cname, m = stack.pop()
        mult[cname] += m
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instructions:
            key = (cname, ins.name)
            if key in seen:
                continue
            seen.add(key)
            if ins.opcode == "while":
                tc = _find_trip_count(ins, comps)
                for role in ("body", "condition"):
                    mm = re.search(role + r"=%?([\w.\-]+)", ins.rest)
                    if mm and mm.group(1) in comps:
                        stack.append((mm.group(1), m * tc))
            else:
                for attr in ("calls", "to_apply"):
                    mm = re.search(attr + r"=%?([\w.\-]+)", ins.rest)
                    if mm and mm.group(1) in comps:
                        stack.append((mm.group(1), m))

    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instructions:
            base_op = ins.opcode.replace("-start", "")
            if base_op in COLLECTIVE_OPS and not ins.opcode.endswith("-done"):
                groups = _materialize_groups(ins)
                if groups is None:
                    continue
                operand_bytes = sum(
                    shape_numel_bytes(comp.symbols.get(o, ""))[1]
                    for o in ins.operands) or shape_numel_bytes(ins.shape)[1]
                yield base_op, groups, operand_bytes, m
