"""Human and JSON renderings of a lint + audit run."""

from __future__ import annotations

import json

from .engine import LintResult

_RULE_TITLES = {
    "VIEM000": "syntax error",
    "VIEM001": "host-sync hazard in device module",
    "VIEM002": "retrace hazard (per-call jit over closures)",
    "VIEM003": "Python control flow on traced value",
    "VIEM004": "lock discipline",
}


def render_human(result: LintResult, audit: dict | None = None,
                 verbose: bool = False) -> str:
    lines: list[str] = []
    for f in result.active:
        lines.append(f"{f.path}:{f.line}:{f.col}: {f.rule} {f.message}")
        if f.snippet:
            lines.append(f"    {f.snippet}")
    if verbose and result.suppressed:
        lines.append("")
        lines.append("suppressed:")
        for f in result.suppressed:
            why = f.justification or "(no justification)"
            lines.append(f"  {f.path}:{f.line}: {f.rule} — {why}")
    lines.append("")
    by_rule: dict[str, int] = {}
    for f in result.findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    summary = ", ".join(f"{r}={n}" for r, n in sorted(by_rule.items())) \
        or "none"
    lines.append(
        f"viem lint: {result.files_checked} files, "
        f"{len(result.active)} active finding(s), "
        f"{len(result.suppressed)} suppressed ({summary})")
    if audit is not None:
        ok = sum(1 for e in audit["entries"] if e["status"] == "ok")
        skipped = sum(1 for e in audit["entries"]
                      if e["status"] == "skipped")
        failed = [e for e in audit["entries"] if e["status"] == "failed"]
        lines.append(
            f"jaxpr audit: {ok} lowered clean, {skipped} skipped "
            f"(incompatible combos), {len(failed)} failed")
        for e in failed:
            lines.append(f"  FAIL {e['construction']} x {e['topology']}: "
                         f"{'; '.join(e['problems'])}")
    return "\n".join(lines)


def render_json(result: LintResult, audit: dict | None = None) -> str:
    doc = {
        "files_checked": result.files_checked,
        "active": [f.to_dict() for f in result.active],
        "suppressed": [f.to_dict() for f in result.suppressed],
        "rules": _RULE_TITLES,
    }
    if audit is not None:
        doc["jaxpr_audit"] = audit
    return json.dumps(doc, indent=2, sort_keys=True)
