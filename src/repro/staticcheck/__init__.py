"""Invariant lint engine for the repro tree (`viem lint`).

Three load-bearing disciplines hold this codebase together — zero-retrace
warm paths, padding-inert fixed-shape execution, and lock-guarded threaded
serving/monitoring — and every one of them is invisible to a generic
linter.  This package encodes them as repo-specific checks:

- an AST rule engine (:mod:`repro.staticcheck.rules`) with four rules:
  VIEM001 host-sync hazards in device modules, VIEM002 retrace hazards
  (per-call ``jax.jit`` over Python-scalar closures), VIEM003 Python
  control flow on traced values, VIEM004 lock discipline on threaded
  classes;
- a jaxpr audit (:mod:`repro.staticcheck.jaxpr_audit`) that lowers every
  registered construction x topology through ``Mapper.lower`` and walks
  the engine jaxprs for forbidden callback primitives, host transfers and
  accumulator-dtype drift;
- a CLI (``python -m repro.staticcheck`` / ``viem lint``) emitting human
  and JSON reports, with ``# viem: noqa[VIEMxxx]`` inline suppressions
  and a checked-in baseline file.
"""

from .engine import LintConfig, lint_paths, load_baseline
from .rules import Finding, analyze_source
from .report import render_human, render_json

__all__ = [
    "Finding",
    "LintConfig",
    "analyze_source",
    "lint_paths",
    "load_baseline",
    "render_human",
    "render_json",
]
