"""``viem lint`` / ``python -m repro.staticcheck`` — run the invariant
lint engine (and optionally the jaxpr audit) over the tree.

Exit status: 0 when there are no active findings, no unjustified
suppressions (unless ``--no-require-justification``) and the audit (if
requested) is clean; 1 otherwise.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .engine import LintConfig, lint_paths, write_baseline
from .report import render_human, render_json
from .rules import RULE_IDS

DEFAULT_BASELINE = "staticcheck_baseline.txt"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="viem lint",
        description="repo-invariant static checks (VIEM001-004) plus the "
                    "lowered-jaxpr audit")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src)")
    ap.add_argument("--rules", default=",".join(RULE_IDS),
                    help="comma-separated rule ids to enable")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of accepted finding fingerprints")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current active "
                         "findings and exit 0")
    ap.add_argument("--no-require-justification", dest="require_just",
                    action="store_false", default=True,
                    help="allow bare `# viem: noqa[...]` suppressions "
                         "without a trailing justification")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write the machine-readable report here "
                         "('-' for stdout)")
    ap.add_argument("--jaxpr-audit", action="store_true",
                    help="lower every registered construction x topology "
                         "and audit the traced entry points (slow: "
                         "traces every engine)")
    ap.add_argument("--verbose", action="store_true",
                    help="list suppressed findings too")
    ap.add_argument("--root", default=".",
                    help="repo root for relative paths and the baseline")
    args = ap.parse_args(argv)

    config = LintConfig(
        paths=tuple(args.paths) or ("src",),
        rules=tuple(r.strip() for r in args.rules.split(",") if r.strip()),
        baseline=args.baseline,
        require_justification=args.require_just,
    )
    result = lint_paths(config, root=args.root)

    if args.update_baseline:
        n = write_baseline(result, Path(args.root) / args.baseline)
        print(f"viem lint: baseline rewritten with {n} fingerprint(s)")
        return 0

    audit = None
    if args.jaxpr_audit:
        from .jaxpr_audit import run_audit
        audit = run_audit()

    if args.json:
        doc = render_json(result, audit)
        if args.json == "-":
            print(doc)                # machine output owns stdout
            print(render_human(result, audit, verbose=args.verbose),
                  file=sys.stderr)
        else:
            Path(args.json).write_text(doc + "\n")

    if args.json != "-":
        print(render_human(result, audit, verbose=args.verbose))

    failed = bool(result.active)
    if config.require_justification and result.unjustified:
        for f in result.unjustified:
            print(f"{f.path}:{f.line}: {f.rule} suppressed without a "
                  "justification — add one after the bracket")
        failed = True
    if audit is not None and not audit["ok"]:
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
