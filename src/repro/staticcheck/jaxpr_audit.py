"""Jaxpr audit: lower every registered construction x topology through
``Mapper.lower`` and walk the traced engine entry points.

What it asserts, per lowered plan:

- **no host callbacks** — ``pure_callback``/``io_callback``/
  ``debug_callback`` (and the legacy host_callback forms) would smuggle
  a host round-trip into the sweep ``while_loop``;
- **no device transfers** — a ``device_put`` inside the jaxpr means a
  host constant crossed into the trace per call instead of at lower
  time;
- **accumulator dtype discipline** — every floating-point intermediate
  matches the plan's ``KernelConfig.acc_dtype``; a stray float64 aval
  means a Python float or np.float64 leaked into the trace and doubled
  the accumulator width.

Entry points audited per plan level: the raw sweep fn (``execute``), the
batch-vmapped form (``execute_batch``), the lane-shared vmapped form
(portfolio), and the Pallas objective kernel when the backend compiles
one.  Combos a construction cannot lower (e.g. hierarchy constructions
on a non-tree machine) are reported as skipped, not failed.
"""

from __future__ import annotations

import numpy as np

FORBIDDEN_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
}
TRANSFER_PRIMITIVES = {"device_put", "copy_device_to_host",
                       "copy_host_to_device"}

# one small instance per registered topology kind (16 PEs each)
SMALL_TOPOLOGIES: dict[str, dict] = {
    "tree": {"factors": [4, 4], "distances": [1.0, 10.0]},
    "fattree": {"arities": [4, 4]},
    "torus": {"dims": [4, 4]},
    "dragonfly": {"pes_per_router": 2, "routers_per_group": 2,
                  "n_groups": 4},
    "matrix": {"matrix": [[float(abs(i - j)) for j in range(16)]
                          for i in range(16)]},
}


def _iter_eqns(jaxpr):
    """Depth-first over eqns including every sub-jaxpr (while/cond/scan/
    pjit/pallas_call bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_eqns(sub)


def _sub_jaxprs(val):
    import jax
    core = jax.core
    if isinstance(val, core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _sub_jaxprs(item)


def check_jaxpr(closed_jaxpr, acc_dtype: str = "float32") -> list[str]:
    """Problems found walking one closed jaxpr (empty = clean)."""
    problems: list[str] = []
    seen_prims: set[str] = set()
    bad_dtypes: set[str] = set()
    for eqn in _iter_eqns(closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        seen_prims.add(name)
        if name in FORBIDDEN_PRIMITIVES:
            problems.append(f"forbidden host-callback primitive: {name}")
        if name in TRANSFER_PRIMITIVES:
            problems.append(f"device transfer inside trace: {name}")
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None and np.issubdtype(dt, np.floating) \
                    and str(dt) != acc_dtype:
                bad_dtypes.add(str(dt))
    for dt in sorted(bad_dtypes):
        problems.append(
            f"floating intermediate dtype {dt} != KernelConfig "
            f"acc_dtype {acc_dtype}")
    return sorted(set(problems))


def _ring_graph(n: int):
    from ..core.graph import from_edges
    u = np.arange(n, dtype=np.int64)
    v = (u + 1) % n
    w = np.ones(n, dtype=np.float64)
    return from_edges(n, u, v, w)


def _dummy_engine_args(eng, n: int, k: int = 8, e: int = 128,
                       p: int = 128):
    import jax.numpy as jnp
    return (
        jnp.zeros((n, k), jnp.int32),       # nbr
        jnp.zeros((n, k), jnp.float32),     # wgt
        jnp.zeros((e,), jnp.int32),         # eu
        jnp.zeros((e,), jnp.int32),         # ev
        jnp.zeros((e,), jnp.float32),       # ew
        jnp.zeros((p,), jnp.int32),         # us
        jnp.zeros((p,), jnp.int32),         # vs
        jnp.arange(n, dtype=jnp.int32),     # perm0
        eng._D,                             # packed/topology distances
        jnp.float32(1e-4),                  # eps
        jnp.int32(0),                       # tenure
        jnp.bool_(False),                   # dlb
        jnp.bool_(False),                   # collect telemetry
    )


def audit_plan(plan) -> list[str]:
    """Audit every traced entry point of one lowered plan."""
    import jax
    import jax.numpy as jnp
    problems: list[str] = []
    for lvl, (eng, cfg) in enumerate(
            zip(plan.engines or [], plan.kernel_configs)):
        n = eng.topology.n_pe
        args = _dummy_engine_args(eng, n)
        acc = cfg.acc_dtype
        jaxpr = jax.make_jaxpr(eng._refine_fn)(*args)
        for p in check_jaxpr(jaxpr, acc):
            problems.append(f"level {lvl} refine: {p}")
        if lvl == 0:
            # the serving/batch and portfolio lane entry points share the
            # fn; audit their vmapped jaxprs once at the finest level
            b = 2
            batched = tuple(
                jnp.broadcast_to(a, (b,) + a.shape)
                if i not in (8, 10, 11, 12) else a
                for i, a in enumerate(args))
            vfn = jax.vmap(eng._refine_fn,
                           in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None, 0,
                                    None, None, None))
            vargs = list(batched)
            vargs[9] = jnp.zeros((b,), jnp.float32)     # per-lane eps
            for p in check_jaxpr(jax.make_jaxpr(vfn)(*vargs), acc):
                problems.append(f"batch vmap: {p}")
            lfn = jax.vmap(eng._refine_fn,
                           in_axes=(None, None, None, None, None, None,
                                    None, 0, None, 0, None, None, None))
            largs = list(args)
            largs[7] = jnp.broadcast_to(args[7], (b, n))
            largs[9] = jnp.zeros((b,), jnp.float32)
            for p in check_jaxpr(jax.make_jaxpr(lfn)(*largs), acc):
                problems.append(f"lane vmap: {p}")
    if getattr(plan, "_objective_fn", None) is not None:
        e = 128
        pu = jnp.zeros((e,), jnp.int32)
        pv = jnp.zeros((e,), jnp.int32)
        w = jnp.zeros((e,), jnp.float32)
        acc = plan.kernel_configs[0].acc_dtype
        for p in check_jaxpr(jax.make_jaxpr(plan._objective_fn)(pu, pv, w),
                             acc):
            problems.append(f"objective kernel: {p}")
    return problems


def run_audit(constructions: list[str] | None = None,
              topologies: list[str] | None = None) -> dict:
    """Lower and audit every construction x topology combo; returns a
    JSON-friendly report dict."""
    from ..core import Mapper, MappingSpec, list_constructions
    from ..topology import list_topologies, make_topology

    constructions = constructions or list_constructions()
    topologies = topologies or list_topologies()
    entries: list[dict] = []
    for topo_kind in topologies:
        params = SMALL_TOPOLOGIES.get(topo_kind)
        if params is None:
            entries.append({"construction": "*", "topology": topo_kind,
                            "status": "skipped",
                            "problems": ["no small instance registered "
                                         "for this topology kind"]})
            continue
        topo = make_topology(topo_kind, **params)
        g = _ring_graph(topo.n_pe)
        for cons in constructions:
            spec = MappingSpec(construction=cons, engine="device",
                               backend="pallas").validate()
            entry = {"construction": cons, "topology": topo_kind,
                     "status": "ok", "problems": []}
            try:
                plan = Mapper(topo, spec).lower_for(g)
            except (ValueError, TypeError, NotImplementedError) as exc:
                entry["status"] = "skipped"
                entry["problems"] = [f"lower: {exc}"]
                entries.append(entry)
                continue
            problems = audit_plan(plan)
            if problems:
                entry["status"] = "failed"
                entry["problems"] = problems
            entries.append(entry)
    failed = [e for e in entries if e["status"] == "failed"]
    return {"entries": entries, "ok": not failed}
