"""File walking, inline suppressions, and the checked-in baseline.

Suppression syntax, on the flagged line::

    x = float(j_best)  # viem: noqa[VIEM001] host boundary: final readback

Everything after the closing bracket is the justification; ``viem lint``
refuses a bare suppression in ``--require-justification`` mode (the CI
default) so every exemption carries its one-line why.

The baseline file (``staticcheck_baseline.txt``) holds one finding
fingerprint per line; findings present in it are reported as suppressed
("baselined") without touching the source.  An empty baseline is the
goal state and what this repo checks in.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from pathlib import Path

from .rules import RULE_IDS, Finding, analyze_source

_NOQA_RE = re.compile(
    r"#\s*viem:\s*noqa\[([A-Z0-9,\s]+)\]\s*(.*)$")

DEFAULT_EXCLUDE = ("experiments", "__pycache__", ".git")


@dataclass
class LintConfig:
    paths: tuple[str, ...] = ("src",)
    rules: tuple[str, ...] = RULE_IDS
    baseline: str | None = None
    require_justification: bool = True
    exclude: tuple[str, ...] = DEFAULT_EXCLUDE


@dataclass
class LintResult:
    findings: list[Finding] = field(default_factory=list)
    files_checked: int = 0

    @property
    def active(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> list[Finding]:
        return [f for f in self.findings if f.suppressed]

    @property
    def unjustified(self) -> list[Finding]:
        return [f for f in self.suppressed
                if not f.justification.strip()]


def parse_suppressions(source: str) -> dict[int, tuple[set[str], str]]:
    """line number -> (rule ids, justification text)."""
    out: dict[int, tuple[set[str], str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _NOQA_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = (rules, m.group(2).strip())
    return out


def load_baseline(path: str | Path) -> set[str]:
    p = Path(path)
    if not p.exists():
        return set()
    return {line.strip() for line in p.read_text().splitlines()
            if line.strip() and not line.startswith("#")}


def lint_source(source: str, relpath: str,
                rules: tuple[str, ...] = RULE_IDS,
                baseline: set[str] | None = None) -> list[Finding]:
    findings = analyze_source(source, relpath, rules)
    noqa = parse_suppressions(source)
    baseline = baseline or set()
    for f in findings:
        entry = noqa.get(f.line)
        if entry is not None and f.rule in entry[0]:
            f.suppressed = True
            f.justification = entry[1]
        elif f.fingerprint() in baseline:
            f.suppressed = True
            f.justification = "baselined"
    return findings


def iter_python_files(paths: tuple[str, ...], root: Path,
                      exclude: tuple[str, ...]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        if base.is_file() and base.suffix == ".py":
            files.append(base)
            continue
        for f in sorted(base.rglob("*.py")):
            if any(part in exclude for part in f.parts):
                continue
            files.append(f)
    return files


def lint_paths(config: LintConfig, root: str | Path = ".") -> LintResult:
    root = Path(root)
    baseline = load_baseline(root / config.baseline) \
        if config.baseline else set()
    result = LintResult()
    for f in iter_python_files(config.paths, root, config.exclude):
        try:
            source = f.read_text()
        except (OSError, UnicodeDecodeError):
            continue
        rel = f.relative_to(root).as_posix() if f.is_relative_to(root) \
            else f.as_posix()
        result.findings.extend(
            lint_source(source, rel, config.rules, baseline))
        result.files_checked += 1
    return result


def write_baseline(result: LintResult, path: str | Path) -> int:
    """Snapshot every active finding's fingerprint; returns the count."""
    fps = sorted({f.fingerprint() for f in result.active})
    text = ("# viem lint baseline — one fingerprint per accepted "
            "finding.\n# Regenerate: python -m repro.staticcheck "
            "--update-baseline\n" + "\n".join(fps))
    Path(path).write_text(text + "\n")
    return len(fps)
