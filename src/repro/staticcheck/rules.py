"""AST rules encoding the repo's execution-discipline invariants.

Every rule works on a plain ``ast`` parse of one module — no imports are
executed — plus a small amount of repo knowledge (which packages are
device-resident, which modules are threaded).  The analyses are
deliberately conservative: a rule only fires where the hazard is
structural (a ``np.*`` call inside a function that is demonstrably
traced, an attribute written under ``self._lock`` in one method and
read bare in another), so a finding is actionable rather than noise.

Rules
-----
VIEM001   host-sync hazard in a device module: ``.item()``, ``float()``/
          ``int()``/``bool()`` on device values, ``np.*`` on device
          values, host timing (``time.perf_counter``) — each one a
          silent device->host sync on the hot path.
VIEM002   retrace hazard: ``jax.jit``/``jax.vmap`` called inside a
          per-call function over a callable that closes over that
          function's locals.  Every call traces afresh; the codebase
          convention is a builder that jits once, with runtime knobs
          passed as ``jnp.int32``/``jnp.bool_`` operands (see the
          tabu/telemetry toggles in ``engine/sweep.py``).
VIEM003   Python ``if``/``while`` on a traced expression: inside a
          traced function the parameters ARE tracers, so branching on
          them (or anything computed from them, or any ``jnp``/``lax``
          result in a device module) either fails under jit or forces a
          concretization sync.
VIEM004   lock discipline: an attribute of a threaded class written
          under ``with self._lock`` in one method and accessed bare in
          another is a data race waiting for a free-threaded build.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

# packages whose modules run on (or trace onto) the accelerator
DEVICE_PACKAGES = ("engine", "kernels", "multilevel", "portfolio")

# modules whose classes serve concurrent threads; VIEM004 scope
LOCK_MODULES = (
    "launch/serve.py",
    "obs/metrics.py",
    "obs/trace.py",
    "monitor/",
    "runtime/fault_tolerance.py",
    "core/mapping.py",
)

# dotted call prefixes whose results live on device
_DEVICE_PREFIXES = (
    "jax.numpy.",
    "jax.lax.",
    "jax.random.",
    "jax.nn.",
    "jax.scipy.",
)

# dotted name -> positional argument indices holding traced callables
_TRACING_WRAPPERS: dict[str, tuple[int, ...] | str] = {
    "jax.jit": (0,),
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": "rest",          # every arg from 1 on is a branch
    "jax.experimental.pallas.pallas_call": (0,),
}

# function-name fragments that mark a scope as a build-once site: jitting
# there is the convention, not a hazard (VIEM002 exemption)
_BUILDER_FRAGMENTS = ("build", "make", "lower", "factory", "compile")
_BUILDER_EXACT = {"__init__", "__post_init__", "__call__"}

_HOST_TIMING = {
    "time.perf_counter", "time.perf_counter_ns", "time.time",
    "time.monotonic", "time.process_time",
}

_LOCK_FACTORIES = {"threading.Lock", "threading.RLock", "threading.Condition"}


@dataclass
class Finding:
    rule: str
    path: str            # repo-relative, forward slashes
    line: int
    col: int
    message: str
    snippet: str = ""
    suppressed: bool = False
    justification: str = ""

    def fingerprint(self) -> str:
        # line numbers churn; the (rule, path, snippet) triple is stable
        # across unrelated edits, which is what a baseline needs
        return f"{self.rule}:{self.path}:{self.snippet.strip()}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "snippet": self.snippet,
            "suppressed": self.suppressed,
            "justification": self.justification,
        }


def _dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Resolve an attribute chain to a dotted name, expanding import
    aliases at the root (``jnp.where`` -> ``jax.numpy.where``)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def _collect_aliases(tree: ast.Module) -> dict[str, str]:
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


class _ModuleIndex:
    """Parent links, per-scope function tables, and the traced-scope
    fixpoint shared by VIEM001/002/003."""

    def __init__(self, tree: ast.Module, aliases: dict[str, str]):
        self.tree = tree
        self.aliases = aliases
        self.parent: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
        # scope -> {name: FunctionDef} for defs immediately inside it
        self.defs_in_scope: dict[ast.AST, dict[str, ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = self.enclosing_scope(node)
                self.defs_in_scope.setdefault(scope, {})[node.name] = node
        self.traced: set[ast.AST] = set()
        # fn node -> param names known static (static_argnames/argnums,
        # functools.partial keyword bindings)
        self.static_params: dict[ast.AST, set[str]] = {}
        self._mark_traced()

    def enclosing_scope(self, node: ast.AST) -> ast.AST:
        """Nearest enclosing function/lambda, else the module."""
        cur = self.parent.get(node)
        while cur is not None and not isinstance(cur, _FUNC_NODES):
            cur = self.parent.get(cur)
        return cur if cur is not None else self.tree

    def enclosing_function(self, node: ast.AST):
        scope = self.enclosing_scope(node)
        return None if isinstance(scope, ast.Module) else scope

    def lookup_def(self, name: str, from_node: ast.AST):
        """Resolve a bare name to a FunctionDef visible from a node."""
        scope = self.enclosing_scope(from_node)
        while True:
            found = self.defs_in_scope.get(scope, {}).get(name)
            if found is not None:
                return found
            if isinstance(scope, ast.Module):
                return None
            scope = self.enclosing_scope(scope)

    def _callable_args(self, call: ast.Call) -> list[ast.AST]:
        name = _dotted(call.func, self.aliases)
        spec = None
        if name is not None:
            spec = _TRACING_WRAPPERS.get(name)
            if spec is None and name.endswith(".pallas_call"):
                spec = (0,)
        if spec is None:
            return []
        if spec == "rest":
            return list(call.args[1:])
        return [call.args[i] for i in spec if i < len(call.args)]

    def _as_traced_target(self, node: ast.AST):
        if isinstance(node, ast.Lambda):
            return node
        if isinstance(node, ast.Name):
            return self.lookup_def(node.id, node)
        if isinstance(node, ast.Call):
            # functools.partial(fn, ...): keyword bindings are
            # trace-time constants, not runtime operands
            fname = _dotted(node.func, self.aliases)
            if fname in ("functools.partial", "partial") and node.args:
                tgt = self._as_traced_target(node.args[0])
                if tgt is not None:
                    self.static_params.setdefault(tgt, set()).update(
                        kw.arg for kw in node.keywords if kw.arg)
                return tgt
        return None

    @staticmethod
    def _static_arg_names(call: ast.Call, fn: ast.AST) -> set[str]:
        """Param names pinned static by a jit call's static_argnames/
        static_argnums keywords."""
        names: set[str] = set()
        params = []
        if isinstance(fn, _FUNC_NODES):
            a = fn.args
            params = [p.arg for p in a.posonlyargs + a.args]
        for kw in call.keywords:
            val = kw.value
            items = val.elts if isinstance(val, (ast.Tuple, ast.List)) \
                else [val]
            if kw.arg == "static_argnames":
                names |= {i.value for i in items
                          if isinstance(i, ast.Constant)
                          and isinstance(i.value, str)}
            elif kw.arg == "static_argnums":
                for i in items:
                    if isinstance(i, ast.Constant) \
                            and isinstance(i.value, int) \
                            and i.value < len(params):
                        names.add(params[i.value])
        return names

    def _mark_traced(self):
        roots: set[ast.AST] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                for arg in self._callable_args(node):
                    tgt = self._as_traced_target(arg)
                    if tgt is not None:
                        roots.add(tgt)
                        self.static_params.setdefault(tgt, set()).update(
                            self._static_arg_names(node, tgt))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    dname = _dotted(dec, self.aliases)
                    if dname in ("jax.jit", "jit"):
                        roots.add(node)
                    elif isinstance(dec, ast.Call):
                        cname = _dotted(dec.func, self.aliases)
                        if cname in ("jax.jit", "jit"):
                            roots.add(node)
                        elif cname in ("functools.partial", "partial") \
                                and dec.args:
                            inner = _dotted(dec.args[0], self.aliases)
                            if inner in ("jax.jit", "jit"):
                                roots.add(node)
        traced = set(roots)
        # fixpoint: defs nested in traced scopes are traced; defs called
        # by bare name from a traced body are traced
        changed = True
        while changed:
            changed = False
            for fn in list(traced):
                for node in ast.walk(fn):
                    if node is fn:
                        continue
                    if isinstance(node, _FUNC_NODES) \
                            and node not in traced:
                        traced.add(node)
                        changed = True
                    if isinstance(node, ast.Call) \
                            and isinstance(node.func, ast.Name):
                        tgt = self.lookup_def(node.func.id, node)
                        if tgt is not None and tgt not in traced:
                            traced.add(tgt)
                            changed = True
        self.traced = traced

    def in_traced_scope(self, node: ast.AST) -> bool:
        cur: ast.AST | None = node
        while cur is not None:
            if cur in self.traced:
                return True
            cur = self.parent.get(cur)
        return False


def _is_device_call(node: ast.AST, aliases: dict[str, str]) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = _dotted(node.func, aliases)
    return name is not None and name.startswith(_DEVICE_PREFIXES)


# attribute reads that yield static Python values even on tracers
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding",
                 "weak_type", "aval"}


def _walk_value(node: ast.AST):
    """ast.walk, but stop at attribute reads that are static under trace
    (``x.shape`` of a tracer is a Python tuple, not a tracer)."""
    stack = [node]
    while stack:
        cur = stack.pop()
        if isinstance(cur, ast.Attribute) and cur.attr in _STATIC_ATTRS:
            continue
        yield cur
        stack.extend(ast.iter_child_nodes(cur))


def _taint_function(fn: ast.AST, aliases: dict[str, str],
                    seed: set[str] | None = None) -> set[str]:
    """Names in ``fn``'s body bound (directly or transitively) to
    device-array-producing expressions.  Single-pass-to-fixpoint over
    assignments; precise enough because device code is straight-line."""
    tainted: set[str] = set(seed or ())

    def expr_tainted(node: ast.AST) -> bool:
        return any(
            (isinstance(sub, ast.Name) and sub.id in tainted)
            or _is_device_call(sub, aliases)
            for sub in _walk_value(node))

    def bind(target: ast.AST):
        # `x = ...` and `x, y = ...` taint x/y; `obj.attr = ...` and
        # `obj[i] = ...` do NOT taint obj — attribute granularity is
        # coarser than name granularity and drowns __init__ in noise
        if isinstance(target, ast.Name):
            tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                bind(elt)
        elif isinstance(target, ast.Starred):
            bind(target.value)

    body = fn.body if isinstance(fn.body, list) else [fn.body]
    changed = True
    while changed:
        changed = False
        before = len(tainted)
        for node in ast.walk(ast.Module(body=body, type_ignores=[])):
            if isinstance(node, _FUNC_NODES):
                continue
            if isinstance(node, ast.Assign) and expr_tainted(node.value):
                for t in node.targets:
                    bind(t)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)) \
                    and node.value is not None \
                    and expr_tainted(node.value):
                bind(node.target)
            elif isinstance(node, ast.For) and expr_tainted(node.iter):
                bind(node.target)
        changed = len(tainted) > before
    return tainted


def _first_line(source_lines: list[str], node: ast.AST) -> str:
    try:
        return source_lines[node.lineno - 1].strip()
    except (IndexError, AttributeError):
        return ""


def _in_device_package(relpath: str) -> bool:
    return any(f"/{pkg}/" in f"/{relpath}" or relpath.startswith(f"{pkg}/")
               for pkg in (f"repro/{p}" for p in DEVICE_PACKAGES))


def _in_lock_module(relpath: str) -> bool:
    return any(relpath.endswith(m) or (m.endswith("/") and f"/{m}" in
               f"/{relpath}") for m in LOCK_MODULES)


# ---------------------------------------------------------------- VIEM001


def _traced_seed(idx: _ModuleIndex, fn: ast.AST) -> set[str]:
    """Parameters of a traced function that arrive as tracers:
    positional params minus static_argnames/argnums and partial-bound
    keywords; keyword-only params are static config by convention."""
    args = fn.args
    seed = {a.arg for a in args.posonlyargs + args.args}
    return seed - idx.static_params.get(fn, set())


def _boundary_nodes(idx: _ModuleIndex) -> set[ast.AST]:
    """Nodes lexically inside a ``with host_boundary(...)`` block — the
    documented-transfer marker VIEM001 honors."""
    guarded: set[ast.AST] = set()
    for node in ast.walk(idx.tree):
        if not isinstance(node, ast.With):
            continue
        for item in node.items:
            expr = item.context_expr
            if isinstance(expr, ast.Call):
                name = _dotted(expr.func, idx.aliases)
                if name is not None and (
                        name == "host_boundary"
                        or name.endswith(".host_boundary")):
                    guarded.update(ast.walk(node))
                    break
    return guarded


def _check_host_sync(idx: _ModuleIndex, relpath: str,
                     lines: list[str]) -> list[Finding]:
    if not _in_device_package(relpath):
        return []
    out = []
    aliases = idx.aliases
    boundary = _boundary_nodes(idx)
    # per-function taint cache
    taint_cache: dict[ast.AST, set[str]] = {}

    def taint_for(node: ast.AST) -> set[str]:
        fn = idx.enclosing_function(node)
        if fn is None:
            return set()
        if fn not in taint_cache:
            seed = _traced_seed(idx, fn) if fn in idx.traced else set()
            taint_cache[fn] = _taint_function(fn, aliases, seed)
        return taint_cache[fn]

    def arg_tainted(call: ast.Call) -> bool:
        names = taint_for(call)
        return any(
            (isinstance(sub, ast.Name) and sub.id in names)
            or _is_device_call(sub, aliases)
            for a in call.args for sub in _walk_value(a))

    for node in ast.walk(idx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func, aliases)
        traced = idx.in_traced_scope(node)
        if not traced and node in boundary \
                and name not in _HOST_TIMING:
            continue            # documented, transfer-guard-scoped site
        if name in _HOST_TIMING:
            out.append(Finding(
                "VIEM001", relpath, node.lineno, node.col_offset,
                f"host timing ({name}) in a device module — wall-clock "
                "belongs to tracer spans at the session layer",
                _first_line(lines, node)))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            out.append(Finding(
                "VIEM001", relpath, node.lineno, node.col_offset,
                ".item() forces a device->host sync" +
                (" inside a traced function" if traced else
                 " on the hot path"),
                _first_line(lines, node)))
        elif name in ("float", "int", "bool") and len(node.args) == 1 \
                and not isinstance(node.args[0], ast.Constant):
            # in traced scopes the taint seed is the parameter list, so
            # arg_tainted() covers both hazards
            if arg_tainted(node):
                out.append(Finding(
                    "VIEM001", relpath, node.lineno, node.col_offset,
                    f"{name}() on a device value blocks on the transfer "
                    "stream — keep it a jnp scalar or read back at a "
                    "documented host boundary",
                    _first_line(lines, node)))
        elif name is not None and name.startswith("numpy."):
            if traced:
                out.append(Finding(
                    "VIEM001", relpath, node.lineno, node.col_offset,
                    f"host numpy ({name}) inside a traced function — "
                    "the tracer will constant-fold or sync; use jnp",
                    _first_line(lines, node)))
            elif arg_tainted(node):
                out.append(Finding(
                    "VIEM001", relpath, node.lineno, node.col_offset,
                    f"{name} on a device value is an implicit "
                    "device->host transfer — wrap the documented "
                    "boundary in host_boundary() or keep it on device",
                    _first_line(lines, node)))
    return out


# ---------------------------------------------------------------- VIEM002


def _free_locals_of_callable(target: ast.AST, enclosing: ast.AST,
                             idx: _ModuleIndex) -> set[str]:
    """Names the callable reads that are bound in ``enclosing``'s scope
    (params or locals) — the closure that forces a retrace per call."""
    if isinstance(enclosing, ast.Module):
        return set()
    args = enclosing.args
    bound = {a.arg for a in
             args.posonlyargs + args.args + args.kwonlyargs}
    if args.vararg:
        bound.add(args.vararg.arg)
    if args.kwarg:
        bound.add(args.kwarg.arg)
    body = enclosing.body if isinstance(enclosing.body, list) \
        else [enclosing.body]
    for node in ast.walk(ast.Module(body=body, type_ignores=[])):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        bound.add(sub.id)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.For)):
            tgt = getattr(node, "target", None)
            if tgt is not None:
                for sub in ast.walk(tgt):
                    if isinstance(sub, ast.Name):
                        bound.add(sub.id)
    if isinstance(target, ast.Lambda):
        own = {a.arg for a in target.args.posonlyargs + target.args.args
               + target.args.kwonlyargs}
        reads = {n.id for n in ast.walk(target.body)
                 if isinstance(n, ast.Name)
                 and isinstance(n.ctx, ast.Load)}
    elif isinstance(target, (ast.FunctionDef, ast.AsyncFunctionDef)):
        own = {a.arg for a in target.args.posonlyargs + target.args.args
               + target.args.kwonlyargs}
        reads = set()
        for stmt in target.body:
            reads |= {n.id for n in ast.walk(stmt)
                      if isinstance(n, ast.Name)
                      and isinstance(n.ctx, ast.Load)}
    else:
        return set()
    return (reads - own) & bound


def _check_retrace(idx: _ModuleIndex, relpath: str,
                   lines: list[str]) -> list[Finding]:
    out = []
    for node in ast.walk(idx.tree):
        if not isinstance(node, ast.Call):
            continue
        # vmap alone is conventional eager style; only jit pays a full
        # trace+compile per call
        name = _dotted(node.func, idx.aliases)
        if name not in ("jax.jit", "jit"):
            continue
        enclosing = idx.enclosing_function(node)
        if enclosing is None or isinstance(enclosing, ast.Lambda):
            continue
        fname = enclosing.name
        if fname in _BUILDER_EXACT or fname.startswith("_lower") \
                or any(f in fname for f in _BUILDER_FRAGMENTS):
            continue
        if not node.args:
            continue
        target = node.args[0]
        if isinstance(target, ast.Name):
            target = idx.lookup_def(target.id, node) or target
        free = _free_locals_of_callable(target, enclosing, idx)
        if free:
            out.append(Finding(
                "VIEM002", relpath, node.lineno, node.col_offset,
                f"{name}() inside {fname}() closes over per-call locals "
                f"({', '.join(sorted(free))}) — every call retraces; "
                "hoist to a cached builder or pass them as "
                "jnp.int32/jnp.bool_ runtime operands (the "
                "tabu/telemetry toggle convention)",
                _first_line(lines, node)))
    return out


# ---------------------------------------------------------------- VIEM003


def _check_traced_control_flow(idx: _ModuleIndex, relpath: str,
                               lines: list[str]) -> list[Finding]:
    out = []
    device_mod = _in_device_package(relpath)
    for fn in ast.walk(idx.tree):
        if not isinstance(fn, _FUNC_NODES) or isinstance(fn, ast.Lambda):
            continue
        traced = fn in idx.traced
        if not traced and not device_mod:
            continue
        seed = _traced_seed(idx, fn) if traced else set()
        tainted = _taint_function(fn, idx.aliases, seed)
        if not tainted:
            continue
        for node in ast.walk(ast.Module(
                body=list(fn.body) if isinstance(fn.body, list)
                else [fn.body], type_ignores=[])):
            if isinstance(node, _FUNC_NODES):
                continue
            if not isinstance(node, (ast.If, ast.While)):
                continue
            test = node.test
            # `x is None` / `x is not None` is a trace-time shape
            # dispatch, not a value branch — the idiomatic static gate;
            # so is comparison against a string constant (tracers are
            # never strings)
            if isinstance(test, ast.Compare):
                if len(test.ops) == 1 \
                        and isinstance(test.ops[0], (ast.Is, ast.IsNot)):
                    continue
                operands = [test.left, *test.comparators]
                if any(isinstance(o, ast.Constant)
                       and isinstance(o.value, str) for o in operands):
                    continue
            hit = None
            for sub in _walk_value(test):
                if isinstance(sub, ast.Name) and sub.id in tainted:
                    hit = sub.id
                    break
                if _is_device_call(sub, idx.aliases):
                    hit = _dotted(sub.func, idx.aliases)
                    break
            if hit is not None:
                kind = "while" if isinstance(node, ast.While) else "if"
                where = "a traced function" if traced \
                    else "a device module"
                out.append(Finding(
                    "VIEM003", relpath, node.lineno, node.col_offset,
                    f"Python `{kind}` on traced value `{hit}` in "
                    f"{where} — concretizes the tracer (or syncs); use "
                    "lax.cond/jnp.where or hoist to a static argument",
                    _first_line(lines, node)))
    return out


# ---------------------------------------------------------------- VIEM004


@dataclass
class _AttrAccess:
    node: ast.Attribute
    method: str
    guarded: bool
    is_store: bool


def _check_lock_discipline(idx: _ModuleIndex, relpath: str,
                           lines: list[str]) -> list[Finding]:
    if not _in_lock_module(relpath):
        return []
    out = []
    for cls in ast.walk(idx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        lock_attrs: set[str] = set()
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    vname = _dotted(node.value.func, idx.aliases) \
                        if isinstance(node.value, ast.Call) else None
                    if vname in _LOCK_FACTORIES or \
                            ("lock" in t.attr.lower()
                             and not isinstance(node.value,
                                                ast.Constant)):
                        lock_attrs.add(t.attr)
        if not lock_attrs:
            continue

        # every `self.X` access in every method, tagged by whether an
        # enclosing `with self.<lock>` guards it
        accesses: dict[str, list[_AttrAccess]] = {}
        data_attrs: set[str] = set()

        def _is_lock_ctx(expr: ast.AST) -> bool:
            return (isinstance(expr, ast.Attribute)
                    and isinstance(expr.value, ast.Name)
                    and expr.value.id == "self"
                    and expr.attr in lock_attrs)

        for method in cls.body:
            if not isinstance(method, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                continue
            guarded_nodes: set[ast.AST] = set()
            for node in ast.walk(method):
                if isinstance(node, ast.With) and any(
                        _is_lock_ctx(item.context_expr)
                        for item in node.items):
                    for sub in ast.walk(node):
                        guarded_nodes.add(sub)
            for node in ast.walk(method):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id == "self" \
                        and node.attr not in lock_attrs:
                    is_store = isinstance(node.ctx,
                                          (ast.Store, ast.Del))
                    parent = idx.parent.get(node)
                    if isinstance(parent, ast.Call) \
                            and parent.func is node:
                        continue        # method call, not a data access
                    if is_store:
                        data_attrs.add(node.attr)
                    accesses.setdefault(node.attr, []).append(
                        _AttrAccess(node, method.name,
                                    node in guarded_nodes, is_store))

        for attr, accs in accesses.items():
            if attr not in data_attrs:
                continue                # never assigned in this class
            outside_init = [a for a in accs
                            if a.method not in ("__init__",)
                            and not a.method.endswith("_locked")]
            # lock-managed = touched under the lock AND rebound after
            # __init__; attributes only ever *called* through (Queue,
            # deque) synchronize themselves and stay exempt
            if not any(a.guarded for a in outside_init) \
                    or not any(a.is_store for a in outside_init):
                continue
            for a in outside_init:
                if not a.guarded:
                    what = "write" if a.is_store else "read"
                    out.append(Finding(
                        "VIEM004", relpath, a.node.lineno,
                        a.node.col_offset,
                        f"self.{attr} is lock-managed elsewhere in "
                        f"{cls.name} but this {what} in {a.method}() "
                        "runs outside the lock — take the lock (RLock "
                        "re-enters) or rename the method *_locked",
                        _first_line(lines, a.node)))
    return out


# ----------------------------------------------------------------- driver


RULE_IDS = ("VIEM001", "VIEM002", "VIEM003", "VIEM004")

_CHECKS = (
    _check_host_sync,
    _check_retrace,
    _check_traced_control_flow,
    _check_lock_discipline,
)


def analyze_source(source: str, relpath: str,
                   rules: tuple[str, ...] = RULE_IDS) -> list[Finding]:
    """Run every enabled rule over one module's source text."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding("VIEM000", relpath, exc.lineno or 1, 0,
                        f"syntax error: {exc.msg}")]
    aliases = _collect_aliases(tree)
    idx = _ModuleIndex(tree, aliases)
    lines = source.splitlines()
    findings: list[Finding] = []
    for check, rule in zip(_CHECKS, RULE_IDS):
        if rule in rules:
            findings.extend(check(idx, relpath, lines))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings
