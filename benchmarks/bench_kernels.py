"""Kernel-path benchmarks: Pallas (interpret) correctness-scale runs +
the jnp reference timings that stand in for device timings on CPU."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Hierarchy, grid3d
from repro.core.objective import dense_gain_matrix
from repro.kernels import ops


def run(report):
    rng = np.random.default_rng(0)
    n = 256
    C = rng.random((n, n)) * (rng.random((n, n)) < 0.1)
    C = np.triu(C, 1) + np.triu(C, 1).T
    D = np.triu(rng.random((n, n)), 1)
    D = D + D.T
    perm = rng.permutation(n)

    t0 = time.perf_counter()
    G_np = dense_gain_matrix(C, D, perm)
    t_np = time.perf_counter() - t0
    report("swap_gain/numpy_n256", t_np * 1e6, "host spec")

    gm = jax.jit(lambda c, d, p: ops.gain_matrix_ref(c, d, p))
    out = gm(C, D, perm)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    jax.block_until_ready(gm(C, D, perm))
    t_ref = time.perf_counter() - t0
    err = float(np.max(np.abs(np.asarray(out) - G_np)))
    report("swap_gain/jnp_ref_n256", t_ref * 1e6, f"err={err:.1e}")

    t0 = time.perf_counter()
    G_k = ops.gain_matrix(C, D, perm, tile=128, interpret=True)
    jax.block_until_ready(G_k)
    t_k = time.perf_counter() - t0
    err = float(np.max(np.abs(np.asarray(G_k) - G_np)))
    report("swap_gain/pallas_interpret_n256", t_k * 1e6,
           f"err={err:.1e};interpret-mode(no TPU)")

    g = grid3d(8, 8, 8)
    h = Hierarchy((16, 8, 4), (1.0, 10.0, 100.0))
    perm = rng.permutation(512)
    t0 = time.perf_counter()
    j = ops.objective(g, h, perm, interpret=True)
    t_o = time.perf_counter() - t0
    report("qap_objective/pallas_interpret_512", t_o * 1e6, f"J={j:.0f}")


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}"))
