"""Kernel microbench: every hot-kernel form × path × precision × size.

Times the kernel layer the refinement engine actually runs — the sparse
pair-gain reduction and the edge-list objective — across the three
distance forms (tree / torus / matrix), both implementations (fused jnp
vs the Pallas kernel), and, for matrix-form tables, float32 vs the
lossless int8/int16 packing (``KernelConfig.dist_dtype``).  Emits
``BENCH_kernels.json`` (via :func:`benchmarks._common.write_bench`, so
the payload carries the backend/interpret/git provenance stamp):

  * ``timings``     — per (form, path, precision, n) microseconds/call;
    on a CPU host the Pallas rows run interpret=True (the meta block
    records ``pallas_interpret``), so device-vs-interpret speedups come
    from comparing two archived files with different ``meta.backend`` —
    the GPU CI lane (.github/workflows/gpu.yml) produces the device one.
  * ``tiling``      — derived-config vs explicitly multi-tile wall time
    for the fori_loop paths (acceptance: tiled ≥ fused on CPU because
    the derived CPU config is single-tile → the identical fused graph).
  * ``bytes_moved`` — gather-path byte accounting for float vs quantized
    tables (table residency + per-edge / per-pair-slot gather traffic).
  * ``crossover``   — dense O(n²) ``swap_gain_matrix`` (reference path)
    vs the sparse candidate-pair kernel, the measurement behind keeping
    the dense form out of plan selection.
"""

from __future__ import annotations

import time

import numpy as np

from ._common import write_bench


def _timeit(fn, repeats=3):
    """Median wall time of ``fn()`` (which must block), after warmup."""
    import jax
    jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _tree_factors(n):
    """n = 4**k tree; distances 1,2,4,... stay <= 127 up to n = 4096 so
    the matrix form quantizes to int8 at every benchmarked size."""
    k = (n - 1).bit_length() // 2
    return [4] * k, [float(2 ** i) for i in range(k)] or [1.0]


def _workload(rng, n, deg=8):
    """Random integer-weight graph + perm + candidate pairs (integer
    weights keep every f32 reduction exact, so tiled-vs-fused rows are
    comparing identical results, not just close ones)."""
    from repro.core.graph import DeviceGraph, device_pairs, from_edges
    m = n * deg // 2
    u = rng.integers(0, n, m)
    v = (u + 1 + rng.integers(0, n - 1, m)) % n
    keep = u != v
    g = from_edges(n, u[keep], v[keep],
                   rng.integers(1, 16, keep.sum()).astype(np.float64))
    dg = DeviceGraph.from_comm(g)
    perm = np.asarray(rng.permutation(n))
    p = min(4 * n, 16384)
    pairs = np.stack([rng.integers(0, n, p), rng.integers(0, n, p)],
                     axis=1)
    us, vs = device_pairs(pairs)
    return g, dg, perm, us, vs


def _forms(n):
    """The three distance forms at PE count n (matrix = the tree's
    integer table, so quantization applies)."""
    from repro.topology.base import make_topology
    from repro.topology.matrix import MatrixTopology
    factors, dists = _tree_factors(n)
    tree = make_topology("tree", factors=factors, distances=dists)
    side = int(round(n ** 0.5))
    torus = make_topology("torus", dims=[side, side])
    return [("tree", tree), ("torus", torus),
            ("matrix", MatrixTopology(tree.matrix()))]


def run(report, smoke: bool = False, out: str = "BENCH_kernels.json"):
    import jax
    import jax.numpy as jnp

    from repro.kernels import (KernelConfig, derive_kernel_config,
                               qap_objective as qk, quantize_table)
    from repro.kernels.config import table_bytes
    from repro.kernels.pair_gain import (edge_objective, pair_gains,
                                         pair_gains_pallas)
    from repro.core.spec import ShapeBucket

    interpret = jax.default_backend() != "tpu"
    rng = np.random.default_rng(0)
    sizes = [256] if smoke else [256, 1024, 4096]
    timings, tiling, bytes_moved = [], [], []

    def row(form, path, precision, n, us_, note=""):
        name = f"pair_gain/{form}/{path}/{precision}/n{n}"
        report(name, us_, note)
        timings.append({"form": form, "path": path,
                        "precision": precision, "n": n, "us": us_,
                        "note": note})

    for n in sizes:
        g, dg, perm_np, us, vs = _workload(rng, n)
        perm = jnp.asarray(perm_np, jnp.int32)
        bucket = ShapeBucket.of(g)
        for form, topo in _forms(n):
            kp = topo.kernel_params()
            kind, params = kp[0], kp[1:]
            if kind == "matrix":
                params = ()
                D32 = jnp.asarray(topo.matrix(), jnp.float32)
                packed = quantize_table(topo.matrix())
                Dq = None if packed is None else jnp.asarray(packed[0])
            else:
                D32, Dq = jnp.zeros((1, 1), jnp.float32), None
            cfg = derive_kernel_config(kind, bucket=bucket,
                                       table=topo.matrix()
                                       if kind == "matrix" else None)

            # ---- fused jnp vs Pallas pair gains (float tables)
            fused = jax.jit(lambda p: pair_gains(
                kind, params, dg.nbr, dg.wgt, p, us, vs, D32))
            t_fused = _timeit(lambda: fused(perm))
            row(form, "jnp_fused", "float32", n, t_fused)
            pall = jax.jit(lambda p: pair_gains_pallas(
                kind, params, dg.nbr, dg.wgt, p, us, vs, D32,
                interpret=interpret, config=cfg))
            row(form, "pallas", "float32", n, _timeit(lambda: pall(perm)),
                "interpret" if interpret else "device")

            # ---- quantized matrix tables (bit-identical, narrower moves)
            if Dq is not None:
                qf = jax.jit(lambda p: pair_gains(
                    kind, params, dg.nbr, dg.wgt, p, us, vs, Dq))
                row(form, "jnp_fused", packed[1], n, _timeit(
                    lambda: qf(perm)))
                qp = jax.jit(lambda p: pair_gains_pallas(
                    kind, params, dg.nbr, dg.wgt, p, us, vs, Dq,
                    interpret=interpret, config=cfg))
                row(form, "pallas", packed[1], n, _timeit(
                    lambda: qp(perm)),
                    "interpret" if interpret else "device")
                k_slots = int(us.shape[0]) * int(dg.nbr.shape[1]) * 4
                e_gather = int(dg.eu.shape[0])
                bytes_moved.append({
                    "n": n, "dist_dtype": packed[1],
                    "table_bytes_float32": table_bytes(n, None),
                    "table_bytes_packed": table_bytes(n, packed[1]),
                    "table_ratio": table_bytes(n, None)
                    / table_bytes(n, packed[1]),
                    # the host tables are float64, so end-to-end the
                    # packing shrinks resident distance state 8x (int8)
                    "table_ratio_vs_host_float64":
                        2 * table_bytes(n, None)
                        / table_bytes(n, packed[1]),
                    "gain_gather_bytes_float32": 2 * k_slots * 4,
                    "gain_gather_bytes_packed":
                        2 * k_slots * {"int8": 1, "int16": 2}[packed[1]],
                    "objective_gather_bytes_float32": e_gather * 4,
                    "objective_gather_bytes_packed":
                        e_gather * {"int8": 1, "int16": 2}[packed[1]],
                })

            # ---- edge objective: fused vs derived-tile vs forced tiles
            obj = jax.jit(lambda p: edge_objective(
                kind, params, dg.eu, dg.ev, dg.ew, p, D32))
            t_flat = _timeit(lambda: obj(perm))
            objc = jax.jit(lambda p: edge_objective(
                kind, params, dg.eu, dg.ev, dg.ew, p, D32, config=cfg))
            t_cfg = _timeit(lambda: objc(perm))
            small = KernelConfig(block_rows=1, lanes=128)
            objs = jax.jit(lambda p: edge_objective(
                kind, params, dg.eu, dg.ev, dg.ew, p, D32, config=small))
            t_small = _timeit(lambda: objs(perm))
            report(f"edge_objective/{form}/fused/n{n}", t_flat)
            report(f"edge_objective/{form}/derived_cfg/n{n}", t_cfg,
                   cfg.tag())
            e_pad = int(dg.eu.shape[0])
            tiling.append({"form": form, "n": n, "fused_us": t_flat,
                           "derived_cfg_us": t_cfg,
                           "derived_cfg": cfg.to_dict(),
                           # single-tile ⇒ the tiled path lowers to the
                           # identical fused graph (bit-identical, same
                           # work) — timing deltas are dispatch noise
                           "derived_single_tile":
                               cfg.block_rows * cfg.lanes >= e_pad,
                           "forced_128elem_tiles_us": t_small})

            # ---- Pallas edge-objective entry (the backend='pallas' path)
            pu = perm[dg.eu]
            pv = perm[dg.ev]
            geom = dict(lanes=cfg.lanes, block_rows=cfg.block_rows,
                        interpret=interpret)
            if kind == "tree":
                def pk():
                    return qk.qap_objective_edges(
                        pu, pv, dg.ew, strides=params[0],
                        dists=params[1], **geom)
            elif kind == "torus":
                def pk():
                    return qk.qap_objective_edges_torus(
                        pu, pv, dg.ew, dims=params[0],
                        weights=params[1], **geom)
            else:
                Dk = Dq if Dq is not None else D32

                def pk():
                    return qk.qap_objective_edges_matrix(
                        pu, pv, dg.ew, Dk, **geom)
            report(f"edge_objective/{form}/pallas/n{n}", _timeit(pk),
                   "interpret" if interpret else "device")

    # ---- dense/sparse crossover: the measurement behind keeping
    # swap_gain_matrix a reference path (never plan-selected)
    crossover = []
    from repro.kernels.swap_gain import swap_gain_matrix
    from repro.topology.base import make_topology
    for n in ([64, 128] if smoke else [64, 128, 256, 512]):
        g, dg, perm_np, us, vs = _workload(rng, n)
        perm = jnp.asarray(perm_np, jnp.int32)
        topo = make_topology("tree", factors=[2] * (n.bit_length() - 1),
                             distances=[float(i + 1) for i in
                                        range(n.bit_length() - 1)])
        D = topo.matrix()
        C = np.zeros((n, n))
        u, v, w = g.edge_list()
        C[u, v] = w
        C[v, u] = w
        Cd = jnp.asarray(C, jnp.float32)
        Bd = jnp.asarray(D[np.ix_(perm_np, perm_np)], jnp.float32)
        t_dense = _timeit(
            lambda: swap_gain_matrix(Cd, Bd, interpret=interpret))
        D32 = jnp.asarray(D, jnp.float32)
        sparse = jax.jit(lambda p: pair_gains(
            "matrix", (), dg.nbr, dg.wgt, p, us, vs, D32))
        t_sparse = _timeit(lambda: sparse(perm))
        report(f"crossover/dense_n{n}", t_dense,
               "interpret" if interpret else "device")
        report(f"crossover/sparse_n{n}", t_sparse,
               f"pairs={int(us.shape[0])}")
        crossover.append({"n": n, "dense_us": t_dense,
                          "sparse_us": t_sparse,
                          "pairs": int(us.shape[0])})

    payload = {
        "timings": timings,
        "tiling": tiling,
        "bytes_moved": bytes_moved,
        "crossover": crossover,
        "smoke": smoke,
        "notes": {
            "device_vs_interpret": "compare meta.backend/pallas_interpret "
                                   "across archived files; the GPU lane "
                                   "(.github/workflows/gpu.yml) emits the "
                                   "non-interpreted counterpart",
            "quantized_parity": "int8/int16 rows are bit-identical to "
                                "float32 rows by construction (exact "
                                "integer tables; tested in "
                                "tests/test_kernel_config.py)",
        },
    }
    write_bench(payload, out)
    report("bench_kernels/wrote", 0.0, out)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d="": print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
