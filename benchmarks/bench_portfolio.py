"""Portfolio benchmark — single-trajectory device pipeline vs the
vmapped multistart portfolio (and the tabu escape in isolation) on the
mesh-collective workload.

Three pipelines per (n, topology) cell, same construction seed family,
same candidate neighborhood, same device engine and sweep budget:

* ``single``     — the flat PR 3/5 pipeline: one trajectory, monotone
  sweep (the portfolio's lanes=1/rounds=1/tabu=0 degeneracy).
* ``tabu``       — the SAME single trajectory with tabu tenure enabled:
  the sweep walks downhill out of the local optimum the monotone
  matching converged to and returns the best permutation seen.  Strictly
  better final objective on a cell = an escaped local optimum.
* ``portfolio``  — lanes restart trajectories in ONE vmapped engine
  call, perturbation kicks + tournament selection between rounds, tabu
  on (:mod:`repro.portfolio`).

Writes ``BENCH_portfolio.json``: per-cell objective/wall-time plus the
headline per-(n, topology) comparison.  Objective-per-wall-second is
measured at MATCHED wall clock: the single-trajectory pipeline is given
the portfolio's wall budget as sequential restarts (best-of-k over
consecutive seeds — the only way a single trajectory can spend more
wall), so "portfolio beats single" means a strictly better objective
from the same wall-seconds, i.e. equal-or-better objective per
wall-second by construction.  The acceptance bar is that on ≥ 2
topologies, plus ≥ 1 cell where tabu beats the monotone sweep strictly
(an escaped local optimum).

Wall-times exclude compilation (one warm-up map per mapper/spec) but
include construction and pair generation: graph-side caches are cleared
before the timed run so every pipeline pays its full per-graph cost
honestly.

    python -m benchmarks.bench_portfolio [--smoke] [--out ...]
"""

from __future__ import annotations

import argparse
import time

from repro.core import Mapper, MappingSpec, tpu_v5e_fleet
from repro.core.spec import PortfolioSpec
from repro.topology import MatrixTopology, tpu_v5e_torus

from .bench_topology import mesh_workload

MAX_SWEEPS = 64
PAIR_DIST = 2
LANES = 8
ROUNDS = 3
TENURE = 8
KICK = 0.1
STAGNATION = 2


def _machines(pods: int) -> dict:
    torus = tpu_v5e_torus(pods=pods)
    return {
        "tree": tpu_v5e_fleet(pods=pods),
        "torus": torus,
        # explicit-matrix view of the torus: the general sparse-QAP path
        "matrix": MatrixTopology(matrix=torus.distance_matrix()),
    }


def _timed_map(mapper: Mapper, g, spec: MappingSpec):
    """One warmed, cache-honest map: compile on a warm-up run, then
    clear the plan's graph-side caches so the timed run pays pair
    generation and construction for real."""
    mapper.map(g, spec=spec)                    # warm-up: compiles
    mapper.lower_for(g, spec).clear_request_caches()
    t0 = time.perf_counter()
    res = mapper.map(g, spec=spec)
    return res, time.perf_counter() - t0


def _gain_rate(res, dt: float) -> float:
    """Objective improvement bought per wall-second."""
    return (res.initial_objective - res.final_objective) / max(dt, 1e-9)


MAX_RESTARTS = 64


def _equal_wall_restarts(mapper: Mapper, g, spec: MappingSpec,
                         wall_budget: float) -> tuple:
    """Best-of-k sequential single-trajectory restarts (consecutive
    seeds, warm plan and pair caches — the steady-state session cost)
    until ``wall_budget`` seconds are spent: the matched-wall baseline
    the portfolio must beat to claim better objective-per-wall-second."""
    plan = mapper.lower_for(g, spec)
    best = float("inf")
    k = 0
    t0 = time.perf_counter()
    while (time.perf_counter() - t0 < wall_budget
           and k < MAX_RESTARTS) or k == 0:
        best = min(best, plan.execute(g, seed=spec.seed + k
                                      ).final_objective)
        k += 1
    return best, k, time.perf_counter() - t0


def run(report, smoke: bool = False, out: str = "BENCH_portfolio.json"):
    pod_counts = [1] if smoke else [1, 4]       # n = 256 · pods
    single = MappingSpec(construction="random",
                         neighborhood="communication",
                         neighborhood_dist=PAIR_DIST,
                         preconfiguration="eco", engine="device",
                         seed=0, max_sweeps=MAX_SWEEPS)
    # the tabu escape in isolation: same ONE trajectory (lanes=1 keeps
    # the construction seed), tenure on, no kicks/rounds
    tabu = single.replace(portfolio=PortfolioSpec(
        lanes=1, rounds=1, tabu_tenure=TENURE))
    portfolio = single.replace(portfolio=PortfolioSpec(
        lanes=LANES, rounds=ROUNDS, tabu_tenure=TENURE,
        kick_strength=KICK, stagnation=STAGNATION))
    cells, headline = [], []
    for pods in pod_counts:
        g = mesh_workload(pods)
        for tname, machine in _machines(pods).items():
            mapper = Mapper(machine, single)
            out_runs = {}
            for mode, spec in (("single", single), ("tabu", tabu),
                               ("portfolio", portfolio)):
                res, dt = _timed_map(mapper, g, spec)
                out_runs[mode] = (res, dt)
                cells.append({
                    "n": g.n, "topology": tname, "pipeline": mode,
                    "seconds": dt,
                    "initial_objective": res.initial_objective,
                    "final_objective": res.final_objective,
                    "gain_per_second": _gain_rate(res, dt),
                })
                report(f"portfolio/{tname}/n{g.n}/{mode}", dt * 1e6,
                       f"J={res.final_objective:.4e}")
            rs, ts = out_runs["single"]
            rt, tt = out_runs["tabu"]
            rp, tp = out_runs["portfolio"]
            ew_best, ew_k, ew_wall = _equal_wall_restarts(
                mapper, g, single, tp)
            tol = 1e-5 * max(1.0, abs(rs.final_objective))
            cmp = {
                "n": g.n, "topology": tname,
                "single_J": rs.final_objective,
                "tabu_J": rt.final_objective,
                "portfolio_J": rp.final_objective,
                "improvement": 1.0 - rp.final_objective /
                    max(rs.final_objective, 1e-12),
                "single_seconds": ts, "tabu_seconds": tt,
                "portfolio_seconds": tp,
                "single_gain_per_s": _gain_rate(rs, ts),
                "portfolio_gain_per_s": _gain_rate(rp, tp),
                # the single-trajectory pipeline given the portfolio's
                # wall budget as sequential restarts (best-of-k)
                "equal_wall_single_J": ew_best,
                "equal_wall_restarts": ew_k,
                "equal_wall_seconds": ew_wall,
                # strictly better objective from the same wall-seconds
                # = equal-or-better objective per wall-second
                "portfolio_beats_single":
                    rp.final_objective < rs.final_objective - tol
                    and rp.final_objective < ew_best - tol,
                "tabu_escapes":
                    rt.final_objective < rs.final_objective - tol,
            }
            headline.append(cmp)
            report(f"portfolio/{tname}/n{g.n}/headline", 0,
                   f"improvement={cmp['improvement']:.1%};"
                   f"beats={cmp['portfolio_beats_single']};"
                   f"tabu_escapes={cmp['tabu_escapes']}")

    payload = {"mode": "smoke" if smoke else "full",
               "workload": "mesh-collectives",
               "max_sweeps": MAX_SWEEPS, "pair_dist": PAIR_DIST,
               "portfolio": {"lanes": LANES, "rounds": ROUNDS,
                             "tabu_tenure": TENURE,
                             "kick_strength": KICK,
                             "stagnation": STAGNATION},
               "max_restarts": MAX_RESTARTS,
               "cells": cells, "headline": headline}
    from ._common import write_bench
    payload = write_bench(payload, out)
    report("portfolio/json_written", 0, out)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single-pod fleet only (CI)")
    ap.add_argument("--out", default="BENCH_portfolio.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
