"""Engine benchmark — host vs device refinement on the mesh workload.

Runs the same refinement problem (random construction on the
mesh-collective traffic graph, same candidate-pair set, same sweep
budget) through the host ``parallel_sweep_search`` driver and the
device-resident ``repro.engine`` sweep loop, at fleet sizes
n ∈ {256, 512, 1024} on tree and torus machine models, and writes
``BENCH_engine.json``: wall-time, applied sweeps, per-sweep wall-time,
and final objective per cell, plus the headline device-vs-host
comparison (per-sweep speedup; device objective ≤ host).

Device numbers are interpret-/CPU-mode when no TPU is attached — the
comparison is conservative there (the jitted loop still amortizes; a
real TPU widens the gap).

    python -m benchmarks.bench_engine [--smoke] [--out BENCH_engine.json]
"""

from __future__ import annotations

import argparse
import time

from repro.core import qap_objective, tpu_v5e_fleet
from repro.core.construction import construct
from repro.core.local_search import communication_pairs, \
    parallel_sweep_search
from repro.engine import RefinementEngine
from repro.topology import as_topology, tpu_v5e_torus

from .bench_topology import mesh_workload

MAX_SWEEPS = 64
PAIR_DIST = 2


def _machines(pods: int) -> dict:
    return {"tree": tpu_v5e_fleet(pods=pods),
            "torus": tpu_v5e_torus(pods=pods)}


def _sweeps_of(stats) -> int:
    return max(len(stats.objective_trace) - 1, 1)


def run(report, smoke: bool = False, out: str = "BENCH_engine.json"):
    pod_counts = [1] if smoke else [1, 2, 4]      # n = 256 · pods
    cells, headline = [], []
    for pods in pod_counts:
        g = mesh_workload(pods)
        pairs = communication_pairs(g, PAIR_DIST)
        for tname, machine in _machines(pods).items():
            topo = as_topology(machine)
            perm0 = construct("random", g, topo, seed=0)
            j0 = qap_objective(g, topo, perm0)

            # ---- host reference driver
            p_host = perm0.copy()
            t0 = time.perf_counter()
            st_host = parallel_sweep_search(g, topo, p_host, pairs,
                                            max_sweeps=MAX_SWEEPS)
            t_host = time.perf_counter() - t0

            # ---- device engine (compile excluded: one warm-up run)
            eng = RefinementEngine(topo, max_sweeps=MAX_SWEEPS)
            eng.refine(g, perm0.copy(), pairs)
            p_dev = perm0.copy()
            t0 = time.perf_counter()
            st_dev = eng.refine(g, p_dev, pairs)
            t_dev = time.perf_counter() - t0

            for engine, st, dt in (("host", st_host, t_host),
                                   ("device", st_dev, t_dev)):
                sweeps = _sweeps_of(st)
                cells.append({
                    "n": g.n, "topology": tname, "engine": engine,
                    "pairs": int(len(pairs)), "seconds": dt,
                    "sweeps": sweeps,
                    "us_per_sweep": dt / sweeps * 1e6,
                    "initial_objective": j0,
                    "final_objective": st.final_objective,
                })
                report(f"engine/{tname}/n{g.n}/{engine}",
                       dt / sweeps * 1e6,
                       f"J={st.final_objective:.4e};sweeps={sweeps}")

            tol = 1e-5 * max(1.0, abs(st_host.final_objective))
            cmp = {
                "n": g.n, "topology": tname,
                "host_us_per_sweep": t_host / _sweeps_of(st_host) * 1e6,
                "device_us_per_sweep": t_dev / _sweeps_of(st_dev) * 1e6,
                "device_per_sweep_speedup":
                    (t_host / _sweeps_of(st_host))
                    / max(t_dev / _sweeps_of(st_dev), 1e-12),
                "host_final_objective": st_host.final_objective,
                "device_final_objective": st_dev.final_objective,
                "device_objective_leq_host":
                    st_dev.final_objective <= st_host.final_objective + tol,
            }
            cmp["device_wins_wall_time"] = cmp["device_per_sweep_speedup"] > 1
            headline.append(cmp)
            report(f"engine/{tname}/n{g.n}/speedup", 0,
                   f"x{cmp['device_per_sweep_speedup']:.2f};"
                   f"obj_leq={cmp['device_objective_leq_host']}")

    payload = {"mode": "smoke" if smoke else "full",
               "workload": "mesh-collectives",
               "max_sweeps": MAX_SWEEPS, "pair_dist": PAIR_DIST,
               "cells": cells, "headline": headline}
    from ._common import write_bench
    payload = write_bench(payload, out)
    report("engine/json_written", 0, out)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single-pod fleet only (CI)")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
