"""CI trace smoke: drive the quickstart workload through ``viem
--profile`` and check the observability invariants end to end.

Two gates, both hard failures (exit 1):

1. **Trace content** — the emitted Chrome ``trace_event`` JSON must be
   structurally loadable (``traceEvents`` list, ``ph: "X"`` complete
   events) and carry the pipeline spans (``plan.lower``,
   ``plan.execute``, ``vcycle.construct``, per-level ``vcycle.refine``)
   plus per-sweep engine counter tracks (``ph: "C"`` events from the
   attached telemetry).

2. **Retrace budget** — after a warm-up map, further maps of the same
   bucket (telemetry on AND off) must add ZERO new engine traces and
   zero plan builds: the telemetry toggle is a runtime operand, and a
   regression here silently multiplies steady-state serving cost.

Usage:
    PYTHONPATH=src python -m benchmarks.trace_smoke [--out smoke.trace.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
from pathlib import Path

FAILURES: list[str] = []


def check(ok: bool, what: str) -> None:
    print(f"  {'ok' if ok else 'FAIL'}: {what}")
    if not ok:
        FAILURES.append(what)


def run_cli(out: str) -> None:
    """The quickstart workload (guide §4.1 shapes) through the real CLI
    entry point: 512-process 3-D grid onto the 16:8:4 hierarchy."""
    from repro.cli.viem import main as viem_main
    from repro.core import grid3d, write_metis

    g = grid3d(8, 8, 8)
    with tempfile.TemporaryDirectory() as td:
        gpath = str(Path(td) / "grid.metis")
        write_metis(g, gpath)
        viem_main([gpath,
                   "--hierarchy_parameter_string=16:8:4",
                   "--distance_parameter_string=1:10:100",
                   "--engine=device", "--multilevel",
                   "--preconfiguration=fast",
                   f"--output_filename={Path(td) / 'perm'}",
                   f"--profile={out}", "--telemetry"])


def check_trace(out: str) -> None:
    payload = json.loads(Path(out).read_text())
    events = payload.get("traceEvents")
    check(isinstance(events, list) and len(events) > 0,
          "traceEvents is a non-empty list")
    events = events or []
    complete = [e for e in events if e.get("ph") == "X"]
    names = {e["name"] for e in complete}
    for required in ("plan.lower", "plan.execute", "plan.vcycle",
                     "vcycle.construct", "vcycle.refine"):
        check(required in names, f"span {required!r} present")
    refines = [e for e in complete if e["name"] == "vcycle.refine"]
    levels = {e.get("args", {}).get("level") for e in refines}
    check(len(levels) > 1, f"per-level refine spans (levels {levels})")
    check(all(e.get("args", {}).get("retraces") is not None
              for e in refines), "refine spans carry retrace deltas")
    counters = [e for e in events if e.get("ph") == "C"]
    tracks = {e["name"] for e in counters}
    check("engine/exchanges" in tracks,
          f"per-sweep counter tracks present ({sorted(tracks)})")
    check(any(e["args"]["value"] > 0 for e in counters
              if e["name"] == "engine/objective"),
          "objective counter track has real values")


def check_retrace_budget() -> None:
    """Same-bucket maps after warm-up — telemetry toggled both ways —
    must not grow any engine's trace count or lower a new plan."""
    from repro.core import Hierarchy, Mapper, MappingSpec, grid3d
    from repro.core.spec import MultilevelSpec

    topo = Hierarchy.from_strings("16:8:4", "1:10:100")
    spec = MappingSpec(engine="device", preconfiguration="fast",
                       multilevel=MultilevelSpec())
    mapper = Mapper(topo, spec)
    g = grid3d(8, 8, 8)
    mapper.map(g)                      # warm-up: pays every compile
    plan = next(iter(mapper._plans.values()))
    traces0 = [eng.trace_count() for eng in plan.engines]
    builds0 = mapper.cache_info()["plan_builds"]
    for telemetry in (False, True, False, True):
        mapper.map(g, telemetry=telemetry)
    traces1 = [eng.trace_count() for eng in plan.engines]
    builds1 = mapper.cache_info()["plan_builds"]
    check(traces1 == traces0,
          f"telemetry toggles add no engine retraces "
          f"({traces0} -> {traces1})")
    check(builds1 == builds0, "no new plan lowered after warm-up")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="smoke.trace.json")
    args = ap.parse_args(argv)

    print("== viem --profile on the quickstart workload ==")
    run_cli(args.out)
    print("== trace content ==")
    check_trace(args.out)
    print("== retrace budget ==")
    check_retrace_budget()
    if FAILURES:
        print(f"trace smoke: {len(FAILURES)} failure(s)")
        for f in FAILURES:
            print(f"  - {f}")
        sys.exit(1)
    print("trace smoke: ok")


if __name__ == "__main__":
    main()
